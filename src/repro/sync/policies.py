"""The paper's three builtin disciplines as :class:`~repro.sync.api.PolicyDef`s.

Each policy bundles the three layer implementations that were previously
scattered across ``core/scu/primitives.py`` (simulator fragments),
``kernels/scu_barrier/ops.py`` (chip-level collectives) and
``core/sync/strategies.py`` (training-schedule hooks):

  * ``sw``  -- pure software spin-locks (Sec. 6.1, "purely spin-lock based").
    Chip level: serialized ring accumulation, one contender per turn.
    Training: per-tensor optimization-barrier chain (one collective per
    parameter tensor, strictly in order).
  * ``tas`` -- software + idle-waiting on SCU notifier events.
    Chip level: log-n dissemination rounds over the shared status word.
    Training: a single coarse synchronization point after backward.
  * ``scu`` -- the paper's hardware primitives (single-``elw`` barrier).
    Chip level: one fused all-reduce of the arrival word.
    Training: fine-grain bucketed reduce-scatter onto ZeRO shards with no
    artificial barriers (XLA overlaps collectives with backward compute).

All chip-level barriers *derive the released count from the exchanged
values* -- there is no hidden ``psum`` oracle patching the result (the
oracle lives only in tests, ``ref_barrier_count``).  All disciplines are
numerically identical; they differ in schedule only.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import axis_size
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import param_specs, zero_spec
from repro.sync.api import PolicyDef, register_policy

from repro.core.scu.primitives import (
    DEFAULT_COSTS,
    BarrierState,
    scu_barrier,
    scu_mutex_section,
    sw_barrier,
    sw_mutex_section,
    tas_barrier,
    tas_mutex_section,
    trace_sw_barrier_body,
    trace_tas_mutex_section,
)

__all__ = ["SCU", "TAS", "SW"]


# ---------------------------------------------------------------------------
# Layer (a): simulator fragments -- thin adapters over core/scu/primitives
# ---------------------------------------------------------------------------


def _no_sim_state(n_cores: int) -> None:
    """The hardware SCU keeps all barrier state in the unit itself."""
    return None


def _scu_sim_barrier(cluster, cid, state, cost_model=None):
    yield from scu_barrier(cluster, cid)


def _scu_sim_mutex(cluster, cid, t_crit, state, cost_model=None):
    yield from scu_mutex_section(cluster, cid, t_crit)


def _sw_sim_barrier(cluster, cid, state, cost_model=None):
    yield from sw_barrier(cluster, cid, state, cost_model or DEFAULT_COSTS)


def _sw_sim_mutex(cluster, cid, t_crit, state, cost_model=None):
    yield from sw_mutex_section(cluster, cid, t_crit, cost_model or DEFAULT_COSTS)


def _tas_sim_barrier(cluster, cid, state, cost_model=None):
    yield from tas_barrier(cluster, cid, state, cost_model or DEFAULT_COSTS)


def _tas_sim_mutex(cluster, cid, t_crit, state, cost_model=None):
    yield from tas_mutex_section(cluster, cid, t_crit, cost_model or DEFAULT_COSTS)


# Trace-IR lowerings (repro.core.scu.trace).  The sw/tas barriers and the
# tas mutex branch on *observed* TCDM values (arrival count, lock word), so
# sentinel tracing cannot linearize them -- they get explicit emitters that
# encode the branches as BR rows.  The sw mutex and both scu fragments are
# value-independent, so per-core sentinel tracing is declared safe instead.


def _sw_trace_barrier(tb, cluster, cid, state, cost_model=None):
    trace_sw_barrier_body(tb, cid, state, cost_model or DEFAULT_COSTS, idle_wait=False)


def _tas_trace_barrier(tb, cluster, cid, state, cost_model=None):
    trace_sw_barrier_body(tb, cid, state, cost_model or DEFAULT_COSTS, idle_wait=True)


def _tas_trace_mutex(tb, cluster, cid, t_crit, state, cost_model=None):
    trace_tas_mutex_section(tb, cid, t_crit, cost_model or DEFAULT_COSTS)


# ---------------------------------------------------------------------------
# Layer (b): chip-level barriers (inside shard_map/pmap over ``axis``)
# ---------------------------------------------------------------------------


def scu_chip_barrier(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """One fused synchronization event (the hardware-barrier analogue)."""
    return jax.lax.psum(arrive, axis)


def contribution_vector(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Per-device one-hot contribution slots for exchange-based barriers.

    Slot ``j`` holds device ``j``'s arrival word (or 0 until it is heard
    from); combining two vectors with ``maximum`` is a union because each
    slot only ever carries one device's non-negative arrival count.
    """
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    vec = jnp.zeros((n,) + arrive.shape, arrive.dtype)
    return vec.at[idx].set(arrive)


def tas_chip_barrier(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Log-n dissemination rounds on the shared status word.

    Round k: every device forwards what it has heard so far to the device
    ``2**k`` ahead (mod n).  After ceil(log2 n) rounds every device has heard
    from everyone (windows are contiguous and grow as min(2**k, n)), so the
    released count is the sum of the exchanged contributions -- exact for any
    group size, with no oracle correction.
    """
    n = axis_size(axis)
    vec = contribution_vector(arrive, axis)
    shift = 1
    while shift < n:
        perm = [(i, (i + shift) % n) for i in range(n)]
        incoming = jax.lax.ppermute(vec, axis, perm)
        vec = jnp.maximum(vec, incoming)
        shift *= 2
    return vec.sum(axis=0)


def sw_chip_barrier(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """n-1 serialized ring turns: each contestant's word circulates in order.

    The optimization barrier keeps XLA from fusing the turns -- the rounds
    are a dependency chain, like the spin-lock's serialized acquire order.
    The count is the sum of every token received, exact for any group size.
    """
    n = axis_size(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]
    total = arrive
    token = arrive
    for _ in range(n - 1):
        token = jax.lax.ppermute(token, axis, perm)
        total = total + token
        total, token = jax.lax.optimization_barrier((total, token))
    return total


# ---------------------------------------------------------------------------
# Layer (c): training-schedule hooks
# ---------------------------------------------------------------------------


def _barrier_chain(tree: Any) -> Any:
    """Serialize all leaves with an optimization-barrier dependency chain."""
    leaves, treedef = jax.tree.flatten(tree)
    token = jnp.zeros((), jnp.float32)
    out = []
    for leaf in leaves:
        leaf, token = jax.lax.optimization_barrier((leaf, token))
        token = token + 0.0  # keep the chain explicit
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _zero_specs(params_shape: Any, mesh: Mesh, cfg=None) -> Any:
    """ZeRO shard specs over the data axes for every parameter."""
    specs = param_specs(params_shape, mesh, cfg=cfg)
    return jax.tree.map(
        lambda s, p: zero_spec(s, tuple(p.shape), mesh),
        specs,
        params_shape,
        is_leaf=lambda x: isinstance(x, P),
    )


def sw_shape_gradients(grads, params_shape, mesh: Mesh, cfg=None):
    """Per-tensor serialized sync: one collective per tensor, program order."""
    return _barrier_chain(grads)


def tas_shape_gradients(grads, params_shape, mesh: Mesh, cfg=None):
    """Single coarse sync point between backward and optimizer."""
    return jax.lax.optimization_barrier(grads)


def zero_shape_gradients(grads, params_shape, mesh: Mesh, cfg=None):
    """Fine-grain reduce-scatter onto the ZeRO shards; no barriers."""
    zspecs = _zero_specs(params_shape, mesh, cfg=cfg)
    return jax.tree.map(
        lambda g, s: jax.lax.with_sharding_constraint(
            g, jax.sharding.NamedSharding(mesh, s)
        ),
        grads,
        zspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def replicated_opt_state_specs(params_shape, mesh: Mesh, cfg=None):
    """Baselines keep master/m/v sharded like the params (replicated over
    data) -- the paper's 'every contestant keeps its own copy spinning'."""
    specs = param_specs(params_shape, mesh, cfg=cfg)
    return {"master": specs, "m": specs, "v": specs}


def zero_opt_state_specs(params_shape, mesh: Mesh, cfg=None):
    """ZeRO-shard the optimizer state over the data axes (shard-parallel
    'critical section': the optimizer update)."""
    specs = _zero_specs(params_shape, mesh, cfg=cfg)
    return {"master": specs, "m": specs, "v": specs}


# ---------------------------------------------------------------------------
# The builtin policies
# ---------------------------------------------------------------------------

SCU = register_policy(PolicyDef(
    name="scu",
    description=(
        "hardware SCU primitives: single-elw barrier/mutex; chip: one fused "
        "all-reduce; training: fine-grain ZeRO reduce-scatter, no barriers"
    ),
    aliases=("SCU",),
    make_sim_state=_no_sim_state,
    sim_barrier=_scu_sim_barrier,
    sim_mutex=_scu_sim_mutex,
    chip_barrier=scu_chip_barrier,
    shape_gradients=zero_shape_gradients,
    opt_state_specs=zero_opt_state_specs,
    trace_safe_barrier=True,
    trace_safe_mutex=True,
))

TAS = register_policy(PolicyDef(
    name="tas",
    description=(
        "TAS spin + SCU-notifier idle-wait; chip: log-n dissemination rounds; "
        "training: one coarse sync point after backward"
    ),
    aliases=("TAS",),
    make_sim_state=BarrierState,
    sim_barrier=_tas_sim_barrier,
    sim_mutex=_tas_sim_mutex,
    chip_barrier=tas_chip_barrier,
    shape_gradients=tas_shape_gradients,
    opt_state_specs=replicated_opt_state_specs,
    trace_barrier=_tas_trace_barrier,
    trace_mutex=_tas_trace_mutex,
))

SW = register_policy(PolicyDef(
    name="sw",
    description=(
        "pure software spin-locks; chip: n serialized ring turns; training: "
        "per-tensor optimization-barrier chain"
    ),
    aliases=("SW",),
    make_sim_state=BarrierState,
    sim_barrier=_sw_sim_barrier,
    sim_mutex=_sw_sim_mutex,
    chip_barrier=sw_chip_barrier,
    shape_gradients=sw_shape_gradients,
    opt_state_specs=replicated_opt_state_specs,
    trace_barrier=_sw_trace_barrier,
    trace_safe_mutex=True,
))
