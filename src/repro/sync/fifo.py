"""The producer-consumer event-FIFO discipline (paper Sec. 4.3, SCU FIFO).

The SCU's event FIFO exists precisely for the fine-grain producer-consumer
chains that pure barriers serve poorly: a producer pushes an 8-bit event the
moment a datum is ready and the consumer sleeps clock-gated until its pop is
matched -- no core spins, no core waits for unrelated peers.  MemPool
(Riedel et al., 2023) runs the same pattern at 256 cores, which is why the
scaling sweeps carry this policy to 16/32/64-core clusters.

Registered once as the ``fifo`` :class:`~repro.sync.api.PolicyDef`, the
discipline shows up at every layer:

  * simulator -- producers ``Scu("write", ("fifo", i, "push"), v)``,
    consumers ``Scu("elw", ("fifo", i, "pop"))`` (clock-gated until the FIFO
    comparator matches an event to them).  The barrier is a gather/release
    over FIFOs (arrivals stream into core 0's gather queue; the release is
    one token into each consumer's private queue, so back-to-back barriers
    cannot steal each other's tokens); the mutex passes a single ownership
    token through one queue (pop = acquire, push = release, FIFO-fair).
    :func:`fifo_pipeline_programs` is the native pipelined-chain builder:
    per-link data queues plus a credit queue from the last stage back to the
    first bound the in-flight items to ``depth`` (classic credit flow), so
    stages overlap instead of meeting at a global barrier every tick.
  * chip level -- a point-to-point pipelined chain: the arrival word is
    accumulated along a neighbor send-recv chain (device i adds its word to
    the partial from i-1), then the total streams back along the reverse
    chain -- 2(n-1) pairwise hops, no all-to-all collective.
  * training -- a pipeline-style stage schedule: gradients reduce-scatter
    onto the ZeRO shards exactly like ``scu`` (numerically identical), but
    the tensors are grouped into pipeline stages chained by optimization
    barriers, so XLA schedules the collectives as staged hand-offs rather
    than one unordered wave.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size

from repro.core.scu.engine import Compute, Scu
from repro.core.scu.primitives import DEFAULT_COSTS
from repro.sync.api import PolicyDef, register_policy
from repro.sync.policies import zero_opt_state_specs, zero_shape_gradients

__all__ = [
    "FIFO",
    "FIFO_PIPELINE_STAGES",
    "FifoState",
    "fifo_barrier",
    "fifo_chip_barrier",
    "fifo_mutex_section",
    "fifo_pipeline_programs",
    "fifo_shape_gradients",
    "fifo_work_queue_programs",
    "chain_fifo_span",
]

# SCU FIFO instance allocation (instance 0 stays the legacy cluster-external
# event queue; SCU(...) provisions 2*n_cores+8 instances by default):
#   1                      barrier gather queue (arrivals -> core 0)
#   2                      mutex ownership-token queue
#   3 .. 3+n-1             per-core barrier release queues
#   3+n .. 3+2n-2          chain link queues (stage i -> i+1 at 3+n+i)
#   3+2n-1                 chain credit queue (last stage -> stage 0)
F_GATHER = 1
F_MUTEX = 2
F_RELEASE_BASE = 3


def _release_addr(cid: int) -> int:
    return F_RELEASE_BASE + cid


def _link_addr(n_cores: int, link: int) -> int:
    return F_RELEASE_BASE + n_cores + link


def _credit_addr(n_cores: int) -> int:
    return F_RELEASE_BASE + 2 * n_cores - 1


def chain_fifo_span(n_cores: int) -> int:
    """Number of SCU FIFO instances the chain programs address (for sizing)."""
    return F_RELEASE_BASE + 2 * n_cores


class FifoState:
    """Per-run FIFO-discipline bookkeeping shared by all cores."""

    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        self.mutex_seeded = False  # the single ownership token, pushed once


# ---------------------------------------------------------------------------
# Layer (a): simulator fragments
# ---------------------------------------------------------------------------


def fifo_barrier(cl, cid: int, st: FifoState, cm=DEFAULT_COSTS):
    """Gather/release barrier over event FIFOs.

    Arrivals stream into core 0's gather queue (producers push and move on to
    their private release pop); core 0 pops ``n-1`` arrival events -- asleep,
    clock-gated, between them -- then pushes one release token into *each*
    consumer's private queue.  Private release queues (rather than one shared
    queue) make back-to-back barriers safe: a fast core re-entering the next
    barrier can only ever pop its own queue, which holds at most its own
    token.
    """
    n = st.n_cores
    yield Compute(cm.call)
    if n == 1:
        yield Compute(cm.ret)
        return
    if cid == 0:
        for _ in range(n - 1):
            yield Compute(1)  # pop address setup
            yield Scu("elw", ("fifo", F_GATHER, "pop"))
        for peer in range(1, n):
            yield Compute(1)  # release address setup
            yield Scu("write", ("fifo", _release_addr(peer), "push"), 1)
    else:
        yield Compute(1)  # push address setup
        yield Scu("write", ("fifo", F_GATHER, "push"), cid % 256)
        yield Compute(1)  # pop address setup
        yield Scu("elw", ("fifo", _release_addr(cid), "pop"))
    yield Compute(cm.ret)


def fifo_mutex_section(cl, cid: int, t_crit: int, st: FifoState, cm=DEFAULT_COSTS):
    """Token-passing mutex: one ownership token circulates through a queue.

    Acquire = pop (clock-gated until the token is matched to this core),
    release = push.  The FIFO's popper queue makes the lock FIFO-fair; the
    single token makes it mutually exclusive.  The first core to run the
    section seeds the token (shared Python-side state, so exactly one push).
    """
    if not st.mutex_seeded:
        st.mutex_seeded = True
        yield Scu("write", ("fifo", F_MUTEX, "push"), 1)
    yield Compute(1)  # pop address setup
    yield Scu("elw", ("fifo", F_MUTEX, "pop"))
    if t_crit > 0:
        yield Compute(t_crit)
    yield Compute(1)  # push address setup
    yield Scu("write", ("fifo", F_MUTEX, "push"), 1)


def _fifo_sim_barrier(cluster, cid, state, cost_model=None):
    yield from fifo_barrier(cluster, cid, state, cost_model or DEFAULT_COSTS)


def _fifo_sim_mutex(cluster, cid, t_crit, state, cost_model=None):
    yield from fifo_mutex_section(
        cluster, cid, t_crit, state, cost_model or DEFAULT_COSTS
    )


def fifo_pipeline_programs(
    n_cores: int, work, state, cost_model=None, depth: int = 8
):
    """Native pipelined chain: one stage per core, credit-bounded in flight.

    ``work[item][stage]`` is the Compute cost of ``item`` at ``stage``.
    Stage ``s`` pops its input event from link ``s-1``, works, and pushes the
    completion event into link ``s``; the last stage returns a credit to
    stage 0, which stops injecting more than ``depth`` items ahead of the
    tail.  Every wait is a clock-gated elw pop -- no spinning, no barrier:
    stages overlap whenever the work is there, which is the whole point of
    the FIFO discipline.

    The credit flow bounds every link queue's occupancy to ``depth``, so
    ``depth`` is additionally clamped to the SCU's guaranteed FIFO capacity
    (``max(16, 2*n_cores)``, the ``SCU(...)`` default): a deeper request
    would overflow the queues, drop events, and deadlock the chain.  The
    programs re-check the actual SCU's provisioning (instance count and
    queue depth) when they start, so a custom under-provisioned SCU fails
    loudly instead of dropping events.  ``cost_model`` is unused: like the
    ``scu`` hardware fragments, the chain is address setup + SCU
    transactions, with no software primitive for the cost model to price.
    """
    items = len(work)
    capacity = max(16, 2 * n_cores)
    depth = max(1, min(int(depth) if depth else items, items, capacity))

    def make(cid):
        def prog(cluster, _cid):
            scu = cluster.scu
            if (
                scu is None
                or len(scu.fifos) < chain_fifo_span(n_cores)
                or scu.fifo.depth < depth
            ):
                raise RuntimeError(
                    f"SCU FIFO provisioning too small for a {n_cores}-stage "
                    f"chain at depth {depth}: need >= "
                    f"{chain_fifo_span(n_cores)} instances of depth >= "
                    f"{depth} (see repro.sync.fifo's instance allocation)"
                )
            for item in range(items):
                if _cid == 0:
                    if item >= depth:  # credit flow bounds in-flight items
                        yield Compute(1)
                        yield Scu("elw", ("fifo", _credit_addr(n_cores), "pop"))
                else:
                    yield Compute(1)
                    yield Scu("elw", ("fifo", _link_addr(n_cores, _cid - 1), "pop"))
                w = int(work[item][_cid])
                if w > 0:
                    yield Compute(w)
                yield Compute(1)
                if _cid < n_cores - 1:
                    yield Scu(
                        "write", ("fifo", _link_addr(n_cores, _cid), "push"),
                        item % 256,
                    )
                else:
                    yield Scu("write", ("fifo", _credit_addr(n_cores), "push"), 1)

        return prog

    return [make(c) for c in range(n_cores)]


F_WORK_QUEUE = F_GATHER  # the work-queue bench runs no barrier: reuse inst 1


def fifo_work_queue_programs(
    n_producers: int, n_consumers: int, items: int,
    t_produce: int, t_consume: int, state, cost_model=None,
):
    """Native event-FIFO work queue: producers block on ``push_wait`` (the
    queue itself is the backpressure -- no credit counter, no lock), and
    consumers clock-gate on ``pop`` until an item event is matched to them.
    Nobody spins and nobody serializes through a mutex: the queue ports move
    one event per cycle each, which is the whole argument for the SCU FIFO
    over lock-based work queues (Sec. 4.3)."""

    def make_producer(quota):
        def prog(cluster, cid):
            for i in range(quota):
                if t_produce > 0:
                    yield Compute(t_produce)
                yield Compute(1)  # push address setup
                yield Scu("elw", ("fifo", F_WORK_QUEUE, "push_wait"), i % 256)

        return prog

    def make_consumer(quota):
        def prog(cluster, cid):
            for _ in range(quota):
                yield Compute(1)  # pop address setup
                yield Scu("elw", ("fifo", F_WORK_QUEUE, "pop"))
                if t_consume > 0:
                    yield Compute(t_consume)

        return prog

    from repro.core.scu.programs import split_quota

    return [make_producer(q) for q in split_quota(items, n_producers)] + [
        make_consumer(q) for q in split_quota(items, n_consumers)
    ]


# ---------------------------------------------------------------------------
# Layer (b): chip-level point-to-point pipelined chain
# ---------------------------------------------------------------------------


def fifo_chip_barrier(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Point-to-point pipelined chain: gather along the ring, stream back.

    Forward phase (n-1 neighbor hops): device i adds its arrival word to the
    partial received from i-1, so after hop k device i holds the sum over
    devices [max(0, i-k) .. i] and the tail ends with the full count.
    Backward phase (n-1 hops): the total streams back down the chain
    (``maximum`` keeps it sticky; counts are non-negative and everyone else
    holds zero).  2(n-1) pairwise sends, no all-to-all -- the chip analogue
    of the simulator's per-link event queues, exact for any group size.
    """
    n = axis_size(axis)
    if n == 1:
        return arrive
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    token = arrive
    for _ in range(n - 1):
        incoming = jax.lax.ppermute(token, axis, fwd)
        # device 0 is the head of the chain: the wrap-around hop carries the
        # tail's partial, which must not re-enter the accumulation
        token = arrive + jnp.where(idx >= 1, incoming, jnp.zeros_like(incoming))
    total = jnp.where(idx == n - 1, token, jnp.zeros_like(token))
    bwd = [(i, (i - 1) % n) for i in range(n)]
    for _ in range(n - 1):
        total = jnp.maximum(total, jax.lax.ppermute(total, axis, bwd))
    return total


# ---------------------------------------------------------------------------
# Layer (c): training -- pipeline-style stage schedule over ZeRO shards
# ---------------------------------------------------------------------------

FIFO_PIPELINE_STAGES = 4  # gradient tensors are grouped into this many stages


def fifo_shape_gradients(grads, params_shape, mesh, cfg=None):
    """Staged hand-off schedule, numerically identical to ``scu``.

    Gradients reduce-scatter onto the ZeRO shards exactly like the ``scu``
    policy; the tensors are then grouped into ``FIFO_PIPELINE_STAGES``
    contiguous stages chained by optimization barriers -- each stage's
    collectives may overlap internally but hand off to the next stage in
    order, the XLA-schedule analogue of the simulator's credit-bounded
    producer-consumer chain (finer than ``tas``'s single sync point, coarser
    than ``sw``'s per-tensor chain).
    """
    shaped = zero_shape_gradients(grads, params_shape, mesh, cfg=cfg)
    leaves, treedef = jax.tree.flatten(shaped)
    if not leaves:
        return shaped
    n_stages = min(FIFO_PIPELINE_STAGES, len(leaves))
    size = -(-len(leaves) // n_stages)  # ceil division
    token = jnp.zeros((), jnp.float32)
    out = []
    for s in range(0, len(leaves), size):
        tied = jax.lax.optimization_barrier(tuple(leaves[s:s + size]) + (token,))
        out.extend(tied[:-1])
        token = tied[-1] + 0.0  # keep the stage hand-off explicit
    return jax.tree.unflatten(treedef, out)


FIFO = register_policy(PolicyDef(
    name="fifo",
    description=(
        "producer-consumer event-FIFO chains (SCU FIFO extension): clock-"
        "gated push/pop fragments + credit-bounded pipelined chains; chip: "
        "point-to-point neighbor chain collective; training: staged pipeline "
        "reduce-scatter (numerically identical to scu)"
    ),
    aliases=("FIFO",),
    make_sim_state=FifoState,
    sim_barrier=_fifo_sim_barrier,
    sim_mutex=_fifo_sim_mutex,
    chip_barrier=fifo_chip_barrier,
    shape_gradients=fifo_shape_gradients,
    opt_state_specs=zero_opt_state_specs,
    make_pipeline_programs=fifo_pipeline_programs,
    make_work_queue_programs=fifo_work_queue_programs,
    # the barrier's push/elw sequence is fixed by (cid, n) alone; the mutex
    # is NOT trace-safe: ``mutex_seeded`` is shared Python state mutated in
    # cross-core execution order, which per-core sentinel tracing cannot
    # observe -- it stays on the generator fallback
    trace_safe_barrier=True,
))
