"""A fourth discipline: log-depth tree/hierarchical barrier (MemPool-style).

Proves the ``repro.sync`` extension point: this policy is registered once
and shows up with zero per-layer special-casing in Table 1, the Fig. 5
sweep, Table 2, the chip-level wall-clock benchmark and the training path.

The discipline follows the hierarchical barriers used by large shared-L1
clusters (MemPool, arXiv 2303.17742): instead of all cores contending on
one counter (the SW/TAS pattern) or dedicated hardware (SCU), arrivals are
combined up a binary tournament tree -- O(log n) depth, and each shared
flag word is only ever written by one core and read by one core, so the
hot-spot bank traffic of the central-counter barrier disappears.

  * simulator -- software tournament barrier with sense reversal: core
    ``cid`` publishes its arrival at the first level where its base-``radix``
    digit is non-zero into its private flag word; block representatives wait
    for their ``radix - 1`` partners' subtrees, the champion (core 0)
    broadcasts the release word.  ``radix`` is a policy parameter
    (:func:`make_tree_policy`): depth is ``ceil(log_radix n)``, so radix 4
    halves the tree depth of the default radix-2 tournament on 16-core
    clusters at the cost of wider fan-in spins per level.
  * chip level -- butterfly (recursive-doubling) exchange: log2(n) pairwise
    rounds; the released count is the sum of the exchanged values (blocks
    are disjoint, so the sum is exact).  Non-power-of-two groups fall back
    to the dissemination exchange, which is also log-depth and exact.
  * training -- hierarchical bucketed reduce-scatter: numerically identical
    to the ``scu`` fine-grain discipline (XLA lowers the collectives to
    tree schedules); optimizer state is ZeRO-sharded the same way.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import axis_size

from repro.core.scu.engine import Compute, Mem, Poll, Scu
from repro.core.scu.primitives import DEFAULT_COSTS, sw_mutex_section
from repro.sync.api import PolicyDef, register_policy
from repro.sync.policies import (
    tas_chip_barrier,
    zero_opt_state_specs,
    zero_shape_gradients,
)

__all__ = [
    "TREE",
    "TREE4",
    "TREE_EW",
    "TreeBarrierState",
    "make_tree_policy",
    "tree_barrier",
    "tree_chip_barrier",
]

# TCDM layout: one arrival flag word per core + one release word, all in
# distinct words (distinct banks under word interleaving), above the
# central-barrier variables of core/scu/primitives.py.
A_TREE_RELEASE = 0x1F0
A_TREE_FLAG_BASE = 0x200


def _flag_addr(cid: int) -> int:
    return A_TREE_FLAG_BASE + 4 * cid


class TreeBarrierState:
    """Per-run tournament-barrier bookkeeping (local sense per core)."""

    def __init__(self, n_cores: int, radix: int = 2):
        if radix < 2:
            raise ValueError(f"tree barrier radix must be >= 2, got {radix}")
        self.n_cores = n_cores
        self.radix = radix
        self.local_sense = [0] * n_cores


def tree_barrier(
    cl, cid: int, st: TreeBarrierState, cm=DEFAULT_COSTS, idle_wait: bool = False
):
    """Software radix-k tournament barrier: log_k-depth combining, sense
    reversal.

    Each core loses at exactly one level (the first where its base-``radix``
    digit is non-zero), so a single flag word per core suffices; flags carry
    the sense value, which makes the barrier reusable back-to-back without
    resets.  ``radix=2`` reproduces the classic binary tournament op-for-op.

    ``idle_wait`` selects the release broadcast: the default spins on the
    shared release word; the idle-wait variant instead clock-gates every
    loser on an SCU notifier event and the champion releases the whole
    group with one targeted notifier trigger -- the release-word bank
    traffic disappears and losers sleep instead of polling (the tree
    analogue of the paper's TAS idle-wait discipline).  Safe back-to-back:
    each loser's wake consumes only its own buffered event bit, and the
    champion cannot re-trigger before every loser has re-published its
    next-round arrival flag (the elw is on each loser's critical path).
    """
    n = st.n_cores
    radix = st.radix
    sense = st.local_sense[cid] ^ 1
    st.local_sense[cid] = sense
    yield Compute(cm.call + cm.sense_setup)
    stride = 1
    is_champion = True
    while stride < n:
        if (cid // stride) % radix:
            # loser at this level: publish the subtree's arrival, then wait
            # for the champion's release broadcast
            yield Compute(1)  # flag address computation
            yield Mem("sw", _flag_addr(cid), sense)
            is_champion = False
            break
        # block representative: wait for every partner subtree in the block
        for m in range(1, radix):
            partner = cid + m * stride
            if partner >= n:
                break
            yield Poll(
                "lw", _flag_addr(partner), until=sense,
                hit_cycles=1 + cm.load_use,
                miss_cycles=1 + cm.load_use + cm.branch_taken,
                hit_instr=1, miss_instr=2,
            )
        stride *= radix
    if is_champion:
        if idle_wait:
            # one targeted notifier trigger wakes every loser (core 0 is
            # excluded: its own stale event bit would leak into the next
            # barrier's elw)
            yield Scu("write", ("notifier", 0, "trigger"), ((1 << n) - 1) & ~1)
        else:
            # core 0 saw every subtree arrive: flip the shared release word
            yield Mem("sw", A_TREE_RELEASE, sense)
    elif idle_wait:
        # clock-gated wait for the champion's notifier broadcast
        yield Compute(cm.mask_setup)
        yield Scu("elw", ("notifier", 0, "wait"))
    else:
        yield Poll(
            "lw", A_TREE_RELEASE, until=sense,
            hit_cycles=1 + cm.load_use,
            miss_cycles=1 + cm.load_use + cm.branch_taken,
            hit_instr=1, miss_instr=2,
        )
    yield Compute(cm.ret)


def _tree_sim_mutex(cluster, cid, t_crit, state, cost_model=None):
    # The tree discipline restructures *barriers*; critical sections keep the
    # plain spin-lock (a combining tree has no analogue for mutexes).
    yield from sw_mutex_section(cluster, cid, t_crit, cost_model or DEFAULT_COSTS)


def make_tree_policy(
    radix: int = 2, name: Optional[str] = None, idle_wait: bool = False
) -> PolicyDef:
    """Build a tournament-barrier policy with the given ``radix``.

    ``radix=2`` is the registered builtin ``tree``; higher radices trade
    per-level fan-in for depth (``ceil(log_radix n)`` levels -- radix 4
    halves the depth on 16-core clusters).  ``idle_wait=True`` replaces the
    release-word spin with a clock-gated SCU-notifier wait (the builtin
    ``tree_ew``).  The returned policy is not registered; call
    :func:`repro.sync.register_policy` to add e.g. a ``tree4`` row to every
    benchmark.
    """
    name = name or ("tree" if radix == 2 else f"tree{radix}")

    def _state(n_cores: int) -> TreeBarrierState:
        return TreeBarrierState(n_cores, radix=radix)

    def _sim_barrier(cluster, cid, state, cost_model=None):
        yield from tree_barrier(
            cluster, cid, state, cost_model or DEFAULT_COSTS, idle_wait=idle_wait
        )

    release = "SCU-notifier idle-wait release" if idle_wait else "release-word spin"
    return PolicyDef(
        name=name,
        description=(
            f"log-depth hierarchical barrier (MemPool-style), radix {radix}, "
            f"{release}: simulator tournament tree, chip-level butterfly "
            "exchange, training: hierarchical bucketed reduce-scatter "
            "(numerically identical to scu)"
        ),
        aliases=(name.upper(),),
        make_sim_state=_state,
        sim_barrier=_sim_barrier,
        sim_mutex=_tree_sim_mutex,
        # the chip-level exchange stays the radix-2 butterfly: XLA owns the
        # physical schedule there, the radix only shapes the simulator tree
        chip_barrier=tree_chip_barrier,
        shape_gradients=zero_shape_gradients,
        opt_state_specs=zero_opt_state_specs,
        # the tournament's control flow is fixed by (cid, n, radix): every
        # poll/elw wait is a linear wait on a statically-known address, so
        # per-core sentinel tracing is sound (the mutex is sw_mutex_section,
        # also value-independent)
        trace_safe_barrier=True,
        trace_safe_mutex=True,
    )


def tree_chip_barrier(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Butterfly exchange: log2(n) pairwise rounds, partner = idx XOR 2**k.

    At round k every device holds the sum of its 2**k-aligned block; the
    XOR partner holds the disjoint sibling block, so adding the exchanged
    value is exact -- the count derives entirely from the exchanged values.
    """
    n = axis_size(axis)
    if n & (n - 1):
        # butterfly pairing needs a power-of-two group; dissemination is the
        # log-depth exchange that stays exact for any group size
        return tas_chip_barrier(arrive, axis)
    total = arrive
    shift = 1
    while shift < n:
        perm = [(i, i ^ shift) for i in range(n)]
        total = total + jax.lax.ppermute(total, axis, perm)
        shift *= 2
    return total


TREE = register_policy(make_tree_policy(radix=2, name="tree"))
# Radix-4 tournament: half the tree depth on 16-core clusters, registered as
# a builtin so every benchmark (Table 1, Fig. 5, scaling sweeps, Table 2,
# chip-level, chain) carries a dedicated ``tree4`` row.
TREE4 = register_policy(make_tree_policy(radix=4))
# Idle-wait release variant: losers clock-gate on an SCU notifier event
# instead of spinning on the release word -- the release broadcast costs one
# targeted trigger and zero TCDM polls.
TREE_EW = register_policy(make_tree_policy(radix=2, name="tree_ew", idle_wait=True))
