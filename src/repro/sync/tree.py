"""A fourth discipline: log-depth tree/hierarchical barrier (MemPool-style).

Proves the ``repro.sync`` extension point: this policy is registered once
and shows up with zero per-layer special-casing in Table 1, the Fig. 5
sweep, Table 2, the chip-level wall-clock benchmark and the training path.

The discipline follows the hierarchical barriers used by large shared-L1
clusters (MemPool, arXiv 2303.17742): instead of all cores contending on
one counter (the SW/TAS pattern) or dedicated hardware (SCU), arrivals are
combined up a binary tournament tree -- O(log n) depth, and each shared
flag word is only ever written by one core and read by one core, so the
hot-spot bank traffic of the central-counter barrier disappears.

  * simulator -- software tournament barrier with sense reversal: core
    ``cid`` publishes its arrival at round ``r = lowest set bit of cid``
    into its private flag word; winners wait for their partner's subtree,
    the champion (core 0) broadcasts the release word.
  * chip level -- butterfly (recursive-doubling) exchange: log2(n) pairwise
    rounds; the released count is the sum of the exchanged values (blocks
    are disjoint, so the sum is exact).  Non-power-of-two groups fall back
    to the dissemination exchange, which is also log-depth and exact.
  * training -- hierarchical bucketed reduce-scatter: numerically identical
    to the ``scu`` fine-grain discipline (XLA lowers the collectives to
    tree schedules); optimizer state is ZeRO-sharded the same way.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size

from repro.core.scu.engine import Compute, Mem
from repro.core.scu.primitives import DEFAULT_COSTS, sw_mutex_section
from repro.sync.api import PolicyDef, register_policy
from repro.sync.policies import (
    tas_chip_barrier,
    zero_opt_state_specs,
    zero_shape_gradients,
)

__all__ = ["TREE", "TreeBarrierState", "tree_barrier", "tree_chip_barrier"]

# TCDM layout: one arrival flag word per core + one release word, all in
# distinct words (distinct banks under word interleaving), above the
# central-barrier variables of core/scu/primitives.py.
A_TREE_RELEASE = 0x1F0
A_TREE_FLAG_BASE = 0x200


def _flag_addr(cid: int) -> int:
    return A_TREE_FLAG_BASE + 4 * cid


class TreeBarrierState:
    """Per-run tournament-barrier bookkeeping (local sense per core)."""

    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        self.local_sense = [0] * n_cores


def tree_barrier(cl, cid: int, st: TreeBarrierState, cm=DEFAULT_COSTS):
    """Software tournament barrier: log-depth combining, sense reversal.

    Each core loses at exactly one level (the lowest set bit of its id), so
    a single flag word per core suffices; flags carry the sense value, which
    makes the barrier reusable back-to-back without resets.
    """
    n = st.n_cores
    sense = st.local_sense[cid] ^ 1
    st.local_sense[cid] = sense
    yield Compute(cm.call + cm.sense_setup)
    level = 0
    is_champion = True
    while (1 << level) < n:
        if cid & (1 << level):
            # loser at this level: publish the subtree's arrival, then wait
            # for the champion's release broadcast
            yield Compute(1)  # flag address computation
            yield Mem("sw", _flag_addr(cid), sense)
            is_champion = False
            break
        partner = cid | (1 << level)
        if partner < n:
            # winner: wait for the subtree rooted at the partner
            while True:
                v = yield Mem("lw", _flag_addr(partner))
                yield Compute(1 + cm.load_use)
                if v == sense:
                    break
                yield Compute(cm.branch_taken)
        level += 1
    if is_champion:
        # core 0 saw every subtree arrive: flip the shared release word
        yield Mem("sw", A_TREE_RELEASE, sense)
    else:
        while True:
            s = yield Mem("lw", A_TREE_RELEASE)
            yield Compute(1 + cm.load_use)
            if s == sense:
                break
            yield Compute(cm.branch_taken)
    yield Compute(cm.ret)


def _tree_sim_barrier(cluster, cid, state, cost_model=None):
    yield from tree_barrier(cluster, cid, state, cost_model or DEFAULT_COSTS)


def _tree_sim_mutex(cluster, cid, t_crit, state, cost_model=None):
    # The tree discipline restructures *barriers*; critical sections keep the
    # plain spin-lock (a combining tree has no analogue for mutexes).
    yield from sw_mutex_section(cluster, cid, t_crit, cost_model or DEFAULT_COSTS)


def tree_chip_barrier(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Butterfly exchange: log2(n) pairwise rounds, partner = idx XOR 2**k.

    At round k every device holds the sum of its 2**k-aligned block; the
    XOR partner holds the disjoint sibling block, so adding the exchanged
    value is exact -- the count derives entirely from the exchanged values.
    """
    n = axis_size(axis)
    if n & (n - 1):
        # butterfly pairing needs a power-of-two group; dissemination is the
        # log-depth exchange that stays exact for any group size
        return tas_chip_barrier(arrive, axis)
    total = arrive
    shift = 1
    while shift < n:
        perm = [(i, i ^ shift) for i in range(n)]
        total = total + jax.lax.ppermute(total, axis, perm)
        shift *= 2
    return total


TREE = register_policy(PolicyDef(
    name="tree",
    description=(
        "log-depth hierarchical barrier (MemPool-style): simulator tournament "
        "tree, chip-level butterfly exchange, training: hierarchical bucketed "
        "reduce-scatter (numerically identical to scu)"
    ),
    aliases=("TREE",),
    make_sim_state=TreeBarrierState,
    sim_barrier=_tree_sim_barrier,
    sim_mutex=_tree_sim_mutex,
    chip_barrier=tree_chip_barrier,
    shape_gradients=zero_shape_gradients,
    opt_state_specs=zero_opt_state_specs,
))
