"""The ``repro.sync`` policy API: one registry for synchronization disciplines.

The paper's core move is comparing one synchronization *semantics* under
several *implementations* (Sec. 6.1: SW spin-lock, TAS idle-wait, hardware
SCU).  This repo exercises that comparison at three independent layers:

  (a) the cycle-accurate cluster simulator (``repro.core.scu``) -- barrier /
      mutex generator *fragments* made of ``Compute``/``Mem``/``Scu`` ops,
  (b) chip-level collectives (``repro.kernels.scu_barrier``) -- the barrier
      discipline expressed with real JAX collectives inside ``shard_map``,
  (c) the training schedule (``repro.train.step``) -- how gradients are
      synchronized and how the optimizer state is sharded.

A :class:`SyncPolicy` carries all three layers for one discipline, so a new
discipline (a hierarchical tree barrier, a producer-consumer FIFO chain, ...)
is registered *once* and is instantly benchmarkable everywhere: Table 1,
Fig. 5, Table 2, the chip-level wall-clock sweep, the dry-run, and training.

Layer hook signatures (see :class:`SyncPolicy`):

  * ``make_sim_state(n_cores)``            -> per-run shared simulator state
  * ``sim_barrier(cluster, cid, state, cost_model)``      -> op generator
  * ``sim_mutex(cluster, cid, t_crit, state, cost_model)`` -> op generator
  * ``chip_barrier(arrive, axis)``         -> arrival count (jnp array)
  * ``shape_gradients(grads, params_shape, mesh, cfg)``   -> shaped grads
  * ``opt_state_specs(params_shape, mesh, cfg)``          -> spec dict

All disciplines must be *numerically identical* (same released count, same
loss, same update); they may only differ in schedule / collective structure
-- exactly like the paper's variants.  ``tests/test_sync_api.py`` enforces
this cross-layer parity for every registered policy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

__all__ = [
    "SyncPolicy",
    "PolicyDef",
    "LAYER_HOOKS",
    "register_policy",
    "unregister_policy",
    "get_policy",
    "available_policies",
    "canonical_name",
]

# Every registered policy must provide all of these (cross-layer parity).
LAYER_HOOKS: Tuple[str, ...] = (
    "make_sim_state",
    "sim_barrier",
    "sim_mutex",
    "chip_barrier",
    "shape_gradients",
    "opt_state_specs",
)


@runtime_checkable
class SyncPolicy(Protocol):
    """Structural type of a synchronization policy (see module docstring)."""

    name: str
    description: str

    def make_sim_state(self, n_cores: int) -> Any: ...

    def sim_barrier(self, cluster, cid: int, state, cost_model=None): ...

    def sim_mutex(self, cluster, cid: int, t_crit: int, state, cost_model=None): ...

    def chip_barrier(self, arrive, axis: str): ...

    def shape_gradients(self, grads, params_shape, mesh, cfg=None): ...

    def opt_state_specs(self, params_shape, mesh, cfg=None): ...


@dataclasses.dataclass(frozen=True)
class PolicyDef:
    """Concrete :class:`SyncPolicy`: one record, all three layers.

    The hooks are plain callables (not bound methods), so their signatures
    are exactly the layer-hook signatures above without ``self``.
    """

    name: str
    description: str
    make_sim_state: Callable[[int], Any]
    sim_barrier: Callable[..., Any]
    sim_mutex: Callable[..., Any]
    chip_barrier: Callable[..., Any]
    shape_gradients: Callable[..., Any]
    opt_state_specs: Callable[..., Any]
    aliases: Tuple[str, ...] = ()  # e.g. the legacy simulator spelling "SCU"
    # Optional simulator hook: native pipelined-chain support.  Signature
    # ``(n_cores, work, state, cost_model, depth) -> List[Program]`` where
    # ``work[item][stage]`` is the Compute-cycle cost of ``item`` at stage
    # ``stage`` (one stage per core).  Policies without it fall back to the
    # barrier-synchronous pipeline emulation in ``core/scu/programs.py`` --
    # the baseline the paper's FIFO extension exists to beat.
    make_pipeline_programs: Optional[Callable[..., Any]] = None
    # Optional simulator hook: native multi-producer work-queue support.
    # Signature ``(n_producers, n_consumers, items, t_produce, t_consume,
    # state, cost_model) -> List[Program]`` (producers first, then
    # consumers).  Policies without it fall back to the mutex-protected
    # shared-queue emulation in ``core/scu/programs.py``.
    make_work_queue_programs: Optional[Callable[..., Any]] = None
    # --- compiled-trace lowering hooks (repro.core.scu.trace) -------------
    # ``trace_barrier(tb, cluster, cid, state, cost_model)`` /
    # ``trace_mutex(tb, cluster, cid, t_crit, state, cost_model)`` emit ONE
    # iteration of the primitive as static trace rows into a
    # ``TraceBuilder`` -- needed when the generator's op stream depends on
    # runtime values (the sense-reversal count check, the TAS re-test), so
    # the value-dependent control flow must be expressed as explicit BR/JMP
    # rows.  ``trace_safe_barrier``/``trace_safe_mutex`` declare the
    # generator fragment free of *cross-core-order-dependent shared Python
    # state*, which makes per-core sentinel tracing sound (value-dependence
    # is proven mechanically by the sentinel; order-dependence -- e.g. the
    # fifo mutex's seed-once token -- cannot be, hence the explicit flag).
    # With neither an emitter nor a safety flag, lowering falls back to the
    # generator path: always correct, never collapsed.
    trace_barrier: Optional[Callable[..., Any]] = None
    trace_mutex: Optional[Callable[..., Any]] = None
    trace_safe_barrier: bool = False
    trace_safe_mutex: bool = False


# name (and alias) -> policy, in registration order (order is meaningful:
# benchmarks print columns in it, with the paper's triad first).
_REGISTRY: Dict[str, SyncPolicy] = {}
_ALIASES: Dict[str, str] = {}


def register_policy(policy: SyncPolicy, *, overwrite: bool = False) -> SyncPolicy:
    """Register ``policy`` under its (case-insensitive) name and aliases.

    Validates cross-layer completeness at registration time: a policy missing
    any layer hook would otherwise fail deep inside a benchmark or a jitted
    train step, far from the actual mistake.
    """
    missing = [
        h for h in LAYER_HOOKS
        if not callable(getattr(policy, h, None))
    ]
    if missing:
        raise TypeError(
            f"policy {getattr(policy, 'name', policy)!r} does not implement "
            f"the full SyncPolicy protocol; missing/uncallable hooks: {missing}"
        )
    name = policy.name.lower()
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"sync policy {name!r} is already registered")
    aliases = tuple(a.lower() for a in getattr(policy, "aliases", ()) or ())
    for alias in aliases:
        # an alias may never capture another policy's name or alias --
        # resolution would silently hijack every existing call site
        if alias != name and (
            alias in _REGISTRY or _ALIASES.get(alias, name) != name
        ):
            raise ValueError(
                f"alias {alias!r} of policy {name!r} collides with an "
                f"already-registered policy name or alias"
            )
    if overwrite:
        for alias, target in list(_ALIASES.items()):
            if target == name:  # drop the replaced policy's stale aliases
                del _ALIASES[alias]
    _REGISTRY[name] = policy
    for alias in aliases:
        _ALIASES[alias] = name
    return policy


def unregister_policy(name: str) -> None:
    """Remove a policy and its aliases.

    Restoration is the caller's responsibility: ``repro.sync`` stays cached
    in ``sys.modules``, so the builtin registrations do NOT re-run -- hold on
    to the policy object and ``register_policy`` it back (see
    ``tests/test_sync_api.py`` for the try/finally pattern).
    """
    cname = canonical_name(name)
    del _REGISTRY[cname]
    for alias, target in list(_ALIASES.items()):
        if target == cname:
            del _ALIASES[alias]


def canonical_name(name: str) -> str:
    """Resolve ``name`` (any case, alias allowed) to the registered name.

    Registered names take precedence over aliases, so an alias can never
    shadow a policy's own name.
    """
    key = str(name).lower()
    if key not in _REGISTRY:
        key = _ALIASES.get(key, key)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown sync policy {name!r}; available policies: "
            f"{', '.join(available_policies())}"
        )
    return key


def get_policy(name: str) -> SyncPolicy:
    """Resolve a policy by name (case-insensitive, legacy aliases accepted)."""
    return _REGISTRY[canonical_name(name)]


def available_policies() -> Tuple[str, ...]:
    """Registered policy names, in registration order (paper triad first)."""
    return tuple(_REGISTRY)
