"""``repro.sync`` -- the unified synchronization-policy API.

    from repro.sync import get_policy, register_policy, available_policies

    policy = get_policy("scu")            # case-insensitive; "SCU" works too
    available_policies()         # ('scu', 'tas', 'sw', 'tree', 'tree4', 'fifo')

One :class:`SyncPolicy` carries the discipline's implementation at every
layer of the repo: simulator fragments, chip-level collectives, and
training-schedule hooks.  See :mod:`repro.sync.api` for the protocol and
:mod:`repro.sync.tree` for a worked example of registering a new discipline.
"""

from repro.sync.api import (
    LAYER_HOOKS,
    PolicyDef,
    SyncPolicy,
    available_policies,
    canonical_name,
    get_policy,
    register_policy,
    unregister_policy,
)

# Importing the implementation modules registers the builtin policies
# (the paper's triad first, then the tree/tree4 tournaments, then the
# producer-consumer event-FIFO discipline).
from repro.sync import policies as _policies  # noqa: F401
from repro.sync import tree as _tree  # noqa: F401
from repro.sync import fifo as _fifo  # noqa: F401
from repro.sync.tree import make_tree_policy
from repro.sync.fifo import fifo_pipeline_programs

__all__ = [
    "LAYER_HOOKS",
    "PolicyDef",
    "SyncPolicy",
    "available_policies",
    "canonical_name",
    "fifo_pipeline_programs",
    "get_policy",
    "make_tree_policy",
    "register_policy",
    "unregister_policy",
]
