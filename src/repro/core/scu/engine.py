"""Cycle-accurate discrete-event engine for the shared-L1 multiprocessor cluster.

This is the Tier-1, paper-faithful model of the system evaluated in

    Glaser et al., "Energy-Efficient Hardware-Accelerated Synchronization for
    Shared-L1-Memory Multiprocessor Clusters" (2020).

The cluster consists of

  * ``n_cores`` in-order single-issue PEs (1 op/cycle when not stalled),
  * a word-interleaved multi-banked TCDM (banking factor 2 by default) behind a
    single-cycle logarithmic interconnect (LINT) with per-bank round-robin
    arbitration and native 3-cycle test-and-set (TAS) transactions,
  * the SCU: per-core base units (32 event lines, event buffer, event/irq
    masks, active/sleep/irq FSM, clock-enable control) reached over private
    single-cycle core<->SCU links, plus shared extensions (notifier, barrier,
    mutex, event FIFO) -- see :mod:`repro.core.scu.scu_unit` and
    :mod:`repro.core.scu.extensions`.

Programs are Python generators that yield micro-ops (:class:`Compute`,
:class:`Mem`, :class:`Scu`, :class:`Poll`); the engine resolves arbitration,
SCU event generation, sleep/wake-up sequencing and clock gating exactly as
described in Sec. 4/5 and Fig. 4 of the paper.

Accounting distinguishes *active* core cycles (clock enabled) from *gated*
cycles -- the quantity behind the paper's energy results.

Two execution modes produce bit-exact identical :class:`ClusterStats`:

``mode="lockstep"``
    The reference model: :meth:`Cluster.step` advances the whole cluster one
    clock cycle at a time with plain per-core Python loops, evaluating every
    phase every cycle.  Deliberately unvectorized -- this is the readable,
    obviously-correct implementation every fast path is cross-checked
    against.

``mode="fastforward"`` (default)
    The event-driven engine, organized as **three resolution tiers** (each
    cycle is resolved by the cheapest tier that can prove it exact):

    1. *Quiescent spans*: :meth:`Cluster.next_event_bound` computes a
       provably-safe number of cycles during which nothing observable can
       happen -- every core is burning a :class:`Compute` span, clock-gated
       asleep with no buffered wake event, or inside its wake countdown, and
       no SCU extension comparator can fire without a new core transaction
       (:meth:`repro.core.scu.scu_unit.SCU.next_event_bound`).  The engine
       jumps the clock by the whole span with O(n_cores) span-based stats.
    2. *Spin-phase batch resolution* (:meth:`Cluster._resolve_spin_phase`):
       when every awake core sits inside a deterministic :class:`Poll` loop
       (fixed periodic bank traffic) while the rest are asleep or counting
       down, and no SCU comparator is armed, the cluster's evolution until
       the next spectator deadline is fully engine-determined.  The
       resolver replays exactly the per-bank round-robin outcomes with
       per-*grant* instead of per-cycle work -- queue-wait spans, retry
       shadows and the implied stall/conflict accounting settle in closed
       form per segment, and empty cycles between re-polls are skipped
       outright.  Long phases additionally run a period detector
       (configuration hashing over the relative spinner state, the involved
       round-robin pointers and the polled TCDM words): a repeat proves
       periodicity and the remaining horizon collapses into one multiply of
       the per-period stat deltas -- the closed form for "one core computes
       for 10^5 cycles while everyone else spins".
    3. *Full steps*: any cycle in which a generator advance, SCU grant, or
       comparator could act runs through a full cluster step.  On clusters
       with ``n_cores >= VEC_MIN_CORES`` this step is the **vectorized
       structure-of-arrays core** (:class:`_VecState`): per-core scheduler
       state and stat counters live in numpy arrays and the per-cycle phases
       (countdowns, TCDM round-robin arbitration with per-bank winner
       election via one lexsort, elw grant scans against the SCU's event
       vectors, accounting) are numpy kernels over all cores at once.
       Smaller clusters use the same scalar step as lockstep mode (numpy
       overhead would dominate at 8 cores).

    Parity with lockstep is bit-exact and enforced by golden values plus
    randomized cross-checks up to 256 cores in ``tests/test_scu_simulator.py``.

Sweeps over many independent configurations additionally have **fleet
mode**: :func:`simulate_fleet` stacks N clusters onto the same
structure-of-arrays core along a flattened ``(config, core)`` axis --
per-config segments partition the TCDM arbitration, SCU registers and the
``next_event_bound()`` reduction, quiescent jumps become per-config
segment-min spans, and full steps batch across every config at once (which
is what makes 8-core configs vectorizable for the first time).  Results are
bit-exact per config against one-at-a-time runs; see :class:`_Fleet`.
"""

from __future__ import annotations

import bisect
import dataclasses
import enum
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple

import numpy as np

from .faults import DeadlockError, FaultPlan, SimTimeout, build_wait_graph

__all__ = [
    "Compute",
    "Mem",
    "Scu",
    "Poll",
    "CoreState",
    "CoreStats",
    "ClusterStats",
    "Cluster",
    "FleetConfig",
    "Program",
    "simulate_fleet",
    "SlotFleet",
]


# ---------------------------------------------------------------------------
# Micro-ops yielded by core programs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Compute:
    """``cycles`` of core-local work (ALU/regfile only, no memory traffic)."""

    cycles: int


@dataclasses.dataclass
class Mem:
    """A TCDM transaction through the LINT.

    kind:
      ``lw``  -- load word (single cycle when granted; contention stalls)
      ``sw``  -- store word
      ``tas`` -- atomic test-and-set: returns current value, writes -1.
                 Occupies the bank for :attr:`Cluster.TAS_CYCLES` cycles
                 ("TAS transactions take just three cycles", Sec. 4.1).
    """

    kind: str
    addr: int
    data: int = 0


@dataclasses.dataclass
class Poll:
    """A declarative spin/poll loop on one TCDM word, resolved engine-native.

    Stands in -- cycle- and stats-exact -- for the classic expanded loop::

        while True:
            v = yield Mem(kind, addr)     # "lw" poll or "tas" lock attempt
            yield Compute(hit_cycles)     # value check after the load
            if v == until:
                break
            yield Compute(miss_cycles - hit_cycles)   # branch back, retry

    The engine re-polls without ever resuming the generator on a miss: each
    granted access returning ``v != until`` burns ``miss_cycles`` ACTIVE
    cycles (plus the TAS busy time for ``kind="tas"``) and re-enters the
    bank queue; the access returning ``until`` burns ``hit_cycles`` and then
    resumes the program with that value.  Instruction accounting mirrors the
    expanded loop: ``miss_instr`` instructions per retry round on top of the
    re-issued load, ``hit_instr`` on the exit path.

    Declaring the spin (instead of expanding it) is what enables the
    fast-forward engine's *spin-phase batch resolution*: a pending ``Poll``
    is a complete description of the core's behaviour until the polled word
    changes, with no generator state hidden from the scheduler.
    """

    kind: str
    addr: int
    until: int
    hit_cycles: int
    miss_cycles: int
    hit_instr: int = 1
    miss_instr: int = 2


@dataclasses.dataclass
class Scu:
    """A transaction on the private core<->SCU link (single cycle, Sec. 4.4).

    kind:
      ``elw``   -- event-load-word (Sec. 5): read `addr` in the aliased SCU
                   space; the SCU withholds the grant until a masked-in event
                   is buffered, clock-gating the core meanwhile.  The read
                   response carries extension-specific data.
      ``read``  -- plain (non-blocking) read of an SCU register.
      ``write`` -- plain write (mutex unlock, notifier trigger, mask setup...).
    """

    kind: str
    addr: Any
    data: int = 0


Program = Callable[["Cluster", int], Generator]


class CoreState(enum.Enum):
    ACTIVE = 0  # clock enabled, executing / issuing
    STALL_MEM = 1  # clock enabled, waiting for a TCDM grant
    STALL_SCU = 2  # clock enabled, elw issued, pre-gate window (Fig. 4 left)
    SLEEP = 3  # clock gated by the SCU
    WAKING = 4  # event seen; grant/response sequencing (Fig. 4 right)
    DONE = 5


# integer state codes for the structure-of-arrays engine (== enum values)
_ACTIVE = CoreState.ACTIVE.value
_STALL_MEM = CoreState.STALL_MEM.value
_STALL_SCU = CoreState.STALL_SCU.value
_SLEEP = CoreState.SLEEP.value
_WAKING = CoreState.WAKING.value
_DONE = CoreState.DONE.value

_STATE_BY_CODE = {s.value: s for s in CoreState}


@dataclasses.dataclass
class CoreStats:
    active_cycles: int = 0  # clock enabled (= comp + wait)
    comp_cycles: int = 0  # clocked and executing/issuing (full core power)
    wait_cycles: int = 0  # clocked but pipeline held (stall/grant/wake)
    gated_cycles: int = 0  # clock gated by the SCU
    stall_cycles: int = 0  # subset of wait: stalled on LINT contention
    instructions: int = 0
    tcdm_accesses: int = 0
    tas_accesses: int = 0
    scu_accesses: int = 0
    finished_at: Optional[int] = None


@dataclasses.dataclass
class ClusterStats:
    cycles: int = 0
    cores: List[CoreStats] = dataclasses.field(default_factory=list)
    bank_conflicts: int = 0
    scu_events: int = 0

    # -- aggregates ---------------------------------------------------------
    @property
    def total_active(self) -> int:
        return sum(c.active_cycles for c in self.cores)

    @property
    def total_comp(self) -> int:
        return sum(c.comp_cycles for c in self.cores)

    @property
    def total_wait(self) -> int:
        return sum(c.wait_cycles for c in self.cores)

    @property
    def total_gated(self) -> int:
        return sum(c.gated_cycles for c in self.cores)

    @property
    def total_tcdm(self) -> int:
        return sum(c.tcdm_accesses for c in self.cores)

    @property
    def total_scu(self) -> int:
        return sum(c.scu_accesses for c in self.cores)


_COUNTERS = (
    "active_cycles",
    "comp_cycles",
    "wait_cycles",
    "gated_cycles",
    "stall_cycles",
    "instructions",
    "tcdm_accesses",
    "tas_accesses",
    "scu_accesses",
)
# row indices into _VecState.counter_block
(
    _C_ACTIVE,
    _C_COMP,
    _C_WAIT,
    _C_GATED,
    _C_STALL,
    _C_INSTR,
    _C_TCDM,
    _C_TAS,
    _C_SCU,
) = range(len(_COUNTERS))

# Phase-5 accounting as a lookup table: column = CoreState code, row = one
# of the first five counters (active/comp/wait/gated/stall); one fancy
# gather + add replaces the per-counter boolean mask arithmetic in the
# vectorized step kernels.  DONE contributes zeros (no clock, no counters).
_ACCT_INC = np.zeros((5, len(CoreState)), dtype=np.int64)
_ACCT_INC[_C_ACTIVE, [_ACTIVE, _STALL_MEM, _STALL_SCU, _WAKING]] = 1
_ACCT_INC[_C_COMP, _ACTIVE] = 1
_ACCT_INC[_C_WAIT, [_STALL_MEM, _STALL_SCU, _WAKING]] = 1
_ACCT_INC[_C_GATED, _SLEEP] = 1
_ACCT_INC[_C_STALL, _STALL_MEM] = 1


class _Core:
    """Execution context of one PE, including its scheduler state.

    The countdown fields (``busy``, ``wake_countdown``, ``sleep_entry``) are
    the *explicit scheduler state* of the core: between steps they fully
    determine how many cycles the core can advance without interacting with
    any shared resource.  :meth:`quiescent_bound` derives that number and
    :meth:`fast_forward` applies a whole span of it at once (span-based
    accounting); the lockstep path consumes the same state one cycle at a
    time through :meth:`Cluster._issue`.

    Stat counters are plain attributes (structure-of-scalars); the
    :attr:`stats` property materializes a :class:`CoreStats` snapshot on
    demand, so programs sampling their own counters mid-run always see
    current values in either engine mode.
    """

    __slots__ = (
        "cid",
        "gen",
        "started",
        "state",
        "busy",
        "pending",
        "resume_value",
        "wake_countdown",
        "sleep_entry",
        "elw_issued",
        "finished_at",
    ) + _COUNTERS

    def __init__(self, cid: int, gen: Generator):
        self.cid = cid
        self.gen = gen
        self.started = False
        self.state = CoreState.ACTIVE
        self.busy = 0  # remaining Compute (or Poll grant-shadow) cycles
        self.pending: Optional[Any] = None  # outstanding Mem/Poll/Scu op
        self.resume_value: int = 0  # data returned to the generator
        self.wake_countdown = 0
        self.sleep_entry = 0  # busy-release window before clock gating
        self.elw_issued = False  # extension trigger-once guard (Sec. 5)
        self.finished_at: Optional[int] = None
        for name in _COUNTERS:
            setattr(self, name, 0)

    @property
    def stats(self) -> CoreStats:
        return CoreStats(
            finished_at=self.finished_at,
            **{name: getattr(self, name) for name in _COUNTERS},
        )

    # ------------------------------------------------------------ scheduler
    def quiescent_bound(self, scu) -> Optional[int]:
        """Cycles this core is guaranteed to spend without any observable
        interaction, or ``None`` for "indefinitely many" (needs an external
        stimulus to make progress).  0 means the core must be stepped.

        Safe bounds per state (mirrors one lockstep :meth:`Cluster._issue`):

        * ``ACTIVE`` with ``busy=k>0`` -- k pure countdown cycles; the
          generator advance (or :class:`Poll` re-issue) happens on the
          following step.
        * ``WAKING`` with ``wake_countdown=w>1`` -- w-1 countdown cycles; the
          step where the countdown reaches 0 resumes the generator.
        * ``SLEEP`` -- indefinite, unless the waited-on event is already
          buffered (then the phase-4 poll would grant *this* cycle).
        * everything else (``STALL_MEM`` arbitration, ``STALL_SCU`` grant /
          sleep-entry windows, ``busy==0`` advances) -- 0: these transients
          touch shared resources and must run through the full step.
        """
        state = self.state
        if state is CoreState.DONE:
            return None
        if state is CoreState.ACTIVE:
            return self.busy if self.busy > 0 else 0
        if state is CoreState.WAKING:
            return self.wake_countdown - 1 if self.wake_countdown > 1 else 0
        if state is CoreState.SLEEP:
            if self.pending is None or scu is None:  # pragma: no cover
                return 0
            return 0 if scu.elw_would_grant(self.cid, self.pending.addr) else None
        return 0

    def fast_forward(self, span: int) -> None:
        """Advance this core ``span`` quiescent cycles in one O(1) update.

        Only the three states with a positive/indefinite quiescent bound can
        appear here; the stats written are exactly what ``span`` iterations
        of the lockstep phase-5 accounting would have written.
        """
        state = self.state
        if state is CoreState.ACTIVE:
            self.busy -= span
            self.active_cycles += span
            self.comp_cycles += span
        elif state is CoreState.WAKING:
            self.wake_countdown -= span
            self.active_cycles += span
            self.wait_cycles += span
        elif state is CoreState.SLEEP:
            self.gated_cycles += span
        # DONE: no clock, no accounting


class _VecState:
    """Structure-of-arrays mirror-free core state for the vectorized engine.

    Owns the scheduler state and stat counters of every core as numpy
    arrays; :class:`_VecCore` objects are thin per-core views so the shared
    scalar helpers (:meth:`Cluster._advance`, SCU servicing) and programs
    reading ``cluster.cores[cid]`` keep working unchanged.
    """

    __slots__ = (
        "n",
        "state",
        "busy",
        "wake",
        "sleep_entry",
        "pend_bank",
        "has_poll",
        "elw",
        "counter_block",
        "counters",
        "finished_at",
    )

    def __init__(self, n: int):
        self.n = n
        self.state = np.zeros(n, dtype=np.int64)  # CoreState codes
        self.busy = np.zeros(n, dtype=np.int64)
        self.wake = np.zeros(n, dtype=np.int64)
        self.sleep_entry = np.zeros(n, dtype=np.int64)
        self.pend_bank = np.full(n, -1, dtype=np.int64)  # bank of pending Mem/Poll
        self.has_poll = np.zeros(n, dtype=bool)  # pending op is a Poll
        self.elw = np.zeros(n, dtype=bool)  # elw_issued
        # one (n_counters, n_cores) block so snapshots/deltas are single
        # fancy-index operations; the dict maps names to row views
        self.counter_block = np.zeros((len(_COUNTERS), n), dtype=np.int64)
        self.counters = {
            name: self.counter_block[i] for i, name in enumerate(_COUNTERS)
        }
        self.finished_at: List[Optional[int]] = [None] * n

    @classmethod
    def view_of(cls, parent: "_VecState", sl: slice) -> "_VecState":
        """A per-segment view sharing the parent's storage (fleet mode).

        Every array field is a basic slice of the parent's arrays, so the
        member cluster's scalar helpers and the fleet's flattened kernels
        operate on the same memory -- the view *is* the segment partition.
        ``finished_at`` stays a per-member list (never touched vectorized).
        """
        v = object.__new__(cls)
        v.n = sl.stop - sl.start
        for name in ("state", "busy", "wake", "sleep_entry", "pend_bank",
                     "has_poll", "elw"):
            setattr(v, name, getattr(parent, name)[sl])
        v.counter_block = parent.counter_block[:, sl]
        v.counters = {
            name: v.counter_block[i] for i, name in enumerate(_COUNTERS)
        }
        v.finished_at = [None] * v.n
        return v


def _vec_scalar_property(array_name: str):
    def get(self):
        return int(getattr(self._V, array_name)[self.cid])

    def set(self, value):
        getattr(self._V, array_name)[self.cid] = value

    return property(get, set)


def _vec_counter_property(counter: str):
    def get(self):
        return int(self._V.counters[counter][self.cid])

    def set(self, value):
        self._V.counters[counter][self.cid] = value

    return property(get, set)


class _VecCore(_Core):
    """Per-core view into a :class:`_VecState` (vectorized engine mode).

    Scheduler fields and counters resolve into the shared arrays; everything
    idiosyncratic (the program generator, pending op object, resume value)
    stays a per-object attribute.  Property access is slower than a slot --
    this view is only touched on the cold paths (generator advances, SCU
    servicing, tests/programs introspecting a core); the per-cycle kernels
    operate on the arrays directly.
    """

    __slots__ = ("_V",)

    def __init__(self, cid: int, gen: Generator, vec: _VecState):
        self._V = vec
        super().__init__(cid, gen)

    busy = _vec_scalar_property("busy")
    wake_countdown = _vec_scalar_property("wake")
    sleep_entry = _vec_scalar_property("sleep_entry")

    @property
    def state(self) -> CoreState:
        return _STATE_BY_CODE[int(self._V.state[self.cid])]

    @state.setter
    def state(self, value: CoreState) -> None:
        self._V.state[self.cid] = value.value

    @property
    def elw_issued(self) -> bool:
        return bool(self._V.elw[self.cid])

    @elw_issued.setter
    def elw_issued(self, value: bool) -> None:
        self._V.elw[self.cid] = value

    @property
    def finished_at(self) -> Optional[int]:
        return self._V.finished_at[self.cid]

    @finished_at.setter
    def finished_at(self, value: Optional[int]) -> None:
        self._V.finished_at[self.cid] = value


for _name in _COUNTERS:
    setattr(_VecCore, _name, _vec_counter_property(_name))


class Cluster:
    """The cycle-accurate cluster model.

    Parameters
    ----------
    n_cores:
        Number of PEs (the paper's cluster: 8; SCU supports up to 16).
    banking_factor:
        TCDM banks = ``banking_factor * n_cores`` (paper: 2).
    scu:
        An :class:`repro.core.scu.scu_unit.SCU` instance (constructed by the
        caller so extensions are configurable).  May be ``None`` for purely
        software experiments.
    mode:
        ``"fastforward"`` (default) -- event-driven engine with three
        resolution tiers (quiescent span / spin-phase batch / full step; the
        full step is the vectorized structure-of-arrays kernel on clusters
        with at least :attr:`VEC_MIN_CORES` cores); ``"lockstep"`` -- the
        unvectorized cycle-by-cycle reference model.  Both produce bit-exact
        identical :class:`ClusterStats` (see module docstring).
    faults:
        An optional :class:`repro.core.scu.faults.FaultPlan` -- a
        deterministic schedule of injected upsets (lost/spurious wake-ups,
        transient core stalls, TCDM bank blackouts).  The plan implements
        the ``next_event_bound()`` contract, so fault-injected runs stay
        bit-exact between the two modes.  Plans are single-use; pass a
        fresh (or :meth:`~repro.core.scu.faults.FaultPlan.clone`\\ d) plan
        per cluster.
    """

    MODES = ("fastforward", "lockstep")

    TAS_CYCLES = 3  # Sec. 4.1: "TAS transactions take just three cycles"
    # Fig. 4 timing: elw issue -> busy release -> clock gate takes 2 cycles on
    # the way in; event -> clock enable + grant -> response -> resume takes 4
    # cycles on the way out.  Together with the issue and address-setup cycles
    # this yields the paper's 6 active core cycles per handled
    # synchronization point (Sec. 5, Fig. 4).
    SLEEP_ENTRY_CYCLES = 1
    WAKE_CYCLES = 4

    # Minimum cluster size for the numpy kernels: below this the fixed
    # per-numpy-call overhead exceeds the per-core Python loop it replaces,
    # so small fastforward clusters keep the scalar step (still tiered).
    VEC_MIN_CORES = 16

    # Spin-phase batch resolution: phases expected to outlast this many
    # cycles additionally run the period detector, which can collapse the
    # remaining horizon into one closed-form multiply (see
    # :meth:`_resolve_spin_phase`).  Short phases are resolved grant-by-grant
    # without paying for configuration hashing.
    SPIN_PERIOD_MIN_HORIZON = 64
    # ... and the detector gives up after this many distinct configurations
    # (a phase whose period is longer is replayed grant-by-grant; the memo
    # must not grow unboundedly on pathological rotations).
    SPIN_PERIOD_MEMO_LIMIT = 4096

    # Spin-resolver spectator handling: at or below this core count the
    # horizon/writeback passes use direct scalar reads on the SoA arrays (a
    # handful of element accesses beat the fixed cost of the numpy mask
    # kernels on such narrow arrays -- the fleet runs many 8-core members).
    SPIN_SCALAR_MAX_CORES = 32

    def __init__(
        self,
        n_cores: int,
        scu=None,
        banking_factor: int = 2,
        mode: str = "fastforward",
        faults: Optional[FaultPlan] = None,
    ):
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, got {mode!r}")
        self.n_cores = n_cores
        self.n_banks = banking_factor * n_cores
        self.scu = scu
        self.mode = mode
        self.faults = faults
        self.vectorized = mode == "fastforward" and n_cores >= self.VEC_MIN_CORES
        if scu is not None:
            scu.attach(self)
        self.tcdm: Dict[int, int] = {}
        self._rr = np.zeros(self.n_banks, dtype=np.int64)  # round-robin ptrs
        self.cores: List[_Core] = []
        self._vec: Optional[_VecState] = None
        self._n_done = 0
        self.cycle = 0
        self.max_cycles = 0  # horizon of the current run()
        self.stats = ClusterStats()
        # fast-forward diagnostics (engine-internal; never part of
        # ClusterStats so the two modes stay bit-exact comparable)
        self.ff_spans = 0  # number of quiescent-span jumps taken
        self.ff_cycles = 0  # cycles covered by those jumps
        self.ff_batch_spans = 0  # number of spin-phase batch jumps taken
        self.ff_batch_cycles = 0  # cycles covered by those jumps
        # compiled-trace fast path (armed by load() when every core runs a
        # pure TraceProgram cursor; see repro.core.scu.trace)
        self._trace_monitor = None
        self.trace_jumps = 0  # whole-cluster period collapses taken
        self.trace_jump_cycles = 0  # cycles covered by those collapses

    # ------------------------------------------------------------------ api
    def load(self, programs: List[Program]) -> None:
        assert len(programs) == self.n_cores
        if self.vectorized:
            self._vec = _VecState(self.n_cores)
            self.cores = [
                _VecCore(i, prog(self, i), self._vec)
                for i, prog in enumerate(programs)
            ]
        else:
            self._vec = None
            self.cores = [_Core(i, prog(self, i)) for i, prog in enumerate(programs)]
        self.stats = ClusterStats()
        self._n_done = 0
        # Arm the compiled-trace period collapse when the *entire* cluster
        # state is static trace state: every core a pure table cursor, no
        # fault plan rewriting state mid-run, no watchdog measuring wall
        # progress.  (Generator fallbacks hold opaque Python frames the
        # digest cannot cover, so one fallback disables the whole monitor.)
        self._trace_monitor = None
        if (
            self.mode == "fastforward"
            and self.faults is None
            and (self.scu is None or self.scu.watchdog is None)
            and self.cores
            and all(
                getattr(c.gen, "_is_trace_cursor", False) for c in self.cores
            )
        ):
            from .trace import TraceRunMonitor  # deferred: trace imports us

            self._trace_monitor = TraceRunMonitor(
                self, [c.gen for c in self.cores]
            )

    def run(self, max_cycles: int = 10_000_000) -> ClusterStats:
        self.max_cycles = max_cycles
        try:
            if self.mode == "fastforward":
                self._run_fast(max_cycles)
            else:
                scu = self.scu
                has_wd = scu is not None and scu.watchdog is not None
                while self._n_done < self.n_cores:
                    if self.cycle >= max_cycles:
                        self._raise_timeout(max_cycles)
                    self.step()
                    if has_wd and scu.watchdog.tripped is not None:
                        raise self._watchdog_error()
        finally:
            self.stats.cycles = self.cycle
            self.stats.cores = [c.stats for c in self.cores]
        return self.stats

    def _raise_timeout(self, max_cycles: int) -> None:
        graph = build_wait_graph(self)
        raise SimTimeout(
            f"cluster did not finish within {max_cycles} cycles "
            f"(states: {[c.state.name for c in self.cores]})\n"
            + graph.describe(),
            graph=graph,
        )

    def _watchdog_error(self) -> Optional[DeadlockError]:
        """The pending watchdog trip as a raisable error, or ``None``.

        Trips are detected *after* a step completes (trip-and-report): the
        watchdog never aborts a step half-way, which in fleet mode would
        corrupt co-resident members sharing the batched step."""
        scu = self.scu
        if scu is None or scu.watchdog is None:
            return None
        wd = scu.watchdog
        graph = wd.tripped
        if graph is None:
            return None
        return DeadlockError(
            f"watchdog tripped at cycle {graph.cycle}: no armed-set progress "
            f"within {wd.timeout} cycles "
            f"(mode={wd.mode!r}, releases={wd.release_count})\n"
            + graph.describe(),
            graph=graph,
        )

    def _run_fast(self, max_cycles: int) -> None:
        step = self._step_vec if self.vectorized else self.step
        scu = self.scu
        has_wd = scu is not None and scu.watchdog is not None
        monitor = self._trace_monitor
        while self._n_done < self.n_cores:
            if monitor is not None:
                # compiled-trace tier: digest the full cluster state at
                # loop-head crossings; a recurring digest collapses all
                # remaining periods into one multiply of the stat deltas
                monitor.poll()
            if self.cycle >= max_cycles:
                self._raise_timeout(max_cycles)
            bound = self.next_event_bound()
            if bound is None:
                # deadlock: every core is gated with no wake event in
                # sight -- burn to the cap so the failure mode (and the
                # cycle count it reports) matches lockstep exactly
                bound = max_cycles - self.cycle
            if bound > 0:
                self.fast_forward(min(bound, max_cycles - self.cycle))
                continue
            if self._resolve_spin_phase():
                continue
            step()
            if has_wd and scu.watchdog.tripped is not None:
                raise self._watchdog_error()

    # ---------------------------------------------------------------- cycle
    def step(self) -> None:
        """Advance the whole cluster by one clock cycle (scalar reference)."""
        # Injected upsets land before anything else sees the cycle; the
        # fault plan's bound guarantees a full step runs on every scheduled
        # cycle in either mode.
        if self.faults is not None:
            self.faults.apply(self)

        # Phase 0: extension comparators are registered -- events caused by
        # the *previous* cycle's triggers become visible in the buffers now.
        if self.scu is not None:
            n_ev = self.scu.evaluate(self.cycle)
            self.stats.scu_events += n_ev

        # Phase 1: issue -- every clocked core makes progress / places reqs.
        for core in self.cores:
            self._issue(core)

        # Phase 2: TCDM / LINT arbitration (per-bank round robin).
        self._arbitrate_tcdm()

        # Phase 3: SCU -- private links, elw grant logic, extension triggers.
        if self.scu is not None:
            self._service_scu()

        # Phase 4: pending elw transactions are polled against the buffers.
        if self.scu is not None:
            self._wake_cores()

        # Phase 5: accounting.
        for core in self.cores:
            state = core.state
            if state is CoreState.DONE:
                continue
            if state is CoreState.SLEEP:
                core.gated_cycles += 1
            else:
                core.active_cycles += 1
                if state is CoreState.ACTIVE:
                    core.comp_cycles += 1
                else:
                    # clocked but held: LINT stall, elw grant window, wake
                    core.wait_cycles += 1
                    if state is CoreState.STALL_MEM:
                        core.stall_cycles += 1
        self.cycle += 1

    # ----------------------------------------------------- fast-forward path
    def next_event_bound(self) -> Optional[int]:
        """Number of cycles that can be skipped before anything observable
        can happen; 0 forces a full step, ``None`` means no internal event is
        ever due (every core gated/done and no comparator armed).

        The bound is the min over the per-core countdown bounds
        (:meth:`_Core.quiescent_bound`) and the SCU extension bound
        (:meth:`repro.core.scu.scu_unit.SCU.next_event_bound`): extensions
        are pure comparators over state written by core transactions, so if
        none can fire now and no core acts, none can fire during the span.

        An attached :class:`FaultPlan` is a third bound source: injected
        faults are observable events, so the plan's own
        ``next_event_bound()`` is min'd in -- every fault cycle (and every
        cycle of a bank-blackout window) resolves through a full step.
        """
        if self.vectorized:
            bound = self._next_event_bound_vec()
        else:
            bound = self._next_event_bound_scalar()
        faults = self.faults
        if faults is not None and bound != 0:
            fb = faults.next_event_bound(self.cycle)
            if fb is not None and (bound is None or fb < bound):
                bound = fb
        return bound

    def _next_event_bound_scalar(self) -> Optional[int]:
        # cores first: during contention phases the first stalled core
        # short-circuits the scan before any extension comparator is touched
        bound: Optional[int] = None
        scu = self.scu
        for core in self.cores:
            b = core.quiescent_bound(scu)
            if b is None:
                continue
            if b <= 0:
                return 0
            if bound is None or b < bound:
                bound = b
        if scu is not None:
            b = scu.next_event_bound()
            if b is not None:
                if b <= 0:
                    return 0
                if bound is None or b < bound:
                    bound = b
        return bound

    def _next_event_bound_vec(self) -> Optional[int]:
        V = self._vec
        st = V.state
        active = st == _ACTIVE
        waking = st == _WAKING
        # any transient state or imminent advance forces a step now
        if (
            np.any(st == _STALL_MEM)
            or np.any(st == _STALL_SCU)
            or np.any(active & (V.busy <= 0))
            or np.any(waking & (V.wake <= 1))
        ):
            return 0
        bound: Optional[int] = None
        if np.any(active):
            bound = int(V.busy[active].min())
        if np.any(waking):
            w = int(V.wake[waking].min()) - 1
            if bound is None or w < bound:
                bound = w
        scu = self.scu
        if scu is not None:
            sleeping = np.nonzero(st == _SLEEP)[0]
            if sleeping.size and scu.elw_any_grantable(sleeping):
                return 0
            b = scu.next_event_bound()
            if b is not None:
                if b <= 0:
                    return 0
                if bound is None or b < bound:
                    bound = b
        return bound

    def fast_forward(self, span: int) -> None:
        """Jump ``span`` quiescent cycles: counters and stats advance in one
        span-based update, no arbitration / SCU phases run (the scheduler
        proved none could act -- see :meth:`next_event_bound`)."""
        if self.vectorized:
            V = self._vec
            st = V.state
            active = st == _ACTIVE
            waking = st == _WAKING
            sleeping = st == _SLEEP
            V.busy[active] -= span
            V.wake[waking] -= span
            clocked = active | waking
            V.counters["active_cycles"][clocked] += span
            V.counters["comp_cycles"][active] += span
            V.counters["wait_cycles"][waking] += span
            V.counters["gated_cycles"][sleeping] += span
        else:
            for core in self.cores:
                core.fast_forward(span)
        self.cycle += span
        self.ff_spans += 1
        self.ff_cycles += span

    # --------------------------------------------- spin-phase batch resolver
    def _spin_participants_vec(self) -> Optional[np.ndarray]:
        """Vectorized eligibility check: participant cids, or ``None``."""
        V = self._vec
        st = V.state
        if (st == _STALL_SCU).any():
            return None
        has_poll = V.has_poll
        stalled = st == _STALL_MEM
        if (stalled & ~has_poll).any():
            return None  # a plain Mem transaction is in flight
        part = has_poll & (stalled | (st == _ACTIVE))
        if not part.any():
            return None
        if ((st == _ACTIVE) & (V.busy <= 0) & ~part).any():
            return None  # generator advance due this cycle
        waking = st == _WAKING
        if waking.any() and (waking & (V.wake <= 1)).any():
            return None
        scu = self.scu
        if scu is not None:
            if scu.next_event_bound() is not None:
                return None
            sleeping = np.nonzero(st == _SLEEP)[0]
            if sleeping.size and scu.elw_any_grantable(sleeping):
                return None
        return np.nonzero(part)[0]

    def _spin_participants(self) -> Optional[List[_Core]]:
        """The polling cores of an eligible spin phase, or ``None``.

        A spin phase requires every non-DONE core to be one of

        * a *participant*: a pending :class:`Poll` (requesting the bank or
          counting down a retry shadow) -- engine-deterministic until the
          polled word changes;
        * a *spectator*: a pure countdown (``Compute`` span, wake sequencing
          with at least one safe cycle left) or clock-gated sleep with no
          buffered wake event;

        and no armed SCU comparator.  Under those conditions the only state
        evolving is the participants' round-robin rotation -- periodic, and
        therefore batch-resolvable.
        """
        scu = self.scu
        participants: List[_Core] = []
        for core in self.cores:
            state = core.state
            if state is CoreState.DONE:
                continue
            pending = core.pending
            if isinstance(pending, Poll) and state in (
                CoreState.STALL_MEM,
                CoreState.ACTIVE,
            ):
                participants.append(core)
                continue
            if state is CoreState.ACTIVE:
                if core.busy <= 0:
                    return None  # generator advance due this cycle
            elif state is CoreState.WAKING:
                if core.wake_countdown <= 1:
                    return None
            elif state is CoreState.SLEEP:
                if scu is None or scu.elw_would_grant(core.cid, pending.addr):
                    return None
            else:  # STALL_SCU or anything mid-transaction
                return None
        if not participants:
            return None
        if scu is not None and scu.next_event_bound() is not None:
            return None
        return participants

    def _resolve_spin_phase(self, pids_arr: Optional[np.ndarray] = None) -> bool:
        """Tier-2 resolution: batch-resolve a pure spin/poll phase.

        When every awake core is inside a :class:`Poll` (eligibility via
        :meth:`_spin_participants`), the cluster's evolution until the next
        spectator deadline is fully determined by engine state: per cycle,
        each polled bank grants one requester (round robin), misses re-enter
        the queue after their retry shadow, and nothing else can move.  This
        resolver replays exactly those round-robin outcomes with per-*grant*
        (not per-cycle) work -- queue-wait spans, retry shadows and the
        implied conflict/stall accounting are settled in closed form per
        segment -- and skips empty cycles between rejoins entirely.

        For long phases (horizon > :attr:`SPIN_PERIOD_MIN_HORIZON`) it
        additionally hashes the relative spin configuration each cycle; a
        repeat proves periodicity, and the remaining horizon collapses into
        one multiply of the per-period stat deltas (the closed form for the
        "one core computes for 10^5 cycles while the rest spin" phases of
        the imbalanced applications).

        The phase ends at the first poll *hit* (the program must resume), at
        the spectator horizon (a countdown expires), or at ``max_cycles``;
        the cores are written back in exactly the state the same number of
        lockstep steps would have left them in.  Returns True when at least
        one cycle was resolved.

        ``pids_arr`` short-circuits the eligibility check with a
        caller-proven participant set -- the fleet engine computes
        eligibility for every config in one flattened pass and hands each
        eligible member its participants directly.
        """
        V = self._vec
        cores = self.cores
        n = self.n_cores
        t0 = self.cycle

        # -- fault plan: the resolver replays TCDM grants without the
        #    arbitration (and blackout) machinery, so a fault due now blocks
        #    tier 2 outright and a future fault caps the replay horizon
        fault_bound = None
        if self.faults is not None:
            fault_bound = self.faults.next_event_bound(t0)
            if fault_bound == 0:
                return False

        # -- eligibility + participant set ---------------------------------
        if pids_arr is not None:
            pids = [int(c) for c in pids_arr]
        elif self.vectorized:
            p_arr = self._spin_participants_vec()
            if p_arr is None:
                return False
            pids = [int(c) for c in p_arr]
        else:
            parts = self._spin_participants()
            if parts is None:
                return False
            pids = [c.cid for c in parts]

        # -- spectator horizon ---------------------------------------------
        horizon = self.max_cycles - t0
        pid_set = set(pids)
        small = n <= self.SPIN_SCALAR_MAX_CORES
        if self.vectorized and not small:
            st = V.state
            spect = np.ones(n, dtype=bool)
            spect[pids] = False
            sa = spect & (st == _ACTIVE)
            if sa.any():
                horizon = min(horizon, int(V.busy[sa].min()))
            sw = spect & (st == _WAKING)
            if sw.any():
                horizon = min(horizon, int(V.wake[sw].min()) - 1)
        elif self.vectorized:
            # small clusters: direct scalar reads beat the numpy mask ops
            stv, busyv, wakev = V.state, V.busy, V.wake
            for cid in range(n):
                if cid in pid_set:
                    continue
                s = stv[cid]
                if s == _ACTIVE:
                    b = busyv[cid]
                    if b < horizon:
                        horizon = int(b)
                elif s == _WAKING:
                    w = wakev[cid] - 1
                    if w < horizon:
                        horizon = int(w)
        else:
            for core in cores:
                if core.cid in pid_set:
                    continue
                cs = core.state
                if cs is CoreState.ACTIVE:
                    horizon = min(horizon, core.busy)
                elif cs is CoreState.WAKING:
                    horizon = min(horizon, core.wake_countdown - 1)
        if fault_bound is not None and fault_bound < horizon:
            horizon = fault_bound
        if horizon <= 0:  # pragma: no cover - eligibility guarantees >= 1
            return False

        # -- participant records -------------------------------------------
        k = len(pids)
        banks_ = [0] * k
        addrs_ = [0] * k
        untils = [0] * k
        is_tas = [False] * k
        miss_sh = [0] * k  # full ACTIVE shadow after a miss grant
        hit_sh = [0] * k
        h_in = [0] * k
        m_in = [0] * k
        queued_at = [-1] * k  # request time while queued, else -1
        rejoin_at = [-1] * k  # re-issue time while in a retry shadow
        shadow_from = [0] * k  # start of the unsettled comp segment
        acc = [[0] * len(_COUNTERS) for _ in range(k)]
        queues: Dict[int, List[int]] = {}
        rejoins: Dict[int, List[int]] = {}
        tas_cycles = self.TAS_CYCLES - 1
        vec = self.vectorized
        if vec:
            stv_, busyv_ = V.state, V.busy
        n_banks = self.n_banks
        for i, cid in enumerate(pids):
            op = cores[cid].pending
            b = (op.addr >> 2) % n_banks  # _bank_of, inlined
            banks_[i] = b
            addrs_[i] = op.addr
            untils[i] = op.until
            tas = op.kind == "tas"
            base = tas_cycles if tas else 0
            is_tas[i] = tas
            miss_sh[i] = base + op.miss_cycles
            hit_sh[i] = base + op.hit_cycles
            h_in[i] = op.hit_instr
            m_in[i] = op.miss_instr
            if vec:
                in_queue = stv_[cid] == _STALL_MEM
                busy_c = int(busyv_[cid])
            else:
                in_queue = cores[cid].state is CoreState.STALL_MEM
                busy_c = cores[cid].busy
            if in_queue:
                queued_at[i] = t0
                queues.setdefault(b, []).append(i)
            else:
                # mid-shadow at entry: the re-issue lands busy cycles out
                tr = t0 + busy_c
                rejoin_at[i] = tr
                shadow_from[i] = t0
                rejoins.setdefault(tr, []).append(i)

        # -- replay grants until a hit / the horizon ------------------------
        t = t0
        t_end = t0 + horizon
        hits: List[Tuple[int, int]] = []
        rr = self._rr
        tcdm = self.tcdm
        detect = horizon > self.SPIN_PERIOD_MIN_HORIZON
        bank_list = sorted(set(banks_)) if detect else ()
        # the round-robin pointers of the involved banks, mirrored into a
        # plain dict for the replay (one numpy scalar read/write per bank
        # instead of one per grant); written back after the loop
        rr_loc = {b: int(rr[b]) for b in set(banks_)}
        # lazy detection start: most phases end by a hit long before
        # periodicity could pay off, so the per-cycle configuration hashing
        # only begins once the replay has actually outlasted the threshold
        detect_from = t0 + self.SPIN_PERIOD_MIN_HORIZON
        seen: Dict[Any, Tuple[int, List[List[int]]]] = {}
        while t < t_end:
            joiners = rejoins.pop(t, None)
            if joiners:
                for i in joiners:
                    a = acc[i]
                    seg = t - shadow_from[i]
                    a[_C_COMP] += seg
                    a[_C_ACTIVE] += seg
                    a[_C_INSTR] += 1  # the re-issued load
                    queued_at[i] = t
                    rejoin_at[i] = -1
                    queues.setdefault(banks_[i], []).append(i)
            if not queues:
                if not rejoins:  # pragma: no cover - all cores hit
                    break
                nxt = min(rejoins)
                t = nxt if nxt < t_end else t_end
                continue
            if detect and t >= detect_from:
                # a shadow's key carries both the rejoin offset and the
                # unsettled-segment start: an entry shadow (segment began at
                # phase entry, not at a grant) must never alias an in-phase
                # shadow with the same rejoin offset, or the settled-delta
                # cancellation argument breaks
                key = (
                    tuple(
                        (i, t - queued_at[i])
                        if queued_at[i] >= 0
                        else (i, t - rejoin_at[i], t - shadow_from[i])
                        for i in range(k)
                    ),
                    tuple(rr_loc[b] for b in bank_list),
                    tuple(tcdm.get(a, 0) for a in addrs_),
                )
                prev = seen.get(key)
                if prev is None:
                    if len(seen) >= self.SPIN_PERIOD_MEMO_LIMIT:
                        detect = False
                        seen.clear()
                    else:
                        seen[key] = (t, [list(a) for a in acc])
                else:
                    t1, acc1 = prev
                    period = t - t1
                    m = (t_end - t) // period
                    if m > 0:
                        shift = m * period
                        for i in range(k):
                            a, a1 = acc[i], acc1[i]
                            for j in range(len(_COUNTERS)):
                                a[j] += m * (a[j] - a1[j])
                            if queued_at[i] >= 0:
                                queued_at[i] += shift
                            else:
                                rejoin_at[i] += shift
                                shadow_from[i] += shift
                        rejoins = {
                            tk + shift: v for tk, v in rejoins.items()
                        }
                        t += shift
                        seen.clear()
                        if t >= t_end:
                            break
            for b in list(queues):
                q = queues[b]
                if len(q) == 1:
                    wi = q[0]
                    del queues[b]
                else:
                    rb = rr_loc[b]
                    best = n
                    for i in q:
                        kk = (pids[i] - rb) % n
                        if kk < best:
                            best = kk
                            wi = i
                    q.remove(wi)
                rr_loc[b] = (pids[wi] + 1) % n
                dt = t - queued_at[wi]
                queued_at[wi] = -1
                a = acc[wi]
                a[_C_ACTIVE] += dt + 1
                a[_C_WAIT] += dt
                a[_C_STALL] += dt
                a[_C_COMP] += 1
                a[_C_TCDM] += 1
                addr = addrs_[wi]
                value = tcdm.get(addr, 0)
                if is_tas[wi]:
                    tcdm[addr] = -1
                    a[_C_TAS] += 1
                if value == untils[wi]:
                    a[_C_INSTR] += h_in[wi]
                    hits.append((wi, value))
                else:
                    a[_C_INSTR] += m_in[wi]
                    tr = t + miss_sh[wi] + 1
                    shadow_from[wi] = t + 1
                    rejoin_at[wi] = tr
                    rejoins.setdefault(tr, []).append(wi)
            t += 1
            if hits:
                t_end = t
                break
        for b, v in rr_loc.items():
            rr[b] = v

        # -- settle partial segments + write the cores back -----------------
        span = t_end - t0
        hit_idx = {i for i, _ in hits}
        conflicts = 0
        for i, cid in enumerate(pids):
            a = acc[i]
            if i in hit_idx:
                pass  # exits at the grant cycle; shadow runs under tier 1
            elif queued_at[i] >= 0:
                seg = t_end - queued_at[i]
                a[_C_ACTIVE] += seg
                a[_C_WAIT] += seg
                a[_C_STALL] += seg
            else:
                seg = t_end - shadow_from[i]
                a[_C_COMP] += seg
                a[_C_ACTIVE] += seg
            conflicts += a[_C_STALL]
        self.stats.bank_conflicts += conflicts
        if self.vectorized:
            CB = V.counter_block
            # all participants' accumulated counters in one fancy add
            CB[:, pids] += np.array(acc, dtype=np.int64).T
            for i, value in hits:
                cid = pids[i]
                core = cores[cid]
                core.pending = None
                core.resume_value = value
                V.state[cid] = _ACTIVE
                V.busy[cid] = hit_sh[i]
                V.pend_bank[cid] = -1
                V.has_poll[cid] = False
            for i in range(k):
                if i in hit_idx:
                    continue
                cid = pids[i]
                if queued_at[i] >= 0:
                    # the virtual re-issue happened inside the phase: the
                    # core is waiting in the bank queue again
                    V.state[cid] = _STALL_MEM
                    V.busy[cid] = 0
                else:
                    V.state[cid] = _ACTIVE
                    V.busy[cid] = rejoin_at[i] - t_end
            # spectators: span-based countdown accounting
            st = V.state
            if small:
                stv, busyv, wakev = st, V.busy, V.wake
                for cid in range(n):
                    if cid in pid_set:
                        continue
                    s = stv[cid]
                    if s == _ACTIVE:
                        busyv[cid] -= span
                        CB[_C_ACTIVE, cid] += span
                        CB[_C_COMP, cid] += span
                    elif s == _WAKING:
                        wakev[cid] -= span
                        CB[_C_ACTIVE, cid] += span
                        CB[_C_WAIT, cid] += span
                    elif s == _SLEEP:
                        CB[_C_GATED, cid] += span
            else:
                spect = np.ones(n, dtype=bool)
                spect[pids] = False
                sa = spect & (st == _ACTIVE)
                sw = spect & (st == _WAKING)
                V.busy[sa] -= span
                V.wake[sw] -= span
                C = V.counters
                C["active_cycles"][sa] += span
                C["comp_cycles"][sa] += span
                C["active_cycles"][sw] += span
                C["wait_cycles"][sw] += span
                C["gated_cycles"][spect & (st == _SLEEP)] += span
        else:
            for i, cid in enumerate(pids):
                core = cores[cid]
                a = acc[i]
                for j, name in enumerate(_COUNTERS):
                    setattr(core, name, getattr(core, name) + a[j])
                if i in hit_idx:
                    continue
                if queued_at[i] >= 0:
                    # the virtual re-issue happened inside the phase: the
                    # core is waiting in the bank queue again
                    core.state = CoreState.STALL_MEM
                    core.busy = 0
                else:
                    core.state = CoreState.ACTIVE
                    core.busy = rejoin_at[i] - t_end
            for i, value in hits:
                core = cores[pids[i]]
                core.pending = None
                core.resume_value = value
                core.state = CoreState.ACTIVE
                core.busy = hit_sh[i]
            pid_set = set(pids)
            for core in cores:
                if core.cid not in pid_set:
                    core.fast_forward(span)
        self.cycle = t_end
        self.ff_batch_spans += 1
        self.ff_batch_cycles += span
        return True


    def _step_vec(self) -> None:
        """One full cluster step through the structure-of-arrays kernels.

        Phase order and semantics are identical to the scalar :meth:`step`;
        every per-core loop is replaced by a numpy kernel over the state
        arrays, dropping to Python only for the idiosyncratic transitions
        (generator advances, SCU transactions, elw grants).
        """
        V = self._vec
        cores = self.cores
        st = V.state

        # Injected upsets land before anything else sees the cycle.
        if self.faults is not None:
            self.faults.apply(self)

        # Phase 0: extension comparators.
        if self.scu is not None:
            n_ev = self.scu.evaluate(self.cycle)
            self.stats.scu_events += n_ev

        # Phase 1a: countdowns (vectorized).
        active = st == _ACTIVE
        counting = active & (V.busy > 0)
        V.busy[counting] -= 1
        waking = st == _WAKING
        V.wake[waking] -= 1
        gating = (st == _STALL_SCU) & V.elw
        if np.any(gating):
            V.sleep_entry[gating] -= 1
            gated = gating & (V.sleep_entry <= 0)
            st[gated] = _SLEEP

        # Phase 1b: generator advances and Poll re-issues (scalar).
        due = np.nonzero((active & ~counting) | (waking & (V.wake <= 0)))[0]
        for cid in due:
            core = cores[cid]
            if st[cid] == _WAKING:
                st[cid] = _ACTIVE
            if core.pending is not None and not V.elw[cid]:
                # armed Poll whose retry shadow expired: re-enter the queue
                st[cid] = _STALL_MEM
                V.counters["instructions"][cid] += 1
            else:
                self._advance(core, core.resume_value)

        # Phase 2: TCDM / LINT arbitration (vectorized round robin).
        self._arbitrate_tcdm_vec()

        # Phase 3 + 4: SCU private links and elw grant scans.
        if self.scu is not None:
            fresh = np.nonzero((st == _STALL_SCU) & ~V.elw)[0]
            for cid in fresh:
                self._service_one(cores[cid])
            self._wake_cores_vec()

        # Phase 5: accounting (one state-code table gather, see _ACCT_INC).
        V.counter_block[:5] += _ACCT_INC[:, st]
        self.cycle += 1

    def _arbitrate_tcdm_vec(self) -> None:
        V = self._vec
        st = V.state
        req = np.nonzero(st == _STALL_MEM)[0]
        if req.size == 0:
            return
        if self.faults is not None:
            blk = self.faults.blacked_banks(self.cycle)
            if blk:
                # filter before the single-requester shortcut: a blacked
                # bank grants nothing and charges no conflicts
                req = req[~np.isin(V.pend_bank[req], tuple(blk))]
                if req.size == 0:
                    return
        n = self.n_cores
        if req.size == 1:
            cid = int(req[0])
            self._rr[V.pend_bank[cid]] = (cid + 1) % n
            self._grant_mem_vec(cid)
            return
        banks = V.pend_bank[req]
        key = (req - self._rr[banks]) % n
        order = np.lexsort((key, banks))
        sorted_banks = banks[order]
        # winners: the first requester of each bank group (lowest rr key)
        first = np.ones(order.size, dtype=bool)
        first[1:] = sorted_banks[1:] != sorted_banks[:-1]
        winners = req[order[first]]
        self.stats.bank_conflicts += int(req.size - winners.size)
        rr = self._rr
        for cid in winners:
            cid = int(cid)
            rr[V.pend_bank[cid]] = (cid + 1) % n
            self._grant_mem_vec(cid)

    def _grant_mem_vec(self, cid: int) -> None:
        """Granted TCDM transaction, writing the SoA state directly.

        Deliberate (measured) duplicate of :meth:`_grant_mem`: the generic
        version goes through the `_VecCore` property layer, which costs ~3x
        more per winner, and this path takes up to one grant per bank per
        cycle.  Keep the two in lockstep when touching grant semantics --
        the 16..256-core randomized cross-checks in
        ``tests/test_scu_simulator.py`` trip on any divergence."""
        V = self._vec
        core = self.cores[cid]
        op = core.pending
        CB = V.counter_block
        CB[_C_TCDM, cid] += 1
        if type(op) is Poll:
            value = self.tcdm.get(op.addr, 0)
            base = 0
            if op.kind == "tas":
                self.tcdm[op.addr] = -1
                CB[_C_TAS, cid] += 1
                base = self.TAS_CYCLES - 1
            if value == op.until:
                core.pending = None
                core.resume_value = value
                V.busy[cid] = base + op.hit_cycles
                CB[_C_INSTR, cid] += op.hit_instr
                V.pend_bank[cid] = -1
                V.has_poll[cid] = False
            else:
                V.busy[cid] = base + op.miss_cycles
                CB[_C_INSTR, cid] += op.miss_instr
            V.state[cid] = _ACTIVE
            return
        kind = op.kind
        if kind == "lw":
            value = self.tcdm.get(op.addr, 0)
        elif kind == "sw":
            self.tcdm[op.addr] = op.data
            value = 0
        elif kind == "tas":
            value = self.tcdm.get(op.addr, 0)
            self.tcdm[op.addr] = -1
            CB[_C_TAS, cid] += 1
            V.busy[cid] = self.TAS_CYCLES - 1
        else:  # pragma: no cover
            raise ValueError(kind)
        core.pending = None
        core.resume_value = value
        V.state[cid] = _ACTIVE
        V.pend_bank[cid] = -1
        V.has_poll[cid] = False

    def _wake_cores_vec(self) -> None:
        """Phase 4, vectorized precheck: only cores whose waited-on event is
        actually buffered run the scalar grant sequencing."""
        V = self._vec
        st = V.state
        pending = V.elw & ((st == _STALL_SCU) | (st == _SLEEP))
        if not np.any(pending):
            return
        cids = np.nonzero(pending)[0]
        granted = self.scu.elw_grantable_mask(cids)
        for cid in cids[granted]:
            self._wake_one(self.cores[cid])

    # ------------------------------------------------------------ internals
    def _advance(self, core: _Core, value: int = 0) -> None:
        """Feed ``value`` into the program generator and fetch the next op."""
        try:
            op = core.gen.send(value) if core.started else next(core.gen)
        except StopIteration:
            core.state = CoreState.DONE
            core.finished_at = self.cycle
            core.pending = None
            self._n_done += 1
            return
        core.started = True
        V = self._vec
        if V is not None:
            # SoA fast path: write the arrays directly instead of going
            # through the _VecCore property layer (~6 property round-trips
            # per advance otherwise; this runs once per micro-op on every
            # core of a vectorized cluster or fleet)
            cid = core.cid
            V.counter_block[_C_INSTR, cid] += 1
            t = type(op)
            if t is Compute:
                c = op.cycles
                V.busy[cid] = c - 1 if c > 1 else 0  # this cycle counts
                V.state[cid] = _ACTIVE
                core.pending = None
            elif t is Mem or t is Poll:
                core.pending = op
                V.state[cid] = _STALL_MEM
                V.pend_bank[cid] = self._bank_of(op.addr)
                V.has_poll[cid] = t is Poll
            elif t is Scu:
                core.pending = op
                V.state[cid] = _STALL_SCU
            else:  # pragma: no cover - programming error
                raise TypeError(f"bad micro-op {op!r}")
            return
        core.instructions += 1
        if isinstance(op, Compute):
            core.busy = max(0, op.cycles - 1)  # this cycle counts as work
            core.state = CoreState.ACTIVE
            core.pending = None
        elif isinstance(op, (Mem, Poll)):
            core.pending = op
            core.state = CoreState.STALL_MEM
        elif isinstance(op, Scu):
            core.pending = op
            core.state = CoreState.STALL_SCU
        else:  # pragma: no cover - programming error
            raise TypeError(f"bad micro-op {op!r}")

    def _issue(self, core: _Core) -> None:
        state = core.state
        if state is CoreState.DONE:
            return
        if state is CoreState.ACTIVE:
            if core.busy > 0:
                core.busy -= 1
                return
            if core.pending is not None:
                # armed Poll whose retry shadow expired: re-enter the queue
                core.state = CoreState.STALL_MEM
                core.instructions += 1
                return
            self._advance(core, core.resume_value)
        elif state is CoreState.WAKING:
            core.wake_countdown -= 1
            if core.wake_countdown <= 0:
                core.state = CoreState.ACTIVE
                # response data already latched in resume_value
                self._advance(core, core.resume_value)
        elif state is CoreState.STALL_SCU and core.elw_issued:
            # busy-release window (Fig. 4 left): active, then clock gated
            core.sleep_entry -= 1
            if core.sleep_entry <= 0:
                core.state = CoreState.SLEEP

    def _bank_of(self, addr: int) -> int:
        return (addr >> 2) % self.n_banks

    def _arbitrate_tcdm(self) -> None:
        by_bank: Dict[int, List[_Core]] = {}
        for core in self.cores:
            if core.state is CoreState.STALL_MEM:
                by_bank.setdefault(self._bank_of(core.pending.addr), []).append(core)
        if by_bank and self.faults is not None:
            blk = self.faults.blacked_banks(self.cycle)
            if blk:
                # blacked-out banks grant nothing; queued requests are the
                # interconnect's fault, not contention -- no conflict charge
                for bank in blk:
                    by_bank.pop(bank, None)
        for bank, reqs in by_bank.items():
            # round-robin election among contenders
            rrb = int(self._rr[bank])
            n = self.n_cores
            reqs.sort(key=lambda c: (c.cid - rrb) % n)
            winner = reqs[0]
            self._rr[bank] = (winner.cid + 1) % n
            self.stats.bank_conflicts += len(reqs) - 1
            self._grant_mem(winner)

    def _grant_mem(self, winner: _Core) -> None:
        """Execute a granted TCDM transaction (shared by both arbiters)."""
        op = winner.pending
        winner.tcdm_accesses += 1
        if type(op) is Poll:
            value = self.tcdm.get(op.addr, 0)
            base = 0
            if op.kind == "tas":
                self.tcdm[op.addr] = -1
                winner.tas_accesses += 1
                base = self.TAS_CYCLES - 1
            if value == op.until:
                # hit: check cycles, then resume the program with the value
                winner.pending = None
                winner.resume_value = value
                winner.busy = base + op.hit_cycles
                winner.instructions += op.hit_instr
                if self._vec is not None:
                    self._vec.pend_bank[winner.cid] = -1
                    self._vec.has_poll[winner.cid] = False
            else:
                # miss: retry shadow, the Poll stays armed for re-issue
                winner.busy = base + op.miss_cycles
                winner.instructions += op.miss_instr
            winner.state = CoreState.ACTIVE
            return
        if op.kind == "lw":
            value = self.tcdm.get(op.addr, 0)
        elif op.kind == "sw":
            self.tcdm[op.addr] = op.data
            value = 0
        elif op.kind == "tas":
            value = self.tcdm.get(op.addr, 0)
            self.tcdm[op.addr] = -1
            winner.tas_accesses += 1
            # "-1 written back to memory in the next cycle before any
            # other core gets its request granted" (Sec. 4.1): the LINT
            # sequences the write-back through a forwarding write buffer
            # (atomicity is guaranteed by the arbitration order), and the
            # requesting core sees the full 3-cycle TAS latency.
            winner.busy = self.TAS_CYCLES - 1
        else:  # pragma: no cover
            raise ValueError(op.kind)
        # single-cycle TCDM: response consumed next cycle
        winner.pending = None
        winner.resume_value = value
        winner.state = CoreState.ACTIVE
        if self._vec is not None:
            self._vec.pend_bank[winner.cid] = -1
            self._vec.has_poll[winner.cid] = False

    def _service_scu(self) -> None:
        for core in self.cores:
            if core.state is CoreState.STALL_SCU and not core.elw_issued:
                self._service_one(core)

    def _service_one(self, core: _Core) -> None:
        """Service one fresh transaction on a private core<->SCU link."""
        op: Scu = core.pending
        V = self._vec
        if V is not None:
            # SoA fast path (see _advance): array writes, no property layer
            cid = core.cid
            V.counter_block[_C_SCU, cid] += 1
            if op.kind in ("write", "read"):
                value = self.scu.access(cid, op.kind, op.addr, op.data)
                core.pending = None
                core.resume_value = value if value is not None else 0
                V.state[cid] = _ACTIVE
            elif op.kind == "elw":
                self.scu.elw_trigger(cid, op.addr, op.data)
                V.elw[cid] = True
                V.sleep_entry[cid] = self.SLEEP_ENTRY_CYCLES
            else:  # pragma: no cover
                raise ValueError(op.kind)
            return
        core.scu_accesses += 1
        if op.kind in ("write", "read"):
            value = self.scu.access(core.cid, op.kind, op.addr, op.data)
            core.pending = None
            core.resume_value = value if value is not None else 0
            core.state = CoreState.ACTIVE
        elif op.kind == "elw":
            # Trigger the addressed extension exactly once per elw
            # transaction (FSM trigger-once guard, Sec. 5).
            self.scu.elw_trigger(core.cid, op.addr, op.data)
            core.elw_issued = True
            # Grant withheld for now; if the event is already buffered
            # the phase-4 poll grants in this same cycle with no
            # power management ("to not waste any cycles", Sec. 5).
            core.sleep_entry = self.SLEEP_ENTRY_CYCLES
        else:  # pragma: no cover
            raise ValueError(op.kind)

    def _wake_one(self, core: _Core) -> None:
        granted, value = self.scu.elw_poll(core.cid, core.pending.addr)
        if granted:
            V = self._vec
            if V is not None:
                # SoA fast path: immediate grants skip the clock-gate entry
                # latency but still pay grant + response + resume
                cid = core.cid
                never_slept = V.state[cid] == _STALL_SCU
                core.pending = None
                core.resume_value = value
                V.elw[cid] = False
                V.state[cid] = _WAKING
                V.wake[cid] = (
                    self.WAKE_CYCLES - 1 if never_slept else self.WAKE_CYCLES
                )
                return
            never_slept = core.state is CoreState.STALL_SCU
            core.pending = None
            core.elw_issued = False
            core.resume_value = value
            core.state = CoreState.WAKING
            # Immediate grants skip the clock-gate entry latency but still
            # pay grant + response + resume.
            core.wake_countdown = (
                self.WAKE_CYCLES - 1 if never_slept else self.WAKE_CYCLES
            )

    def _wake_cores(self) -> None:
        """Phase 4: poll every in-flight elw against the event buffers."""
        for core in self.cores:
            if core.pending is None or not core.elw_issued:
                continue
            if core.state not in (CoreState.STALL_SCU, CoreState.SLEEP):
                continue
            self._wake_one(core)

    # ------------------------------------------------------------- helpers
    def poke(self, addr: int, value: int) -> None:
        self.tcdm[addr] = value

    def peek(self, addr: int) -> int:
        return self.tcdm.get(addr, 0)


# ---------------------------------------------------------------------------
# Batched fleet simulation: many independent clusters, one array program
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetConfig:
    """One member of a batched fleet run: a cluster plus its programs.

    The cluster must be freshly constructed (``mode="fastforward"``, not yet
    loaded or run); :func:`simulate_fleet` loads ``programs`` itself so the
    per-core state lands in the fleet's flattened arrays.
    """

    cluster: Cluster
    programs: List[Program]
    max_cycles: int = 10_000_000


# sentinel for "no internal event due" in the segment-min reductions
_NO_BOUND = np.int64(1) << 60


class _FleetMember:
    """Bookkeeping of one config inside the fleet's flattened state.

    ``index`` is the member's segment id: the position in the config list
    for the static fleet (:class:`_Fleet`), the slot id for the
    slot-recycling fleet (:class:`SlotFleet`).  ``error`` stays ``None``
    except in slot mode, where a member that burns to its ``max_cycles``
    cap is marked failed instead of aborting the whole fleet.
    """

    __slots__ = ("index", "cluster", "max_cycles", "sl", "off", "done", "error")

    def __init__(self, index: int, cfg: FleetConfig, off: int):
        self.index = index
        self.cluster = cfg.cluster
        self.max_cycles = cfg.max_cycles
        self.off = off
        self.sl = slice(off, off + cfg.cluster.n_cores)
        self.done = False
        self.error: Optional[str] = None


def _check_fleet_config(cfg: FleetConfig, label: str, needs: str) -> None:
    """Shared admission validation of the static and slot-recycling fleets."""
    cl = cfg.cluster
    if cl.mode != "fastforward":
        raise ValueError(
            f"{label}: cluster mode must be 'fastforward', got {cl.mode!r}"
        )
    if len(cfg.programs) != cl.n_cores:
        raise ValueError(
            f"{label}: {len(cfg.programs)} programs for {cl.n_cores} cores"
        )
    if cl.cycle != 0 or cl.cores:
        raise ValueError(
            f"{label}: cluster already loaded or run; "
            f"{needs} needs a fresh cluster"
        )
    if cl.n_cores < 1:
        raise ValueError(f"{label}: cluster has no cores")


class _FleetEngine:
    """Shared flattened-array core of the two fleet dispatchers.

    Owns nothing itself -- subclasses allocate the flattened state
    (:class:`_Fleet` packs variable-size segments back to back;
    :class:`SlotFleet` uses fixed-width recyclable slots) and this base
    provides the member-attachment protocol plus the scheduling round:
    per-segment bound/spin reductions, the vectorized multi-span jump and
    the batched full step.  Every method here treats ``self.members`` as a
    list indexed by segment id (entries may be ``None`` in slot mode).

    Required fields (populated by the subclass):

    ``_vec``/``_rr``/``seg``/``local_cid``/``cfg_n``/``bank_base``/
    ``seg_offsets`` -- the flattened scheduler state and geometry;
    ``ev_buf``/``ev_mask``/``irq_mask``/``ntf_target``/``elw_wait`` -- the
    flattened SCU base-unit registers; ``_step_mask``/``_span_buf`` --
    reused scratch; ``members``/``_no_spin``/``_cl_list``/``_core_list``/
    ``_lcid_list`` -- per-segment and per-lane lookup tables.
    """

    # ------------------------------------------------------------ attachment
    def _attach_member(
        self, m: "_FleetMember", cfg: FleetConfig, bank_off: int
    ) -> None:
        """Adopt one member cluster's state into the flattened arrays.

        After this, the member's own engine code (generator advances, SCU
        servicing, the spin resolver) runs unchanged on *views* of the
        fleet-level storage -- the view is the segment partition.  The
        slot-recycling fleet calls this at admission time on a freshly
        zeroed segment; the static fleet calls it once per config at
        construction."""
        cl = m.cluster
        sl = m.sl
        cl.vectorized = True
        cl._vec = _VecState.view_of(self._vec, sl)
        cl._rr = self._rr[bank_off:bank_off + cl.n_banks]
        cl.max_cycles = m.max_cycles
        if cl.scu is not None:
            cl.scu.adopt_views(
                self.ev_buf[sl], self.ev_mask[sl], self.irq_mask[sl],
                self.ntf_target[sl], self.elw_wait[sl],
            )
        cl.cores = [
            _VecCore(i, prog(cl, i), cl._vec)
            for i, prog in enumerate(cfg.programs)
        ]
        cl.stats = ClusterStats()
        cl._n_done = 0

    # ------------------------------------------------------------ scheduling
    def _on_timeout(self, m: "_FleetMember") -> None:
        """A member hit its ``max_cycles`` cap.  The static fleet aborts the
        whole run (matching ``Cluster.run``); the slot fleet overrides this
        to mark the member failed so co-resident jobs keep running."""
        m.cluster._raise_timeout(m.max_cycles)

    def _round(self, live: List["_FleetMember"]) -> List["_FleetMember"]:
        """One scheduling round over the ``live`` members: per-segment
        bound/spin reductions in one flattened pass, then every member
        either jumps its own quiescent span, batch-resolves a spin phase,
        or joins the batched full step.  Returns the members that finished
        (or, in slot mode, failed) this round, with ``done`` set."""
        V = self._vec
        st = V.state
        offs = self.seg_offsets
        # -- per-config bounds + spin eligibility (one flattened pass,
        #    segment reductions instead of N per-member scans).  Cores of
        #    finished members and empty slots are all DONE, so no live-mask
        #    is needed: every state test below excludes them already.
        active = st == _ACTIVE
        waking = st == _WAKING
        stalled = st == _STALL_MEM
        stall_scu = st == _STALL_SCU
        sleeping = st == _SLEEP
        if sleeping.any():
            sleep_grant = sleeping & (
                (self.ev_buf & self.elw_wait) != 0
            )
        else:
            sleep_grant = sleeping
        adv_due = active & (V.busy <= 0)
        wake_due = waking & (V.wake <= 1)
        need = stalled | stall_scu
        need |= adv_due
        need |= wake_due
        need |= sleep_grant
        seg_need = np.logical_or.reduceat(need, offs).tolist()
        # one fused countdown-min reduction: busy for active cores,
        # wake-1 for waking cores, +inf sentinel otherwise
        countdown = np.where(
            active, V.busy, np.where(waking, V.wake - 1, _NO_BOUND)
        )
        seg_bound = np.minimum.reduceat(countdown, offs).tolist()
        # spin-phase eligibility, mirroring _spin_participants_vec: the
        # participants (armed Polls queued or in their retry shadow) and
        # the disqualifiers, reduced per segment
        if V.has_poll.any():
            part = V.has_poll & (stalled | active)
            spin_bad = stall_scu | (stalled & ~V.has_poll)
            spin_bad |= adv_due & ~part
            spin_bad |= wake_due
            spin_bad |= sleep_grant
            seg_spin = (
                np.logical_or.reduceat(part, offs)
                & ~np.logical_or.reduceat(spin_bad, offs)
            ).tolist()
        else:
            part = None
            seg_spin = self._no_spin

        jumps: List[Tuple[_FleetMember, int]] = []
        stepping: List[_FleetMember] = []
        finished: List[_FleetMember] = []
        for m in live:
            cl = m.cluster
            if cl.cycle >= m.max_cycles:
                self._on_timeout(m)  # static fleet: raises
                m.done = True
                finished.append(m)
                continue
            g = m.index
            if seg_need[g]:
                scu = cl.scu
                if (
                    seg_spin[g]
                    and (scu is None or scu.next_event_bound() is None)
                    and cl._resolve_spin_phase(np.flatnonzero(part[m.sl]))
                ):
                    continue
                stepping.append(m)
                continue
            b = seg_bound[g]
            scu = cl.scu
            if scu is not None:
                sb = scu.next_event_bound()
                if sb is not None:
                    if sb <= 0:
                        stepping.append(m)
                        continue
                    b = min(b, sb)
            if cl.faults is not None:
                fb = cl.faults.next_event_bound(cl.cycle)
                if fb is not None:
                    if fb <= 0:
                        stepping.append(m)
                        continue
                    b = min(b, fb)
            if b >= _NO_BOUND:
                # deadlock: no internal event in sight -- burn to the
                # cap so the failure matches the sequential engine
                b = m.max_cycles - cl.cycle
            jumps.append((m, min(b, m.max_cycles - cl.cycle)))

        if jumps:
            self._jump(jumps)
        if stepping:
            self._step(stepping)
            for m in stepping:
                err = m.cluster._watchdog_error()
                if err is not None:
                    self._on_deadlock(m, err)  # static fleet: raises
                    m.done = True
                    finished.append(m)
                    continue
                if m.cluster._n_done >= m.cluster.n_cores:
                    m.done = True
                    finished.append(m)
        return finished

    def _on_deadlock(self, m: "_FleetMember", err: "DeadlockError") -> None:
        """A member's watchdog tripped.  The static fleet aborts the run
        (matching ``Cluster.run``); the slot fleet contains the failure."""
        raise err

    # ----------------------------------------------------------------- jump
    def _jump(self, jumps: List[Tuple["_FleetMember", int]]) -> None:
        """Per-config quiescent-span jumps, one vectorized update.

        Every member jumps by its *own* bound (members sit at different
        local cycles); exactness per member follows from
        :meth:`Cluster.fast_forward` -- the span never exceeds the
        segment's proven bound."""
        V = self._vec
        st = V.state
        span = self._span_buf
        span[:] = 0
        for m, s in jumps:
            span[m.sl] = s
        # elementwise span products instead of fancy indexing: non-jumping
        # members carry span 0, so the unmasked updates are exact
        a_span = span * (st == _ACTIVE)
        w_span = span * (st == _WAKING)
        V.busy -= a_span
        V.wake -= w_span
        C = V.counters
        C["active_cycles"] += a_span
        C["active_cycles"] += w_span
        C["comp_cycles"] += a_span
        C["wait_cycles"] += w_span
        C["gated_cycles"] += span * (st == _SLEEP)
        for m, s in jumps:
            cl = m.cluster
            cl.cycle += s
            cl.ff_spans += 1
            cl.ff_cycles += s

    # ----------------------------------------------------------------- step
    def _step(self, stepping: List["_FleetMember"]) -> None:
        """One batched full cluster step over every member in ``stepping``.

        Phase order and semantics are identical to
        :meth:`Cluster._step_vec`, with every kernel masked to the stepping
        members' cores and the idiosyncratic transitions (generator
        advances, grants, SCU servicing) delegated to the member cluster --
        whose state lives in the same arrays."""
        V = self._vec
        st = V.state
        members = self.members
        cls_l = self._cl_list
        cores_l = self._core_list
        lcid_l = self._lcid_list
        mask = self._step_mask
        mask[:] = False
        for m in stepping:
            mask[m.sl] = True

        # Phase 0: injected upsets, then per-config extension comparators
        # (armed sets checked inline: a disarmed SCU's evaluate is a
        # guaranteed no-op -- unless a watchdog deadline is due, which
        # fires from inside evaluate).
        for m in stepping:
            cl = m.cluster
            if cl.faults is not None:
                cl.faults.apply(cl)
            scu = cl.scu
            if scu is not None and (
                scu._armed_barriers or scu._armed_mutexes or scu._armed_fifos
                or (scu.watchdog is not None and scu.watchdog_due(cl.cycle))
            ):
                cl.stats.scu_events += scu.evaluate(cl.cycle)

        # Phase 1a: countdowns (vectorized across configs; bool subtraction
        # instead of fancy indexing -- non-stepping cores subtract 0).
        active = st == _ACTIVE
        active &= mask
        counting = V.busy > 0
        counting &= active
        V.busy -= counting
        waking = st == _WAKING
        waking &= mask
        V.wake -= waking
        gating = st == _STALL_SCU
        gating &= V.elw
        gating &= mask
        if gating.any():
            V.sleep_entry -= gating
            gated = V.sleep_entry <= 0
            gated &= gating
            st[gated] = _SLEEP

        # Phase 1b: Poll re-issues (vectorized: an ACTIVE core with an armed
        # Poll and no busy left re-enters its bank queue -- the only way a
        # core sits ACTIVE with a pending op) and generator advances
        # (scalar; WAKING cores reaching 0 always advance, their pending was
        # consumed by the wake).
        CB = V.counter_block
        adv = active ^ counting  # active with no busy left (counting
        reissue = adv & V.has_poll  # is a subset of active, so xor == and-not)
        if reissue.any():
            st[reissue] = _STALL_MEM
            CB[_C_INSTR] += reissue
            adv ^= reissue
        wdue = V.wake <= 0
        wdue &= waking
        if wdue.any():
            st[wdue] = _ACTIVE
            adv |= wdue
        for g in np.nonzero(adv)[0].tolist():
            core = cores_l[g]
            cls_l[g]._advance(core, core.resume_value)

        # Phase 2: TCDM / LINT arbitration -- one lexsort across the
        # fleet's banks (bank ids offset per config, round-robin keys taken
        # modulo each config's own core count).
        req = np.nonzero(mask & (st == _STALL_MEM))[0]
        if req.size:
            blk_banks: Optional[List[int]] = None
            for m in stepping:
                f = m.cluster.faults
                if f is not None:
                    bb = f.blacked_banks(m.cluster.cycle)
                    if bb:
                        base = int(self.bank_base[m.off])
                        if blk_banks is None:
                            blk_banks = []
                        blk_banks.extend(base + b for b in bb)
            if blk_banks:
                gb = self.bank_base[req] + V.pend_bank[req]
                req = req[~np.isin(gb, blk_banks)]
        if req.size:
            gbank = self.bank_base[req] + V.pend_bank[req]
            key = (self.local_cid[req] - self._rr[gbank]) % self.cfg_n[req]
            order = np.lexsort((key, gbank))
            sorted_banks = gbank[order]
            first = np.ones(order.size, dtype=bool)
            first[1:] = sorted_banks[1:] != sorted_banks[:-1]
            winners = req[order[first]]
            if winners.size != req.size:
                n_req = np.bincount(self.seg[req], minlength=len(members))
                n_win = np.bincount(self.seg[winners], minlength=len(members))
                for m in stepping:
                    d = int(n_req[m.index] - n_win[m.index])
                    if d:
                        m.cluster.stats.bank_conflicts += d
            for g in winners.tolist():
                cl = cls_l[g]
                cid = lcid_l[g]
                cl._rr[cl._vec.pend_bank[cid]] = (cid + 1) % cl.n_cores
                cl._grant_mem_vec(cid)

        # Phase 3 + 4: SCU private links and elw grant scans.  ``stall_scu``
        # is sampled before servicing; that is safe for the pending scan
        # because a serviced read/write leaves ACTIVE with ``elw`` False and
        # the ``&= V.elw`` filter drops it.
        stall_scu = st == _STALL_SCU
        fresh = stall_scu & ~V.elw
        fresh &= mask
        for g in np.nonzero(fresh)[0].tolist():
            cls_l[g]._service_one(cores_l[g])
        if V.elw.any():
            pending = stall_scu | (st == _SLEEP)
            pending &= V.elw
            pending &= mask
            granted = (self.ev_buf & self.elw_wait) != 0
            granted &= pending
            for g in np.nonzero(granted)[0].tolist():
                cls_l[g]._wake_one(cores_l[g])

        # Phase 5: accounting (one state-code table gather, see _ACCT_INC;
        # non-stepping cores read the all-zero DONE column).
        stm = np.where(mask, st, _DONE)
        V.counter_block[:5] += _ACCT_INC[:, stm]
        for m in stepping:
            m.cluster.cycle += 1


class _Fleet(_FleetEngine):
    """The fleet engine: N independent clusters on one flattened SoA core.

    Every member cluster's scheduler state (:class:`_VecState`), round-robin
    pointers and SCU base-unit registers become *views* into fleet-level
    arrays laid out along a flattened ``(config, core)`` axis -- per-config
    segments partition TCDM bank arbitration, SCU registers, armed-extension
    sets and the ``next_event_bound()`` reduction, so configs never interact
    (each keeps its own TCDM dict, SCU instance, stats and local clock).

    The run loop generalizes :meth:`Cluster._run_fast` per segment:

    * per-config quiescent bounds come from segment-min reductions over the
      flattened arrays (one ``np.minimum.reduceat`` instead of N bound
      scans), and the global jump becomes a **per-config span jump** --
      members at different local cycles advance by their own bound in one
      vectorized update;
    * members whose bound is 0 first try their own spin-phase batch
      resolver (tier 2, unchanged -- it operates on the views), then join
      one **batched full step** whose phase kernels run over the cores of
      every stepping config at once -- this is what makes 8-core configs
      vectorizable for the first time (64 eight-core clusters = one
      512-lane array program);
    * members that finish early are masked out of every kernel.

    Each tier is individually exact (a full step *is* the reference
    semantics; any jump up to the bound is exact; the spin resolver is
    exact), so per-config results are bit-identical to a one-at-a-time
    ``Cluster.run()`` -- enforced by the fleet parity suite in
    ``tests/test_scu_simulator.py``.
    """

    def __init__(self, configs: List[FleetConfig]):
        self.members: List[_FleetMember] = []
        total = 0
        total_banks = 0
        for i, cfg in enumerate(configs):
            _check_fleet_config(cfg, f"fleet member {i}", "simulate_fleet")
            cl = cfg.cluster
            self.members.append(_FleetMember(i, cfg, total))
            total += cl.n_cores
            total_banks += cl.n_banks
        self.total = total

        # flattened (config, core) state + per-core constants
        self._vec = _VecState(total)
        self._rr = np.zeros(total_banks, dtype=np.int64)
        self.seg = np.zeros(total, dtype=np.int64)  # member index per core
        self.local_cid = np.zeros(total, dtype=np.int64)
        self.cfg_n = np.zeros(total, dtype=np.int64)  # member n_cores per core
        self.bank_base = np.zeros(total, dtype=np.int64)
        self.seg_offsets = np.zeros(len(self.members), dtype=np.int64)
        # flattened SCU base-unit registers + latched elw wait masks
        self.ev_buf = np.zeros(total, dtype=np.int64)
        self.ev_mask = np.zeros(total, dtype=np.int64)
        self.irq_mask = np.zeros(total, dtype=np.int64)
        self.ntf_target = np.zeros(total, dtype=np.int64)
        self.elw_wait = np.zeros(total, dtype=np.int64)
        self._step_mask = np.zeros(total, dtype=bool)  # reused per step
        self._span_buf = np.zeros(total, dtype=np.int64)  # reused per jump
        self._no_spin = [False] * len(self.members)  # shared, never mutated

        bank_off = 0
        for m, cfg in zip(self.members, configs):
            cl = m.cluster
            sl = m.sl
            n = cl.n_cores
            self.seg[sl] = m.index
            self.local_cid[sl] = np.arange(n)
            self.cfg_n[sl] = n
            self.bank_base[sl] = bank_off
            self.seg_offsets[m.index] = m.off
            # adopt the member's state into the fleet arrays: the member's
            # engine code keeps running unchanged on these views
            self._attach_member(m, cfg, bank_off)
            bank_off += cl.n_banks
        # plain-int lookup tables for the scalar loops (indexing a numpy
        # array with a Python int and converting is ~5x the list cost)
        self._lcid_list = self.local_cid.tolist()
        # per-core cluster + core-object tables: one list index from a
        # flattened core id to the owning member's state
        self._cl_list = [
            m.cluster for m in self.members for _ in range(m.cluster.n_cores)
        ]
        self._core_list = [c for m in self.members for c in m.cluster.cores]

    # ------------------------------------------------------------------ run
    def run(self) -> List[ClusterStats]:
        try:
            self._run()
        finally:
            for m in self.members:
                cl = m.cluster
                cl.stats.cycles = cl.cycle
                cl.stats.cores = [c.stats for c in cl.cores]
        return [m.cluster.stats for m in self.members]

    def _run(self) -> None:
        live = list(self.members)  # zero-core members rejected at build time
        while live:
            if self._round(live):
                live = [m for m in live if not m.done]


class SlotFleet(_FleetEngine):
    """Slot-recycling fleet: a fixed lane geometry that admits jobs mid-run.

    Where :class:`_Fleet` packs a *fixed* config list into back-to-back
    segments and drains them all, this engine owns ``n_slots`` recyclable
    segments of ``slot_cores`` lanes each and exposes an incremental API:

    * :meth:`admit` binds a fresh :class:`FleetConfig` (``n_cores <=
      slot_cores``) into the lowest free slot -- the same view adoption as
      the static fleet (:meth:`_FleetEngine._attach_member`), on freshly
      scrubbed lanes;
    * :meth:`advance` runs **one scheduling round** over every occupied
      slot and returns the members that completed (or failed) in it, with
      their :class:`ClusterStats` already materialized -- safe to read
      after the slot is recycled;
    * :meth:`free` scrubs a finished member's lanes back to ``DONE`` and
      returns the slot to the free list, ready for the next admission.

    Empty lanes (free slots, and the tail of a slot running a job narrower
    than ``slot_cores``) sit in the ``DONE`` state, whose column in every
    flattened kernel is neutral: segment reductions see ``+inf`` bounds and
    no needs, jumps multiply them by span 0, the step's accounting gather
    reads the all-zero ``DONE`` column.  That is what makes admission
    timing invisible to co-residents -- a job admitted while another slot
    is mid-quiescent-span neither shortens nor lengthens that span, it just
    changes which *scheduler round* resolves each event.  Per-member
    results therefore stay bit-exact against one-at-a-time ``Cluster.run()``
    calls regardless of what shared a step with them (enforced by the
    service parity suite in ``tests/test_fleet_service.py``).

    Deadlock/timeout semantics match :func:`simulate_fleet` per member: a
    member with no internal event in sight burns to its ``max_cycles`` cap
    and is then marked **failed** -- ``member.error`` carries the exact
    message ``Cluster.run`` would have raised -- instead of aborting the
    fleet, so co-resident jobs are unaffected.
    """

    def __init__(
        self, n_slots: int, slot_cores: int, banking_factor: int = 2
    ):
        if n_slots < 1 or slot_cores < 1:
            raise ValueError("SlotFleet needs at least one slot and one lane")
        self.n_slots = n_slots
        self.slot_cores = slot_cores
        self.slot_banks = banking_factor * slot_cores
        total = n_slots * slot_cores
        self.total = total

        # flattened (slot, lane) state -- fixed geometry, recycled in place
        self._vec = _VecState(total)
        self._vec.state[:] = _DONE  # every empty lane is neutral
        self._rr = np.zeros(n_slots * self.slot_banks, dtype=np.int64)
        self.seg = np.repeat(np.arange(n_slots, dtype=np.int64), slot_cores)
        self.local_cid = np.tile(
            np.arange(slot_cores, dtype=np.int64), n_slots
        )
        self.cfg_n = np.ones(total, dtype=np.int64)  # 1 on empty lanes: no %0
        self.bank_base = np.repeat(
            np.arange(n_slots, dtype=np.int64) * self.slot_banks, slot_cores
        )
        self.seg_offsets = (
            np.arange(n_slots, dtype=np.int64) * slot_cores
        )
        # flattened SCU base-unit registers + latched elw wait masks
        self.ev_buf = np.zeros(total, dtype=np.int64)
        self.ev_mask = np.zeros(total, dtype=np.int64)
        self.irq_mask = np.zeros(total, dtype=np.int64)
        self.ntf_target = np.zeros(total, dtype=np.int64)
        self.elw_wait = np.zeros(total, dtype=np.int64)
        self._step_mask = np.zeros(total, dtype=bool)
        self._span_buf = np.zeros(total, dtype=np.int64)
        self._no_spin = [False] * n_slots

        # slot directory: members[slot] is None while the slot is free
        self.members: List[Optional[_FleetMember]] = [None] * n_slots
        self._free: List[int] = list(range(n_slots))  # kept sorted
        self._lcid_list = self.local_cid.tolist()
        self._cl_list: List[Optional[Cluster]] = [None] * total
        self._core_list: List[Optional[_VecCore]] = [None] * total

    # ------------------------------------------------------------- occupancy
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def occupied(self) -> int:
        return self.n_slots - len(self._free)

    # ------------------------------------------------------------------ admit
    def validate(self, cfg: FleetConfig) -> None:
        """Admission checks without claiming a slot (queue-time screening).

        Same config checks as :func:`simulate_fleet` plus the slot-width
        fit; raises ``ValueError`` on the first violation."""
        _check_fleet_config(cfg, "slot fleet job", "SlotFleet.admit")
        cl = cfg.cluster
        if cl.n_cores > self.slot_cores:
            raise ValueError(
                f"slot fleet job: {cl.n_cores} cores exceed the "
                f"{self.slot_cores}-lane slot width"
            )
        if cl.n_banks > self.slot_banks:
            raise ValueError(
                f"slot fleet job: {cl.n_banks} banks exceed the "
                f"{self.slot_banks}-bank slot range"
            )

    def admit(self, cfg: FleetConfig) -> int:
        """Bind a fresh config into the lowest free slot; returns the slot id.

        Raises ``ValueError`` on an invalid config (same checks as
        :func:`simulate_fleet`, plus the slot-width fit) and
        ``RuntimeError`` when no slot is free -- check :attr:`free_slots`
        first; queueing policy belongs to the caller (see
        ``repro.serve.fleet_service``)."""
        self.validate(cfg)
        slot = self._claim()
        return self._bind(slot, cfg)

    def _claim(self, slot: Optional[int] = None) -> int:
        """Take a slot off the free list (the lowest, or a specific one)."""
        if not self._free:
            raise RuntimeError("SlotFleet.admit: no free slot")
        if slot is None:
            return self._free.pop(0)
        try:
            self._free.remove(slot)
        except ValueError:
            raise RuntimeError(
                f"SlotFleet: slot {slot} is not free"
            ) from None
        return slot

    def _bind(self, slot: int, cfg: FleetConfig) -> int:
        """Scrub ``slot``'s lanes and attach ``cfg`` (the admit body --
        restore reuses this path verbatim, so a restored member lands on
        exactly the residue-free lane state a fresh admission gets)."""
        cl = cfg.cluster
        off = slot * self.slot_cores
        full = slice(off, off + self.slot_cores)

        # scrub the whole slot: the previous occupant may have timed out
        # mid-SLEEP/STALL and view adoption only overwrites the SCU
        # registers, not the scheduler lanes
        V = self._vec
        V.state[full] = _DONE
        V.busy[full] = 0
        V.wake[full] = 0
        V.sleep_entry[full] = 0
        V.pend_bank[full] = -1
        V.has_poll[full] = False
        V.elw[full] = False
        V.counter_block[:, full] = 0
        self.ev_buf[full] = 0
        self.ev_mask[full] = 0
        self.irq_mask[full] = 0
        self.ntf_target[full] = 0
        self.elw_wait[full] = 0
        self.cfg_n[full] = 1
        bank_off = slot * self.slot_banks
        self._rr[bank_off:bank_off + self.slot_banks] = 0

        m = _FleetMember(slot, cfg, off)
        n = cl.n_cores
        self.cfg_n[m.sl] = n
        self._attach_member(m, cfg, bank_off)
        V.state[m.sl] = _ACTIVE  # lanes join the flattened passes now
        self.members[slot] = m
        for i in range(n):
            self._cl_list[off + i] = cl
            self._core_list[off + i] = cl.cores[i]
        return slot

    # ------------------------------------------------------------------ free
    def free(self, slot: int) -> None:
        """Recycle a finished (or failed) member's slot.

        The member's stats were materialized when :meth:`advance` returned
        it; after this call its lanes are ``DONE`` and the slot is back on
        the free list."""
        m = self.members[slot]
        if m is None:
            raise ValueError(f"SlotFleet.free: slot {slot} is already free")
        if not m.done:
            raise ValueError(f"SlotFleet.free: slot {slot} is still running")
        off = slot * self.slot_cores
        full = slice(off, off + self.slot_cores)
        V = self._vec
        # back to the neutral lane state (a timed-out member can leave
        # SLEEP/STALL lanes and latched elw waits behind)
        V.state[full] = _DONE
        V.has_poll[full] = False
        V.elw[full] = False
        self.ev_buf[full] = 0
        self.elw_wait[full] = 0
        self.cfg_n[full] = 1
        for i in range(off, off + self.slot_cores):
            self._cl_list[i] = None
            self._core_list[i] = None
        self.members[slot] = None
        bisect.insort(self._free, slot)

    # ----------------------------------------------------- checkpoint/restore
    def snapshot(self, slot: int):
        """Checkpoint the member in ``slot`` at the current round boundary.

        Non-destructive: the member keeps running.  Returns a
        :class:`repro.core.scu.checkpoint.MemberCheckpoint`; raises
        :class:`~repro.core.scu.checkpoint.NotCheckpointable` when the
        member runs generator-backed programs (callers fall back to
        restart) and ``ValueError`` on a free or finished slot."""
        from .checkpoint import capture_cluster

        m = self.members[slot]
        if m is None:
            raise ValueError(f"SlotFleet.snapshot: slot {slot} is free")
        if m.done:
            raise ValueError(
                f"SlotFleet.snapshot: slot {slot} already finished"
            )
        return capture_cluster(m.cluster)

    def suspend(self, slot: int):
        """Snapshot the member in ``slot`` and evict it (preemption).

        The slot is scrubbed and returned to the free list; the returned
        checkpoint resumes the job later via :meth:`restore` -- in this
        fleet or any other wide enough."""
        ckpt = self.snapshot(slot)
        m = self.members[slot]
        m.done = True  # free() refuses live members; this one is suspended
        self.free(slot)
        return ckpt

    def restore(self, ckpt, slot: Optional[int] = None, faults="carry"):
        """Re-admit a checkpointed member; returns the slot id.

        Runs the exact admission scrub+attach path on the lowest free slot
        (or a specific free ``slot``), then overwrites the fresh member
        with the checkpointed scheduler/SCU/TCDM state -- restore into any
        slot of any fleet is residue-free by construction.  ``faults``
        forwards to :func:`repro.core.scu.checkpoint.resume_config`:
        ``"carry"`` resumes the checkpointed :class:`FaultPlan` cursor,
        ``None`` strips it (live migration to a healthy domain), a plan
        overrides."""
        from .checkpoint import apply_cluster_state, resume_config

        cfg = resume_config(ckpt, faults=faults)
        self.validate(cfg)
        slot = self._claim(slot)
        self._bind(slot, cfg)
        apply_cluster_state(self.members[slot].cluster, ckpt)
        return slot

    # --------------------------------------------------------------- advance
    def advance(self) -> List[_FleetMember]:
        """One scheduling round over every occupied slot.

        Returns the members that completed this round (``error`` set on the
        ones that hit their ``max_cycles`` cap), with ``ClusterStats``
        materialized -- the caller reads ``member.cluster.stats`` and then
        :meth:`free`\\ s the slot.  A fleet with no live member returns
        ``[]`` without touching the arrays."""
        live = [m for m in self.members if m is not None and not m.done]
        if not live:
            return []
        finished = self._round(live)
        for m in finished:
            cl = m.cluster
            cl.stats.cycles = cl.cycle
            cl.stats.cores = [c.stats for c in cl.cores]
        return finished

    def _on_timeout(self, m: _FleetMember) -> None:
        # capture exactly the message Cluster.run would have raised, but
        # contain the failure to this member
        try:
            m.cluster._raise_timeout(m.max_cycles)
        except RuntimeError as e:
            m.error = str(e)

    def _on_deadlock(self, m: _FleetMember, err: DeadlockError) -> None:
        # same containment for watchdog trips: the member is failed, the
        # co-resident jobs keep running
        m.error = str(err)


def simulate_fleet(configs: List[FleetConfig]) -> List[ClusterStats]:
    """Run N independent cluster configurations as one batched array program.

    Stacks the configs onto the structure-of-arrays engine core along a
    flattened ``(config, core)`` axis (see :class:`_Fleet`); results are
    **bit-exact per config** against one-at-a-time ``Cluster.run()`` calls.
    Empty-handed configs (``n_cores == 0``) are not supported; an empty
    ``configs`` list returns ``[]``.

    Use this for sweeps: a fleet of 64 eight-core clusters is a 512-lane
    array program, amortizing the per-step kernel overhead that makes
    individually-run 8-core clusters fall below the vectorization threshold
    (:attr:`Cluster.VEC_MIN_CORES`).
    """
    if not configs:
        return []
    return _Fleet(list(configs)).run()
