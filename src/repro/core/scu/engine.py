"""Cycle-accurate discrete-event engine for the shared-L1 multiprocessor cluster.

This is the Tier-1, paper-faithful model of the system evaluated in

    Glaser et al., "Energy-Efficient Hardware-Accelerated Synchronization for
    Shared-L1-Memory Multiprocessor Clusters" (2020).

The cluster consists of

  * ``n_cores`` in-order single-issue PEs (1 op/cycle when not stalled),
  * a word-interleaved multi-banked TCDM (banking factor 2 by default) behind a
    single-cycle logarithmic interconnect (LINT) with per-bank round-robin
    arbitration and native 3-cycle test-and-set (TAS) transactions,
  * the SCU: per-core base units (32 event lines, event buffer, event/irq
    masks, active/sleep/irq FSM, clock-enable control) reached over private
    single-cycle core<->SCU links, plus shared extensions (notifier, barrier,
    mutex, event FIFO) -- see :mod:`repro.core.scu.scu_unit` and
    :mod:`repro.core.scu.extensions`.

Programs are Python generators that yield micro-ops (:class:`Compute`,
:class:`Mem`, :class:`Scu`); the engine resolves arbitration, SCU event
generation, sleep/wake-up sequencing and clock gating exactly as described in
Sec. 4/5 and Fig. 4 of the paper.

Accounting distinguishes *active* core cycles (clock enabled) from *gated*
cycles -- the quantity behind the paper's energy results.

Two execution modes produce bit-exact identical :class:`ClusterStats`:

``mode="lockstep"``
    The reference model: :meth:`Cluster.step` advances the whole cluster one
    clock cycle at a time, evaluating every phase every cycle.

``mode="fastforward"`` (default)
    Event-driven fast path.  Between steps the scheduler computes
    :meth:`Cluster.next_event_bound` -- a provably-safe number of cycles
    during which *nothing observable can happen*: every core is either
    burning a :class:`Compute` span (``busy`` countdown), clock-gated asleep
    with no buffered wake event, or inside its wake countdown, and no SCU
    extension comparator can fire without a new core transaction
    (:meth:`repro.core.scu.scu_unit.SCU.next_event_bound`).  The engine then
    jumps the clock by that whole span, accounting per-core stats in
    O(n_cores) per span instead of O(n_cores) per cycle.  Quiescent regions
    (large SFRs, clock-gated waits under the SCU) dominate realistic
    workloads, so this is orders of magnitude faster; any cycle in which an
    arbiter, SCU grant, or comparator could act is executed through the same
    :meth:`Cluster.step` as lockstep mode, so the two modes agree cycle-for-
    cycle (enforced by ``tests/test_scu_simulator.py`` golden + cross-check
    tests).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple

__all__ = [
    "Compute",
    "Mem",
    "Scu",
    "CoreState",
    "CoreStats",
    "ClusterStats",
    "Cluster",
    "Program",
]


# ---------------------------------------------------------------------------
# Micro-ops yielded by core programs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Compute:
    """``cycles`` of core-local work (ALU/regfile only, no memory traffic)."""

    cycles: int


@dataclasses.dataclass
class Mem:
    """A TCDM transaction through the LINT.

    kind:
      ``lw``  -- load word (single cycle when granted; contention stalls)
      ``sw``  -- store word
      ``tas`` -- atomic test-and-set: returns current value, writes -1.
                 Occupies the bank for :attr:`Cluster.TAS_CYCLES` cycles
                 ("TAS transactions take just three cycles", Sec. 4.1).
    """

    kind: str
    addr: int
    data: int = 0


@dataclasses.dataclass
class Scu:
    """A transaction on the private core<->SCU link (single cycle, Sec. 4.4).

    kind:
      ``elw``   -- event-load-word (Sec. 5): read `addr` in the aliased SCU
                   space; the SCU withholds the grant until a masked-in event
                   is buffered, clock-gating the core meanwhile.  The read
                   response carries extension-specific data.
      ``read``  -- plain (non-blocking) read of an SCU register.
      ``write`` -- plain write (mutex unlock, notifier trigger, mask setup...).
    """

    kind: str
    addr: Any
    data: int = 0


Program = Callable[["Cluster", int], Generator]


class CoreState(enum.Enum):
    ACTIVE = 0  # clock enabled, executing / issuing
    STALL_MEM = 1  # clock enabled, waiting for a TCDM grant
    STALL_SCU = 2  # clock enabled, elw issued, pre-gate window (Fig. 4 left)
    SLEEP = 3  # clock gated by the SCU
    WAKING = 4  # event seen; grant/response sequencing (Fig. 4 right)
    DONE = 5


@dataclasses.dataclass
class CoreStats:
    active_cycles: int = 0  # clock enabled (= comp + wait)
    comp_cycles: int = 0  # clocked and executing/issuing (full core power)
    wait_cycles: int = 0  # clocked but pipeline held (stall/grant/wake)
    gated_cycles: int = 0  # clock gated by the SCU
    stall_cycles: int = 0  # subset of wait: stalled on LINT contention
    instructions: int = 0
    tcdm_accesses: int = 0
    tas_accesses: int = 0
    scu_accesses: int = 0
    finished_at: Optional[int] = None


@dataclasses.dataclass
class ClusterStats:
    cycles: int = 0
    cores: List[CoreStats] = dataclasses.field(default_factory=list)
    bank_conflicts: int = 0
    scu_events: int = 0

    # -- aggregates ---------------------------------------------------------
    @property
    def total_active(self) -> int:
        return sum(c.active_cycles for c in self.cores)

    @property
    def total_comp(self) -> int:
        return sum(c.comp_cycles for c in self.cores)

    @property
    def total_wait(self) -> int:
        return sum(c.wait_cycles for c in self.cores)

    @property
    def total_gated(self) -> int:
        return sum(c.gated_cycles for c in self.cores)

    @property
    def total_tcdm(self) -> int:
        return sum(c.tcdm_accesses for c in self.cores)

    @property
    def total_scu(self) -> int:
        return sum(c.scu_accesses for c in self.cores)


class _Core:
    """Execution context of one PE, including its scheduler state.

    The countdown fields (``busy``, ``wake_countdown``, ``sleep_entry``) are
    the *explicit scheduler state* of the core: between steps they fully
    determine how many cycles the core can advance without interacting with
    any shared resource.  :meth:`quiescent_bound` derives that number and
    :meth:`fast_forward` applies a whole span of it at once (span-based
    accounting); the lockstep path consumes the same state one cycle at a
    time through :meth:`Cluster._issue`.
    """

    __slots__ = (
        "cid",
        "gen",
        "state",
        "busy",
        "pending",
        "resume_value",
        "wake_countdown",
        "sleep_entry",
        "stats",
        "elw_issued",
    )

    def __init__(self, cid: int, gen: Generator):
        self.cid = cid
        self.gen = gen
        self.state = CoreState.ACTIVE
        self.busy = 0  # remaining Compute cycles
        self.pending: Optional[Any] = None  # outstanding Mem/Scu op
        self.resume_value: int = 0  # data returned to the generator
        self.wake_countdown = 0
        self.sleep_entry = 0  # busy-release window before clock gating
        self.stats = CoreStats()
        self.elw_issued = False  # extension trigger-once guard (Sec. 5)

    # ------------------------------------------------------------ scheduler
    def quiescent_bound(self, scu) -> Optional[int]:
        """Cycles this core is guaranteed to spend without any observable
        interaction, or ``None`` for "indefinitely many" (needs an external
        stimulus to make progress).  0 means the core must be stepped.

        Safe bounds per state (mirrors one lockstep :meth:`Cluster._issue`):

        * ``ACTIVE`` with ``busy=k>0`` -- k pure countdown cycles; the
          generator advance happens on the following step.
        * ``WAKING`` with ``wake_countdown=w>1`` -- w-1 countdown cycles; the
          step where the countdown reaches 0 resumes the generator.
        * ``SLEEP`` -- indefinite, unless the waited-on event is already
          buffered (then the phase-4 poll would grant *this* cycle).
        * everything else (``STALL_MEM`` arbitration, ``STALL_SCU`` grant /
          sleep-entry windows, ``busy==0`` advances) -- 0: these transients
          touch shared resources and must run through the full step.
        """
        state = self.state
        if state is CoreState.DONE:
            return None
        if state is CoreState.ACTIVE:
            return self.busy if self.busy > 0 else 0
        if state is CoreState.WAKING:
            return self.wake_countdown - 1 if self.wake_countdown > 1 else 0
        if state is CoreState.SLEEP:
            if self.pending is None or scu is None:  # pragma: no cover
                return 0
            return 0 if scu.elw_would_grant(self.cid, self.pending.addr) else None
        return 0

    def fast_forward(self, span: int) -> None:
        """Advance this core ``span`` quiescent cycles in one O(1) update.

        Only the three states with a positive/indefinite quiescent bound can
        appear here; the stats written are exactly what ``span`` iterations
        of the lockstep phase-5 accounting would have written.
        """
        state = self.state
        if state is CoreState.ACTIVE:
            self.busy -= span
            self.stats.active_cycles += span
            self.stats.comp_cycles += span
        elif state is CoreState.WAKING:
            self.wake_countdown -= span
            self.stats.active_cycles += span
            self.stats.wait_cycles += span
        elif state is CoreState.SLEEP:
            self.stats.gated_cycles += span
        # DONE: no clock, no accounting


class Cluster:
    """The cycle-accurate cluster model.

    Parameters
    ----------
    n_cores:
        Number of PEs (the paper's cluster: 8; SCU supports up to 16).
    banking_factor:
        TCDM banks = ``banking_factor * n_cores`` (paper: 2).
    scu:
        An :class:`repro.core.scu.scu_unit.SCU` instance (constructed by the
        caller so extensions are configurable).  May be ``None`` for purely
        software experiments.
    mode:
        ``"fastforward"`` (default) -- event-driven engine that skips
        quiescent cycles in O(n_cores) spans; ``"lockstep"`` -- the
        cycle-by-cycle reference model.  Both produce bit-exact identical
        :class:`ClusterStats` (see module docstring).
    """

    MODES = ("fastforward", "lockstep")

    TAS_CYCLES = 3  # Sec. 4.1: "TAS transactions take just three cycles"
    # Fig. 4 timing: elw issue -> busy release -> clock gate takes 2 cycles on
    # the way in; event -> clock enable + grant -> response -> resume takes 4
    # cycles on the way out.  Together with the issue and address-setup cycles
    # this yields the paper's 6 active core cycles per handled
    # synchronization point (Sec. 5, Fig. 4).
    SLEEP_ENTRY_CYCLES = 1
    WAKE_CYCLES = 4

    def __init__(
        self,
        n_cores: int,
        scu=None,
        banking_factor: int = 2,
        mode: str = "fastforward",
    ):
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, got {mode!r}")
        self.n_cores = n_cores
        self.n_banks = banking_factor * n_cores
        self.scu = scu
        self.mode = mode
        if scu is not None:
            scu.attach(self)
        self.tcdm: Dict[int, int] = {}
        self._bank_locked_until = [0] * self.n_banks  # TAS write-back lockout
        self._rr = [0] * self.n_banks  # per-bank round-robin pointers
        self.cores: List[_Core] = []
        self._n_done = 0
        self.cycle = 0
        self.stats = ClusterStats()
        self._trace: List[Tuple[int, int, str]] = []
        self.trace_enabled = False
        # fast-forward diagnostics (engine-internal; never part of
        # ClusterStats so the two modes stay bit-exact comparable)
        self.ff_spans = 0  # number of multi-cycle jumps taken
        self.ff_cycles = 0  # cycles covered by those jumps

    # ------------------------------------------------------------------ api
    def load(self, programs: List[Program]) -> None:
        assert len(programs) == self.n_cores
        self.cores = [_Core(i, prog(self, i)) for i, prog in enumerate(programs)]
        self.stats = ClusterStats(cores=[c.stats for c in self.cores])
        self._n_done = 0

    def run(self, max_cycles: int = 10_000_000) -> ClusterStats:
        fast = self.mode == "fastforward"
        while self._n_done < self.n_cores:
            if self.cycle >= max_cycles:
                raise RuntimeError(
                    f"cluster did not finish within {max_cycles} cycles "
                    f"(states: {[c.state.name for c in self.cores]})"
                )
            if fast:
                bound = self.next_event_bound()
                if bound is None:
                    # deadlock: every core is gated with no wake event in
                    # sight -- burn to the cap so the failure mode (and the
                    # cycle count it reports) matches lockstep exactly
                    bound = max_cycles - self.cycle
                if bound > 0:
                    self.fast_forward(min(bound, max_cycles - self.cycle))
                    continue
            self.step()
        self.stats.cycles = self.cycle
        return self.stats

    # ---------------------------------------------------------------- cycle
    def step(self) -> None:
        """Advance the whole cluster by one clock cycle."""
        # Phase 0: extension comparators are registered -- events caused by
        # the *previous* cycle's triggers become visible in the buffers now.
        if self.scu is not None:
            n_ev = self.scu.evaluate(self.cycle)
            self.stats.scu_events += n_ev

        # Phase 1: issue -- every clocked core makes progress / places reqs.
        for core in self.cores:
            self._issue(core)

        # Phase 2: TCDM / LINT arbitration (per-bank round robin).
        self._arbitrate_tcdm()

        # Phase 3: SCU -- private links, elw grant logic, extension triggers.
        if self.scu is not None:
            self._service_scu()

        # Phase 4: pending elw transactions are polled against the buffers.
        if self.scu is not None:
            self._wake_cores()

        # Phase 5: accounting.
        for core in self.cores:
            if core.state is CoreState.DONE:
                continue
            if core.state is CoreState.SLEEP:
                core.stats.gated_cycles += 1
            else:
                core.stats.active_cycles += 1
                if core.state is CoreState.ACTIVE:
                    core.stats.comp_cycles += 1
                else:
                    # clocked but held: LINT stall, elw grant window, wake
                    core.stats.wait_cycles += 1
                    if core.state is CoreState.STALL_MEM:
                        core.stats.stall_cycles += 1
        self.cycle += 1

    # ----------------------------------------------------- fast-forward path
    def next_event_bound(self) -> Optional[int]:
        """Number of cycles that can be skipped before anything observable
        can happen; 0 forces a full :meth:`step`, ``None`` means no internal
        event is ever due (every core gated/done and no comparator armed).

        The bound is the min over the per-core countdown bounds
        (:meth:`_Core.quiescent_bound`) and the SCU extension bound
        (:meth:`repro.core.scu.scu_unit.SCU.next_event_bound`): extensions
        are pure comparators over state written by core transactions, so if
        none can fire now and no core acts, none can fire during the span.
        """
        # cores first: during contention phases the first stalled core
        # short-circuits the scan before any extension comparator is touched
        bound: Optional[int] = None
        scu = self.scu
        for core in self.cores:
            b = core.quiescent_bound(scu)
            if b is None:
                continue
            if b <= 0:
                return 0
            if bound is None or b < bound:
                bound = b
        if scu is not None:
            b = scu.next_event_bound()
            if b is not None:
                if b <= 0:
                    return 0
                if bound is None or b < bound:
                    bound = b
        return bound

    def fast_forward(self, span: int) -> None:
        """Jump ``span`` quiescent cycles: counters and stats advance in one
        O(n_cores) span-based update, no arbitration / SCU phases run (the
        scheduler proved none could act -- see :meth:`next_event_bound`)."""
        for core in self.cores:
            core.fast_forward(span)
        self.cycle += span
        self.ff_spans += 1
        self.ff_cycles += span

    # ------------------------------------------------------------ internals
    def _advance(self, core: _Core, value: int = 0) -> None:
        """Feed ``value`` into the program generator and fetch the next op."""
        try:
            op = core.gen.send(value) if core.stats.instructions else next(core.gen)
        except StopIteration:
            core.state = CoreState.DONE
            core.stats.finished_at = self.cycle
            core.pending = None
            self._n_done += 1
            return
        core.stats.instructions += 1
        if isinstance(op, Compute):
            core.busy = max(0, op.cycles - 1)  # this cycle counts as work
            core.state = CoreState.ACTIVE
            core.pending = None
        elif isinstance(op, Mem):
            core.pending = op
            core.state = CoreState.STALL_MEM
        elif isinstance(op, Scu):
            core.pending = op
            core.state = CoreState.STALL_SCU
        else:  # pragma: no cover - programming error
            raise TypeError(f"bad micro-op {op!r}")

    def _issue(self, core: _Core) -> None:
        if core.state is CoreState.DONE:
            return
        if core.state is CoreState.ACTIVE:
            if core.busy > 0:
                core.busy -= 1
                return
            self._advance(core, core.resume_value)
        elif core.state is CoreState.WAKING:
            core.wake_countdown -= 1
            if core.wake_countdown <= 0:
                core.state = CoreState.ACTIVE
                # response data already latched in resume_value
                self._advance(core, core.resume_value)
        elif core.state is CoreState.STALL_SCU and core.elw_issued:
            # busy-release window (Fig. 4 left): active, then clock gated
            core.sleep_entry -= 1
            if core.sleep_entry <= 0:
                core.state = CoreState.SLEEP

    def _bank_of(self, addr: int) -> int:
        return (addr >> 2) % self.n_banks

    def _arbitrate_tcdm(self) -> None:
        by_bank: Dict[int, List[_Core]] = {}
        for core in self.cores:
            if core.state is CoreState.STALL_MEM and isinstance(core.pending, Mem):
                by_bank.setdefault(self._bank_of(core.pending.addr), []).append(core)
        for bank, reqs in by_bank.items():
            if self._bank_locked_until[bank] > self.cycle:
                self.stats.bank_conflicts += len(reqs)
                continue
            # round-robin election among contenders
            reqs.sort(key=lambda c: (c.cid - self._rr[bank]) % self.n_cores)
            winner = reqs[0]
            self._rr[bank] = (winner.cid + 1) % self.n_cores
            self.stats.bank_conflicts += len(reqs) - 1
            op: Mem = winner.pending  # type: ignore[assignment]
            winner.stats.tcdm_accesses += 1
            if op.kind == "lw":
                value = self.tcdm.get(op.addr, 0)
            elif op.kind == "sw":
                self.tcdm[op.addr] = op.data
                value = 0
            elif op.kind == "tas":
                value = self.tcdm.get(op.addr, 0)
                self.tcdm[op.addr] = -1
                winner.stats.tas_accesses += 1
                # "-1 written back to memory in the next cycle before any
                # other core gets its request granted" (Sec. 4.1): the LINT
                # sequences the write-back through a forwarding write buffer
                # (atomicity is guaranteed by the arbitration order), and the
                # requesting core sees the full 3-cycle TAS latency.
                winner.busy = self.TAS_CYCLES - 1
            else:  # pragma: no cover
                raise ValueError(op.kind)
            # single-cycle TCDM: response consumed next cycle
            winner.pending = None
            winner.resume_value = value
            winner.state = CoreState.ACTIVE

    def _service_scu(self) -> None:
        for core in self.cores:
            if core.state is not CoreState.STALL_SCU or not isinstance(
                core.pending, Scu
            ):
                continue
            op: Scu = core.pending
            core.stats.scu_accesses += 1
            if op.kind in ("write", "read"):
                value = self.scu.access(core.cid, op.kind, op.addr, op.data)
                core.pending = None
                core.resume_value = value if value is not None else 0
                core.state = CoreState.ACTIVE
            elif op.kind == "elw":
                if not core.elw_issued:
                    # Trigger the addressed extension exactly once per elw
                    # transaction (FSM trigger-once guard, Sec. 5).
                    self.scu.elw_trigger(core.cid, op.addr)
                    core.elw_issued = True
                    # Grant withheld for now; if the event is already buffered
                    # the phase-4 poll grants in this same cycle with no
                    # power management ("to not waste any cycles", Sec. 5).
                    core.sleep_entry = self.SLEEP_ENTRY_CYCLES
            else:  # pragma: no cover
                raise ValueError(op.kind)

    def _wake_cores(self) -> None:
        """Phase 4: poll every in-flight elw against the event buffers."""
        for core in self.cores:
            if core.pending is None or not core.elw_issued:
                continue
            if core.state not in (CoreState.STALL_SCU, CoreState.SLEEP):
                continue
            granted, value = self.scu.elw_poll(core.cid, core.pending.addr)
            if granted:
                never_slept = core.state is CoreState.STALL_SCU
                core.pending = None
                core.elw_issued = False
                core.resume_value = value
                core.state = CoreState.WAKING
                # Immediate grants skip the clock-gate entry latency but still
                # pay grant + response + resume.
                core.wake_countdown = (
                    self.WAKE_CYCLES - 1 if never_slept else self.WAKE_CYCLES
                )

    # ------------------------------------------------------------- helpers
    def poke(self, addr: int, value: int) -> None:
        self.tcdm[addr] = value

    def peek(self, addr: int) -> int:
        return self.tcdm.get(addr, 0)
