"""SCU extensions: notifier, barrier, mutex, event FIFO (paper Sec. 4.3).

Extensions are shared blocks that generate *per-core* events; the per-core
events of all instances of one extension type are OR-combined onto a single
event line per type (Sec. 4.3, last paragraph) -- lines ``EV.BARRIER`` /
``EV.MUTEX`` / ``EV.FIFO`` / ``EV.NOTIFIER0..7``.

Fast-forward contract: every extension with an ``evaluate`` comparator also
implements ``next_event_bound() -> Optional[int]`` -- the number of cycles
until ``evaluate`` could generate an event *assuming no new core transaction
arrives*.  ``0`` means "could fire this cycle" (the engine must run a full
lockstep step), a positive ``k`` means "fires in exactly k cycles regardless
of core activity" (for timed comparators), and ``None`` means "cannot fire
until some core transaction re-arms it".  The bound must exactly mirror the
``evaluate`` firing condition, otherwise the event-driven engine would skip
over a comparator edge; ``tests/test_scu_simulator.py`` cross-checks the two
engine modes cycle-for-cycle.  New extensions must implement this hook to be
safe under ``Cluster(mode="fastforward")``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional

__all__ = ["Notifier", "Barrier", "Mutex", "EventFifo"]

_EV_BARRIER = 8
_EV_MUTEX = 9
_EV_FIFO = 10


@dataclasses.dataclass
class Notifier:
    """Any-to-any matrix-style core-to-core signaling (8 notifier events)."""

    n_cores: int

    def trigger(self, event: int, target_mask: int, base_units) -> None:
        assert 0 <= event < 8
        if target_mask == 0:  # all-zero -> broadcast (Sec. 4.3)
            target_mask = (1 << self.n_cores) - 1
        for cid in range(self.n_cores):
            if target_mask & (1 << cid):
                base_units[cid].buffer_set(event)


@dataclasses.dataclass
class Barrier:
    """Hardware barrier: worker/target masks + arrival status register.

    A *worker* subset must arrive; once ``status == worker_mask`` an event is
    generated for every core in the *target* subset and the status register
    clears (ready for immediate reuse -- barriers are commonly back-to-back).
    """

    index: int
    n_cores: int
    worker_mask: int = 0
    target_mask: int = 0
    status: int = 0
    _fired: bool = dataclasses.field(default=False, repr=False)

    def __post_init__(self):
        full = (1 << self.n_cores) - 1
        if self.worker_mask == 0:
            self.worker_mask = full
        if self.target_mask == 0:
            self.target_mask = full

    def arrive(self, cid: int, base_units) -> None:
        self.status |= 1 << cid

    def next_event_bound(self) -> Optional[int]:
        """0 while the arrival pattern is complete (fires now), else None:
        only a new arrival (a core transaction) can complete it."""
        if self.worker_mask and (self.status & self.worker_mask) == self.worker_mask:
            return 0
        return None

    def evaluate(self, base_units) -> int:
        if self.worker_mask and (self.status & self.worker_mask) == self.worker_mask:
            n = 0
            for cid in range(self.n_cores):
                if self.target_mask & (1 << cid):
                    base_units[cid].buffer_set(_EV_BARRIER)
                    n += 1
            self.status = 0
            return n
        return 0


@dataclasses.dataclass
class Mutex:
    """Hardware mutex: pending-request queue + election + message passing.

    ``try_lock`` registers a request; ``evaluate`` elects exactly one pending
    core when the mutex is free and sends it the mutex event.  ``unlock``
    releases and carries a 32-bit message delivered to the next elected core
    over the elw response channel (Sec. 5).
    """

    index: int
    n_cores: int
    owner: Optional[int] = None
    message: int = 0
    pending: Deque[int] = dataclasses.field(default_factory=deque)

    def try_lock(self, cid: int, base_units) -> None:
        if cid not in self.pending and self.owner != cid:
            self.pending.append(cid)

    def unlock(self, cid: int, message: int, base_units) -> None:
        if self.owner == cid:
            self.owner = None
            self.message = message

    def next_event_bound(self) -> Optional[int]:
        """0 while an election is possible (free + contenders), else None:
        progress needs an unlock or a new try_lock transaction."""
        return 0 if self.owner is None and self.pending else None

    def evaluate(self, base_units) -> int:
        if self.owner is None and self.pending:
            elected = self.pending.popleft()
            self.owner = elected
            base_units[elected].buffer_set(_EV_MUTEX)
            return 1
        return 0


@dataclasses.dataclass
class EventFifo:
    """Event queue over the async 8-bit event bus (paper Sec. 4.3).

    The paper's FIFO extension queues up to 256 cluster-external events; we
    generalize it to the core-facing producer-consumer discipline the FIFO
    exists to enable (Sec. 4.3 names fine-grain producer-consumer chains as
    the use case barriers serve poorly):

      * *producers* push an 8-bit event over a plain SCU write
        (``("fifo", i, "push")``) or :meth:`SCU.push_external_event`,
      * *consumers* issue an elw pop (``("fifo", i, "pop")``) which registers
        them as a pending popper; the grant is withheld -- clock-gating the
        consumer -- until an event is matched to them,
      * :meth:`evaluate` drains one event per cycle (the event-bus rate) to
        the oldest pending popper, Mutex-style: the event value is latched
        into :attr:`messages` and delivered over the elw response channel.

    A push to a full FIFO is dropped and counted (the hardware NACKs); the
    sync policy built on top keeps occupancy bounded by construction
    (credit flow), so a nonzero :attr:`dropped` indicates a program bug.
    """

    index: int = 0
    depth: int = 16
    fifo: Deque[int] = dataclasses.field(default_factory=deque)
    poppers: Deque[int] = dataclasses.field(default_factory=deque)
    messages: Dict[int, int] = dataclasses.field(default_factory=dict)
    dropped: int = 0
    pushed: int = 0

    def push(self, event_id: int) -> None:
        assert 0 <= event_id < 256
        if len(self.fifo) >= self.depth:
            self.dropped += 1
            return
        self.fifo.append(event_id)
        self.pushed += 1

    def pop(self) -> Optional[int]:
        """Direct (non-elw) drain, e.g. an external agent emptying the queue."""
        return self.fifo.popleft() if self.fifo else None

    def register_popper(self, cid: int) -> None:
        """elw-trigger hook: queue ``cid`` for the next available event."""
        if cid not in self.poppers and cid not in self.messages:
            self.poppers.append(cid)

    def take_message(self, cid: int) -> int:
        """elw-grant hook: consume the event value latched for ``cid``."""
        return self.messages.pop(cid)

    def next_event_bound(self) -> Optional[int]:
        """0 while a queued event can be matched to a pending popper (the
        comparator fires every cycle until one side drains), else None: only
        a core transaction (push / pop registration) can re-arm it."""
        return 0 if (self.fifo and self.poppers) else None

    def evaluate(self, base_units) -> int:
        if self.fifo and self.poppers:
            cid = self.poppers.popleft()
            self.messages[cid] = self.fifo.popleft()
            base_units[cid].buffer_set(_EV_FIFO)
            return 1
        return 0
