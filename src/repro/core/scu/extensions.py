"""SCU extensions: notifier, barrier, mutex, event FIFO (paper Sec. 4.3).

Extensions are shared blocks that generate *per-core* events; the per-core
events of all instances of one extension type are OR-combined onto a single
event line per type (Sec. 4.3, last paragraph) -- lines ``EV.BARRIER`` /
``EV.MUTEX`` / ``EV.FIFO`` / ``EV.NOTIFIER0..7``.

Fast-forward contract
---------------------
Every extension with an ``evaluate`` comparator also implements
``next_event_bound() -> Optional[int]`` -- the number of cycles until
``evaluate`` could generate an event *assuming no new core transaction
arrives*.  ``0`` means "could fire this cycle" (the engine must run a full
step), a positive ``k`` means "fires in exactly k cycles regardless of core
activity" (for timed comparators), and ``None`` means "cannot fire until
some core transaction re-arms it".  The bound must exactly mirror the
``evaluate`` firing condition, otherwise the event-driven engine would skip
over a comparator edge; ``tests/test_scu_simulator.py`` cross-checks the two
engine modes cycle-for-cycle.

Keeping an extension vectorization-safe
---------------------------------------
The structure-of-arrays engine core and the spin-phase batch resolver rely
on two additional properties beyond the bound contract:

1. **Armed-set maintenance.** The per-cycle ``SCU.evaluate`` only visits
   *armed* instances (those whose ``next_event_bound()`` is 0) -- the hot
   loop must not pay for idle comparators on a 256-core cluster with 128
   barrier instances.  Every mutation that can change an instance's firing
   condition must be followed by the matching ``SCU._*_touched`` re-derive
   (see :meth:`repro.core.scu.scu_unit.SCU.access` / ``elw_trigger``): a
   comparator that arms itself silently will never be evaluated, and one
   that stays in the armed set while disarmed only wastes cycles.
2. **No hidden time dependence.** The spin-phase batch resolver jumps whole
   periods of pure TCDM polling whenever ``SCU.next_event_bound()`` is
   ``None``.  An extension whose ``evaluate`` depends on the cycle number
   (a timed comparator) must therefore return its positive bound from
   ``next_event_bound()`` -- returning ``None`` while counting cycles
   internally would let both fast paths jump over the firing edge.

Event delivery writes the per-core event buffers through the
``base_units`` handle, which is numpy-array backed
(:class:`repro.core.scu.scu_unit.BaseUnits`): deliver to a *set* of cores
with ``base_units.deliver(line, mask)`` (vectorized) rather than a Python
loop when the target set scales with the cluster.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional, Tuple

__all__ = ["Notifier", "Barrier", "Mutex", "EventFifo"]

_EV_BARRIER = 8
_EV_MUTEX = 9
_EV_FIFO = 10


@dataclasses.dataclass
class Notifier:
    """Any-to-any matrix-style core-to-core signaling (8 notifier events)."""

    n_cores: int

    def trigger(self, event: int, target_mask: int, base_units) -> None:
        assert 0 <= event < 8
        if target_mask == 0:  # all-zero -> broadcast (Sec. 4.3)
            target_mask = (1 << self.n_cores) - 1
        base_units.deliver(event, target_mask)


@dataclasses.dataclass
class Barrier:
    """Hardware barrier: worker/target masks + arrival status register.

    A *worker* subset must arrive; once ``status == worker_mask`` an event is
    generated for every core in the *target* subset and the status register
    clears (ready for immediate reuse -- barriers are commonly back-to-back).
    """

    index: int
    n_cores: int
    worker_mask: int = 0
    target_mask: int = 0
    status: int = 0

    def __post_init__(self):
        full = (1 << self.n_cores) - 1
        if self.worker_mask == 0:
            self.worker_mask = full
        if self.target_mask == 0:
            self.target_mask = full

    def arrive(self, cid: int, base_units) -> None:
        self.status |= 1 << cid

    def next_event_bound(self) -> Optional[int]:
        """0 while the arrival pattern is complete (fires now), else None:
        only a new arrival (a core transaction) can complete it."""
        if self.worker_mask and (self.status & self.worker_mask) == self.worker_mask:
            return 0
        return None

    def evaluate(self, base_units) -> int:
        if self.worker_mask and (self.status & self.worker_mask) == self.worker_mask:
            n = base_units.deliver(_EV_BARRIER, self.target_mask)
            self.status = 0
            return n
        return 0

    def state_key(self):
        """Hashable snapshot of every field ``evaluate`` reads or writes
        (the compiled-trace monitor's recurrence digest)."""
        return (self.worker_mask, self.target_mask, self.status)


@dataclasses.dataclass
class Mutex:
    """Hardware mutex: pending-request queue + election + message passing.

    ``try_lock`` registers a request; ``evaluate`` elects exactly one pending
    core when the mutex is free and sends it the mutex event.  ``unlock``
    releases and carries a 32-bit message delivered to the next elected core
    over the elw response channel (Sec. 5).
    """

    index: int
    n_cores: int
    owner: Optional[int] = None
    message: int = 0
    pending: Deque[int] = dataclasses.field(default_factory=deque)

    def try_lock(self, cid: int, base_units) -> None:
        if cid not in self.pending and self.owner != cid:
            self.pending.append(cid)

    def unlock(self, cid: int, message: int, base_units) -> None:
        if self.owner == cid:
            self.owner = None
            self.message = message

    def next_event_bound(self) -> Optional[int]:
        """0 while an election is possible (free + contenders), else None:
        progress needs an unlock or a new try_lock transaction."""
        return 0 if self.owner is None and self.pending else None

    def evaluate(self, base_units) -> int:
        if self.owner is None and self.pending:
            elected = self.pending.popleft()
            self.owner = elected
            base_units[elected].buffer_set(_EV_MUTEX)
            return 1
        return 0

    def state_key(self):
        """Hashable snapshot for the compiled-trace recurrence digest."""
        return (self.owner, self.message, tuple(self.pending))


@dataclasses.dataclass
class EventFifo:
    """Event queue over the async 8-bit event bus (paper Sec. 4.3).

    The paper's FIFO extension queues up to 256 cluster-external events; we
    generalize it to the core-facing producer-consumer discipline the FIFO
    exists to enable (Sec. 4.3 names fine-grain producer-consumer chains as
    the use case barriers serve poorly):

      * *producers* push an 8-bit event over a plain SCU write
        (``("fifo", i, "push")``) or :meth:`SCU.push_external_event`; a push
        to a full FIFO is dropped and counted (the hardware NACKs),
      * *blocking producers* issue an elw push (``("fifo", i, "push_wait")``
        with the event as data), which registers them as a pending pusher;
        the grant is withheld -- clock-gating the producer -- until the
        queue has room and accepts the event: native backpressure without a
        software credit queue,
      * *consumers* issue an elw pop (``("fifo", i, "pop")``) which registers
        them as a pending popper; the grant is withheld -- clock-gating the
        consumer -- until an event is matched to them,
      * :meth:`evaluate` moves one event through each port per cycle (the
        event-bus rate): it delivers the oldest queued event to the oldest
        pending popper (the value is latched into :attr:`messages` and
        returned over the elw response channel), then accepts the oldest
        pending pusher's event if the queue has room -- a pop and a push can
        complete in the same cycle, so a full queue with a waiting consumer
        still makes one item of progress per cycle.

    The non-blocking push keeps the NACK-and-count semantics; the sync
    policy built on top keeps occupancy bounded by construction (credit
    flow), so a nonzero :attr:`dropped` indicates a program bug.
    """

    index: int = 0
    depth: int = 16
    fifo: Deque[int] = dataclasses.field(default_factory=deque)
    poppers: Deque[int] = dataclasses.field(default_factory=deque)
    pushers: Deque[Tuple[int, int]] = dataclasses.field(default_factory=deque)
    messages: Dict[int, int] = dataclasses.field(default_factory=dict)
    dropped: int = 0
    pushed: int = 0

    def push(self, event_id: int) -> None:
        assert 0 <= event_id < 256
        if len(self.fifo) >= self.depth:
            self.dropped += 1
            return
        self.fifo.append(event_id)
        self.pushed += 1

    def pop(self) -> Optional[int]:
        """Direct (non-elw) drain, e.g. an external agent emptying the queue."""
        return self.fifo.popleft() if self.fifo else None

    def register_popper(self, cid: int) -> None:
        """elw-trigger hook: queue ``cid`` for the next available event."""
        if cid not in self.poppers and cid not in self.messages:
            self.poppers.append(cid)

    def register_pusher(self, cid: int, event_id: int) -> None:
        """elw-trigger hook (``push_wait``): queue ``cid``'s blocked push."""
        assert 0 <= event_id < 256
        if cid not in self.messages and all(c != cid for c, _ in self.pushers):
            self.pushers.append((cid, event_id))

    def take_message(self, cid: int) -> int:
        """elw-grant hook: consume the value latched for ``cid`` (the popped
        event for a consumer, the accepted event echoed back for a blocked
        producer).  A grant with no latched value returns 0: a spurious
        (injected) FIFO event or a watchdog force-release can wake a waiter
        the comparator never matched."""
        return self.messages.pop(cid, 0)

    def next_event_bound(self) -> Optional[int]:
        """0 while the comparator can move an event through either port this
        cycle -- a queued event matching a pending popper, or a blocked push
        fitting the queue (including the slot a same-cycle pop frees) --
        else None: only a core transaction can re-arm it."""
        if self.fifo and self.poppers:
            return 0
        if self.pushers and len(self.fifo) < self.depth:
            return 0
        return None

    def evaluate(self, base_units) -> int:
        n = 0
        if self.fifo and self.poppers:
            cid = self.poppers.popleft()
            self.messages[cid] = self.fifo.popleft()
            base_units[cid].buffer_set(_EV_FIFO)
            n += 1
        if self.pushers and len(self.fifo) < self.depth:
            cid, event_id = self.pushers.popleft()
            self.fifo.append(event_id)
            self.pushed += 1
            self.messages[cid] = event_id
            base_units[cid].buffer_set(_EV_FIFO)
            n += 1
        return n

    def state_key(self):
        """Hashable snapshot for the compiled-trace recurrence digest.

        Deliberately includes the monotone ``pushed``/``dropped`` counters:
        they are observable in benchmark output, so a state carrying them
        never recurs and FIFO-driven programs are simply never collapsed
        (correct by construction rather than by a special case)."""
        return (
            tuple(self.fifo), tuple(self.poppers), tuple(self.pushers),
            tuple(sorted(self.messages.items())), self.dropped, self.pushed,
        )
