"""SCU extensions: notifier, barrier, mutex, event FIFO (paper Sec. 4.3).

Extensions are shared blocks that generate *per-core* events; the per-core
events of all instances of one extension type are OR-combined onto a single
event line per type (Sec. 4.3, last paragraph) -- lines ``EV.BARRIER`` /
``EV.MUTEX`` / ``EV.FIFO`` / ``EV.NOTIFIER0..7``.

Fast-forward contract: every extension with an ``evaluate`` comparator also
implements ``next_event_bound() -> Optional[int]`` -- the number of cycles
until ``evaluate`` could generate an event *assuming no new core transaction
arrives*.  ``0`` means "could fire this cycle" (the engine must run a full
lockstep step), a positive ``k`` means "fires in exactly k cycles regardless
of core activity" (for timed comparators), and ``None`` means "cannot fire
until some core transaction re-arms it".  The bound must exactly mirror the
``evaluate`` firing condition, otherwise the event-driven engine would skip
over a comparator edge; ``tests/test_scu_simulator.py`` cross-checks the two
engine modes cycle-for-cycle.  New extensions must implement this hook to be
safe under ``Cluster(mode="fastforward")``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

__all__ = ["Notifier", "Barrier", "Mutex", "EventFifo"]

_EV_BARRIER = 8
_EV_MUTEX = 9
_EV_FIFO = 10


@dataclasses.dataclass
class Notifier:
    """Any-to-any matrix-style core-to-core signaling (8 notifier events)."""

    n_cores: int

    def trigger(self, event: int, target_mask: int, base_units) -> None:
        assert 0 <= event < 8
        if target_mask == 0:  # all-zero -> broadcast (Sec. 4.3)
            target_mask = (1 << self.n_cores) - 1
        for cid in range(self.n_cores):
            if target_mask & (1 << cid):
                base_units[cid].buffer_set(event)


@dataclasses.dataclass
class Barrier:
    """Hardware barrier: worker/target masks + arrival status register.

    A *worker* subset must arrive; once ``status == worker_mask`` an event is
    generated for every core in the *target* subset and the status register
    clears (ready for immediate reuse -- barriers are commonly back-to-back).
    """

    index: int
    n_cores: int
    worker_mask: int = 0
    target_mask: int = 0
    status: int = 0
    _fired: bool = dataclasses.field(default=False, repr=False)

    def __post_init__(self):
        full = (1 << self.n_cores) - 1
        if self.worker_mask == 0:
            self.worker_mask = full
        if self.target_mask == 0:
            self.target_mask = full

    def arrive(self, cid: int, base_units) -> None:
        self.status |= 1 << cid

    def next_event_bound(self) -> Optional[int]:
        """0 while the arrival pattern is complete (fires now), else None:
        only a new arrival (a core transaction) can complete it."""
        if self.worker_mask and (self.status & self.worker_mask) == self.worker_mask:
            return 0
        return None

    def evaluate(self, base_units) -> int:
        if self.worker_mask and (self.status & self.worker_mask) == self.worker_mask:
            n = 0
            for cid in range(self.n_cores):
                if self.target_mask & (1 << cid):
                    base_units[cid].buffer_set(_EV_BARRIER)
                    n += 1
            self.status = 0
            return n
        return 0


@dataclasses.dataclass
class Mutex:
    """Hardware mutex: pending-request queue + election + message passing.

    ``try_lock`` registers a request; ``evaluate`` elects exactly one pending
    core when the mutex is free and sends it the mutex event.  ``unlock``
    releases and carries a 32-bit message delivered to the next elected core
    over the elw response channel (Sec. 5).
    """

    index: int
    n_cores: int
    owner: Optional[int] = None
    message: int = 0
    pending: Deque[int] = dataclasses.field(default_factory=deque)

    def try_lock(self, cid: int, base_units) -> None:
        if cid not in self.pending and self.owner != cid:
            self.pending.append(cid)

    def unlock(self, cid: int, message: int, base_units) -> None:
        if self.owner == cid:
            self.owner = None
            self.message = message

    def next_event_bound(self) -> Optional[int]:
        """0 while an election is possible (free + contenders), else None:
        progress needs an unlock or a new try_lock transaction."""
        return 0 if self.owner is None and self.pending else None

    def evaluate(self, base_units) -> int:
        if self.owner is None and self.pending:
            elected = self.pending.popleft()
            self.owner = elected
            base_units[elected].buffer_set(_EV_MUTEX)
            return 1
        return 0


@dataclasses.dataclass
class EventFifo:
    """Up to 256 cluster-external events over an async 8-bit event bus."""

    depth: int = 16
    fifo: Deque[int] = dataclasses.field(default_factory=deque)
    dropped: int = 0

    def push(self, event_id: int) -> None:
        assert 0 <= event_id < 256
        if len(self.fifo) >= self.depth:
            self.dropped += 1
            return
        self.fifo.append(event_id)

    def pop(self) -> Optional[int]:
        return self.fifo.popleft() if self.fifo else None

    def next_event_bound(self) -> Optional[int]:
        """0 while queued external events exist (the non-empty level is
        re-asserted every cycle), else None until the next push."""
        return 0 if self.fifo else None

    def evaluate(self, base_units) -> int:
        if self.fifo:
            for u in base_units:
                u.buffer_set(_EV_FIFO)
            return 1
        return 0
