"""Deterministic fault injection + watchdog recovery for the cluster engine.

Near-threshold silicon sees transient upsets the paper's evaluation assumes
away: wake-up events that never reach a core, events that fire without a
cause, cores frozen for a handful of cycles by droop, and TCDM banks blacked
out by glitching arbitration.  This module makes those failure modes
first-class *deterministic* simulator inputs:

:class:`FaultPlan`
    A seed-derivable schedule of :class:`FaultEvent`\\ s applied at exact
    cycles.  The plan implements the same ``next_event_bound()`` contract as
    the SCU extensions (see :mod:`repro.core.scu.extensions`): ``0`` at any
    cycle where a fault applies (or inside a bank-blackout window), a
    positive count until the next fault otherwise, ``None`` when the plan is
    exhausted.  The engine mins this bound into every fast-forward tier, so
    a full cluster step lands on *exactly* the fault cycles in both engine
    modes -- fault-injected runs stay bit-exact between ``lockstep`` and
    ``fastforward`` (enforced by ``tests/test_faults.py``).  A plan instance
    is **single-use** (it carries an application cursor); use
    :meth:`FaultPlan.clone` to run the same schedule on a second cluster.

:class:`Watchdog`
    An SCU extension that detects stuck comparators: when cores are parked
    on in-flight ``elw`` transactions and the SCU sees no progress (no
    access, no trigger, no grant, no comparator event) for ``timeout``
    cycles, it either force-releases every parked waiter
    (``mode="release"``) or trips with a structured wait-for graph
    (``mode="raise"`` -- surfaced by the engine as :class:`DeadlockError`).
    The watchdog implements ``next_event_bound()`` (a positive, possibly
    conservative bound is safe: firing only ever moves *later* when
    progress happens), so the fast-forward tiers jump straight to its
    deadline instead of burning to the ``max_cycles`` cap.

:class:`DeadlockError` / :class:`SimTimeout`
    Structured failures carrying a :class:`WaitForGraph`: the per-core
    blocked micro-op, the armed/stuck comparator instances, and the fault
    events applied so far (the blame list).  ``SimTimeout`` keeps the
    legacy ``"cluster did not finish within ..."`` message prefix so
    existing capture paths (``SlotFleet._on_timeout``) stay intact.

This module deliberately imports nothing from the engine (the engine
imports it); everything here operates on clusters by duck typing.
"""

from __future__ import annotations

import bisect
import dataclasses
import random as _random
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

__all__ = [
    "ALL_LINES",
    "FAULT_KINDS",
    "DOMAIN_KINDS",
    "FaultEvent",
    "FaultPlan",
    "Watchdog",
    "DeadlockError",
    "SimTimeout",
    "WaitForGraph",
    "build_wait_graph",
]

ALL_LINES = 0xFFFFFFFF  # every event line (32, Sec. 4.2)

# "droop"/"scu_blackout" are appended so the sort index of the original four
# kinds -- and therefore the event order of every pre-existing plan -- is
# unchanged.
FAULT_KINDS = (
    "lost_wake", "spurious_wake", "stall", "bank_blackout",
    "droop", "scu_blackout",
)

# Kinds that model *correlated* failure of a whole fault domain (a voltage
# island / cluster group) rather than an independent per-core upset.  A
# domain-wide bank blackout is an ordinary ``bank_blackout`` whose ``banks``
# enumerate the domain's banks.
DOMAIN_KINDS = ("droop", "scu_blackout", "bank_blackout")

# event lines a spurious upset plausibly lands on (notifiers 0/1 and the
# three extension lines -- see repro.core.scu.scu_unit.EV)
_SPURIOUS_LINES = (0, 1, 8, 9, 10)


class DeadlockError(RuntimeError):
    """The cluster provably cannot make progress (watchdog trip / timeout).

    ``graph`` carries the :class:`WaitForGraph` snapshot taken when the
    deadlock was detected; the message embeds its rendered form.
    """

    def __init__(self, message: str, graph: Optional["WaitForGraph"] = None):
        super().__init__(message)
        self.graph = graph


class SimTimeout(DeadlockError):
    """A run hit its ``max_cycles`` cap.  Message keeps the legacy
    ``"cluster did not finish within ..."`` prefix and appends the per-core
    wait-for dump."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled upset.  Fields used per kind:

    ``lost_wake``      -- at ``cycle``, arm a one-shot drop filter on core
                          ``core``: the next SCU event delivery on any line
                          in ``lines`` to that core is silently suppressed.
    ``spurious_wake``  -- at ``cycle``, latch event ``line`` into core
                          ``core``'s event buffer with no cause.
    ``stall``          -- at ``cycle``, freeze core ``core`` for ``span``
                          extra cycles (models a local voltage droop): an
                          ACTIVE core's compute countdown and a WAKING
                          core's wake sequencing are extended; cores in any
                          other state are unaffected (logged as a no-op).
    ``bank_blackout``  -- during ``[cycle, cycle + span)``, the TCDM banks
                          in ``banks`` grant nothing; requests stay queued
                          (and are not charged as bank conflicts -- the
                          interconnect, not contention, is at fault).
    ``droop``          -- at ``cycle``, one correlated voltage droop freezes
                          *every* core in ``cores`` for ``span`` extra
                          cycles (same per-core semantics as ``stall``,
                          applied to the whole domain at the same cycle).
    ``scu_blackout``   -- during ``[cycle, cycle + span)``, the SCU's
                          comparators neither evaluate nor grant: triggers
                          still latch (armed state is preserved) and event
                          deliveries still buffer, but nothing fires or
                          wakes until the window ends, when the armed
                          comparators replay on the first ungated evaluate.

    ``domain`` is a free-form blame label ("" = not domain-scoped) carried
    into the :attr:`FaultPlan.applied` log and :class:`WaitForGraph`.
    """

    kind: str
    cycle: int
    core: int = -1
    lines: int = ALL_LINES  # lost_wake: drop mask over event lines
    line: int = 0  # spurious_wake: event line to set
    span: int = 0  # stall/droop: freeze cycles; *_blackout: window length
    banks: Tuple[int, ...] = ()  # bank_blackout: local bank ids
    cores: Tuple[int, ...] = ()  # droop: every core of the domain
    domain: str = ""  # blame label for domain-scoped events

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.cycle < 0:
            raise ValueError(f"fault cycle must be >= 0, got {self.cycle}")
        if self.kind in ("lost_wake", "spurious_wake", "stall") and self.core < 0:
            raise ValueError(f"{self.kind} needs a target core")
        if self.kind in ("stall", "bank_blackout", "droop", "scu_blackout") \
                and self.span < 1:
            raise ValueError(f"{self.kind} needs span >= 1, got {self.span}")
        if self.kind == "bank_blackout" and not self.banks:
            raise ValueError("bank_blackout needs at least one bank")
        if self.kind == "droop" and not self.cores:
            raise ValueError("droop needs at least one core in its domain")


class FaultPlan:
    """A deterministic, cycle-addressed schedule of :class:`FaultEvent`\\ s.

    Pass one instance per cluster (``Cluster(..., faults=plan)``).  The
    engine calls :meth:`apply` at the start of every full step and mins
    :meth:`next_event_bound` into every fast-forward tier; together these
    guarantee each event is applied at exactly its scheduled cycle in both
    engine modes.  :attr:`applied` is the blame log surfaced by
    :func:`build_wait_graph`.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(
            events,
            key=lambda e: (e.cycle, FAULT_KINDS.index(e.kind), e.core, e.line),
        )
        self._next = 0
        self.applied: List[Dict[str, Any]] = []
        self._cycles = sorted({e.cycle for e in self.events})
        self._windows: List[Tuple[int, int, FrozenSet[int]]] = sorted(
            (e.cycle, e.cycle + e.span, frozenset(e.banks))
            for e in self.events
            if e.kind == "bank_blackout"
        )
        self._blk_cache: Tuple[int, FrozenSet[int]] = (-1, frozenset())
        self._scu_windows: List[Tuple[int, int]] = sorted(
            (e.cycle, e.cycle + e.span)
            for e in self.events
            if e.kind == "scu_blackout"
        )
        self._scu_cache: Tuple[int, bool] = (-1, False)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        # eval-able (given FaultEvent/FaultPlan in scope): the minimal
        # reproducer printed by scripts/fault_fuzz.py on a parity mismatch
        return f"FaultPlan({self.events!r})"

    def clone(self) -> "FaultPlan":
        """A fresh plan with the same schedule and a reset cursor (for
        running the identical fault history on a second cluster, e.g. the
        lockstep parity reference)."""
        return FaultPlan(self.events)

    @classmethod
    def random(
        cls,
        seed: int,
        n_cores: int,
        n_banks: int,
        horizon: int,
        n_events: int = 4,
        kinds: Sequence[str] = FAULT_KINDS,
    ) -> "FaultPlan":
        """A seed-derived plan: ``n_events`` faults of the given kinds over
        cycles ``[0, horizon)``.  Same seed -> same schedule, always."""
        rng = _random.Random(seed)
        kinds = tuple(kinds)
        events: List[FaultEvent] = []
        for _ in range(n_events):
            kind = rng.choice(kinds)
            cycle = rng.randrange(max(1, horizon))
            core = rng.randrange(n_cores)
            if kind == "lost_wake":
                events.append(FaultEvent("lost_wake", cycle, core))
            elif kind == "spurious_wake":
                events.append(
                    FaultEvent(
                        "spurious_wake", cycle, core,
                        line=rng.choice(_SPURIOUS_LINES),
                    )
                )
            elif kind == "stall":
                events.append(
                    FaultEvent("stall", cycle, core, span=rng.randrange(1, 64))
                )
            else:
                k = rng.randrange(1, max(2, n_banks // 2 + 1))
                banks = tuple(sorted(rng.sample(range(n_banks), k)))
                events.append(
                    FaultEvent(
                        "bank_blackout", cycle,
                        span=rng.randrange(1, 32), banks=banks,
                    )
                )
        return cls(events)

    @classmethod
    def random_domain(
        cls,
        seed: int,
        n_cores: int,
        n_banks: int,
        horizon: int,
        n_events: int = 3,
        n_domains: int = 2,
        kinds: Sequence[str] = DOMAIN_KINDS,
    ) -> "FaultPlan":
        """A seed-derived plan of *domain-scoped* events: the cluster's
        cores/banks are split into ``n_domains`` contiguous groups and every
        event hits one whole group (correlated droop, SCU blackout, or a
        domain-wide bank blackout).  Same seed -> same schedule, always."""
        rng = _random.Random(seed)
        kinds = tuple(kinds)
        n_domains = max(1, min(n_domains, n_cores))
        events: List[FaultEvent] = []
        for _ in range(n_events):
            kind = rng.choice(kinds)
            d = rng.randrange(n_domains)
            name = f"dom{d}"
            cycle = rng.randrange(max(1, horizon))
            if kind == "droop":
                cores = tuple(
                    c for c in range(n_cores) if c * n_domains // n_cores == d
                )
                events.append(
                    FaultEvent(
                        "droop", cycle, cores=cores,
                        span=rng.randrange(1, 64), domain=name,
                    )
                )
            elif kind == "scu_blackout":
                events.append(
                    FaultEvent(
                        "scu_blackout", cycle,
                        span=rng.randrange(1, 32), domain=name,
                    )
                )
            else:
                banks = tuple(
                    b for b in range(n_banks) if b * n_domains // n_banks == d
                ) or (0,)
                events.append(
                    FaultEvent(
                        "bank_blackout", cycle,
                        span=rng.randrange(1, 32), banks=banks, domain=name,
                    )
                )
        return cls(events)

    # --------------------------------------------------------- engine hooks
    def next_event_bound(self, cycle: int) -> Optional[int]:
        """Fast-forward bound contract (same semantics as the SCU
        extensions): 0 when a fault applies at ``cycle`` or a blackout
        window is active, else cycles until the next scheduled fault,
        ``None`` when nothing is left."""
        nxt: Optional[int] = None
        i = bisect.bisect_left(self._cycles, cycle)
        if i < len(self._cycles):
            d = self._cycles[i] - cycle
            if d == 0:
                return 0
            nxt = d
        for start, end, _banks in self._windows:
            if start > cycle:
                break
            if cycle < end:
                return 0
        # an SCU blackout forces full steps through its whole window: gated
        # grants/evaluates are cycle-addressed state the fast paths must not
        # jump over (and the first post-window step replays the armed state)
        for start, end in self._scu_windows:
            if start > cycle:
                break
            if cycle < end:
                return 0
        return nxt

    def scu_blacked(self, cycle: int) -> bool:
        """True while an ``scu_blackout`` window covers ``cycle`` (the SCU
        gates comparator evaluation and elw grants on this)."""
        if not self._scu_windows:
            return False
        c, blacked = self._scu_cache
        if c == cycle:
            return blacked
        blacked = any(
            start <= cycle < end for start, end in self._scu_windows
        )
        self._scu_cache = (cycle, blacked)
        return blacked

    def blacked_banks(self, cycle: int) -> FrozenSet[int]:
        """Local bank ids blacked out at ``cycle`` (empty set = none)."""
        c, banks = self._blk_cache
        if c == cycle:
            return banks
        acc: set = set()
        for start, end, bs in self._windows:
            if start > cycle:
                break
            if cycle < end:
                acc |= bs
        banks = frozenset(acc)
        self._blk_cache = (cycle, banks)
        return banks

    def apply(self, cluster) -> None:
        """Apply every event scheduled for the cluster's current cycle.

        Called by the engine at the start of each full step; the bound
        contract guarantees a full step lands on every scheduled cycle, so
        events are never skipped (events scheduled before the run started
        are dropped as unreachable)."""
        evs = self.events
        i = self._next
        if i >= len(evs):
            return
        c = cluster.cycle
        while i < len(evs) and evs[i].cycle <= c:
            ev = evs[i]
            i += 1
            if ev.cycle == c:
                self._apply_one(ev, cluster)
        self._next = i

    @staticmethod
    def _stall_core(core, span: int) -> str:
        """Extend one core's countdown by ``span`` (stall/droop semantics);
        returns the per-core effect string."""
        state = core.state.name
        if state == "ACTIVE":
            core.busy = core.busy + span
        elif state == "WAKING":
            core.wake_countdown = core.wake_countdown + span
        else:
            return f"noop({state})"
        return "applied"

    def _apply_one(self, ev: FaultEvent, cluster) -> None:
        entry: Dict[str, Any] = {
            "cycle": ev.cycle, "kind": ev.kind, "core": ev.core,
            "effect": "applied",
        }
        if ev.domain:
            entry["domain"] = ev.domain
        if ev.kind == "lost_wake":
            scu = cluster.scu
            if scu is None:
                entry["effect"] = "noop(no scu)"
            else:
                scu.base.arm_drop(ev.core, ev.lines)
        elif ev.kind == "spurious_wake":
            scu = cluster.scu
            entry["line"] = ev.line
            if scu is None:
                entry["effect"] = "noop(no scu)"
            else:
                scu.base.ev_buf[ev.core] |= 1 << ev.line
        elif ev.kind == "stall":
            entry["span"] = ev.span
            entry["effect"] = self._stall_core(cluster.cores[ev.core], ev.span)
        elif ev.kind == "droop":
            # correlated droop: one stall applied to every core of the
            # domain at the same cycle
            entry["core"] = -1
            entry["span"] = ev.span
            entry["cores"] = list(ev.cores)
            effects = {
                cid: self._stall_core(cluster.cores[cid], ev.span)
                for cid in ev.cores
            }
            noops = sorted(c for c, e in effects.items() if e != "applied")
            if noops:
                entry["effect"] = f"partial(noop cores={noops})"
        elif ev.kind == "scu_blackout":
            # the window is enforced by scu_blacked() -- the SCU gates its
            # comparator evaluation and elw grant paths on it
            entry["core"] = -1
            entry["span"] = ev.span
            if cluster.scu is None:
                entry["effect"] = "noop(no scu)"
        else:  # bank_blackout: the window is enforced by blacked_banks()
            entry["core"] = -1
            entry["span"] = ev.span
            entry["banks"] = list(ev.banks)
        self.applied.append(entry)


# ---------------------------------------------------------------------------
# Watchdog: stuck-comparator detection + recovery
# ---------------------------------------------------------------------------


class Watchdog:
    """Stuck-comparator watchdog, owned by the SCU (``SCU(watchdog=...)``).

    *Engaged* whenever at least one core has an in-flight ``elw``
    transaction.  *Progress* is any SCU-visible activity: a register
    access, an ``elw`` trigger or grant, or a comparator generating events.
    When ``timeout`` cycles pass with waiters parked and zero progress:

    ``mode="release"``
        every parked waiter's latched wait mask is forced into its event
        buffer (bypassing any armed lost-wake drop), waking it as if the
        awaited comparator had fired.  After ``max_releases`` firings the
        watchdog escalates to a trip -- a comparator that stays stuck
        through repeated releases is a hard fault, not a lost edge.

    ``mode="raise"``
        the watchdog *trips*: it records a :class:`WaitForGraph` and stops.
        The engine surfaces the trip as a :class:`DeadlockError` right
        after the step (never mid-step -- a batched fleet step must finish
        for co-resident clusters).

    Timing is bit-exact across engine modes: the firing condition is a pure
    predicate over (cycle, last_progress), and :meth:`bound` feeds the SCU's
    ``next_event_bound`` so the fast-forward tiers step on exactly the
    firing cycle.
    """

    MODES = ("release", "raise")

    def __init__(self, timeout: int, mode: str = "release", max_releases: int = 8):
        if timeout < 1:
            raise ValueError(f"watchdog timeout must be >= 1, got {timeout}")
        if mode not in self.MODES:
            raise ValueError(f"watchdog mode must be one of {self.MODES}, got {mode!r}")
        if max_releases < 0:
            raise ValueError(f"max_releases must be >= 0, got {max_releases}")
        self.timeout = timeout
        self.mode = mode
        self.max_releases = max_releases
        self.last_progress = 0
        self.release_count = 0
        self.release_log: List[Dict[str, Any]] = []
        self.tripped: Optional[WaitForGraph] = None

    def due(self, cycle: int) -> bool:
        """True when the no-progress window has elapsed (and not tripped)."""
        return self.tripped is None and cycle - self.last_progress >= self.timeout

    def bound(self, cycle: int) -> Optional[int]:
        """Cycles until the watchdog could fire absent further progress
        (the fast-forward bound; safe because progress only delays it)."""
        if self.tripped is not None:
            return None
        return max(0, self.last_progress + self.timeout - cycle)

    def fire(self, scu, cycle: int) -> None:
        """Fire: force-release the parked waiters, or trip with a graph."""
        if self.mode == "release" and self.release_count < self.max_releases:
            released = sorted(scu._elw_pending)
            for cid in released:
                # straight into the buffer: a watchdog release must not be
                # eaten by an armed lost-wake drop filter
                scu.base.ev_buf[cid] |= scu.elw_wait[cid]
            self.release_count += 1
            self.release_log.append({"cycle": cycle, "cores": released})
            self.last_progress = cycle
            return
        self.tripped = build_wait_graph(scu.cluster)


# ---------------------------------------------------------------------------
# Wait-for graph: the structured deadlock diagnostic
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WaitForGraph:
    """Snapshot of who waits on what: per-core blocked micro-op, the
    armed/stuck comparator instances, and the fault events applied so far
    (the blame list).  Deterministic -- identical runs render identically,
    which the fleet/sequential error-message parity tests rely on."""

    cycle: int
    cores: List[Dict[str, Any]]
    comparators: List[str]
    faults: List[Dict[str, Any]]

    def describe(self) -> str:
        lines = [f"wait-for graph at cycle {self.cycle}:"]
        for c in self.cores:
            row = f"  core {c['core']}: {c['state']}"
            if c.get("op"):
                row += f" on {c['op']} {c['addr']}"
            lines.append(row)
        if self.comparators:
            lines.append("  armed/stuck comparators:")
            lines.extend(f"    {s}" for s in self.comparators)
        if self.faults:
            lines.append("  injected faults applied so far:")
            lines.extend(f"    {f}" for f in self.faults)
        return "\n".join(lines)


def build_wait_graph(cluster) -> WaitForGraph:
    """Build a :class:`WaitForGraph` from a cluster's current state (duck
    typed -- works on any Cluster regardless of engine mode or fleet
    membership, reading only bit-exact state)."""
    cores: List[Dict[str, Any]] = []
    for core in cluster.cores:
        entry: Dict[str, Any] = {"core": core.cid, "state": core.state.name}
        op = core.pending
        if op is not None:
            entry["op"] = type(op).__name__
            entry["addr"] = getattr(op, "addr", None)
        cores.append(entry)
    comparators: List[str] = []
    scu = getattr(cluster, "scu", None)
    if scu is not None:
        for b in scu.barriers:
            if b.status:
                comparators.append(
                    f"barrier[{b.index}] status={b.status:#x} "
                    f"workers={b.worker_mask:#x}"
                )
        for mx in scu.mutexes:
            if mx.owner is not None or mx.pending:
                comparators.append(
                    f"mutex[{mx.index}] owner={mx.owner} "
                    f"pending={list(mx.pending)}"
                )
        for fifo in scu.fifos:
            if fifo.fifo or fifo.poppers or fifo.pushers:
                comparators.append(
                    f"fifo[{fifo.index}] depth={len(fifo.fifo)} "
                    f"poppers={list(fifo.poppers)} pushers={list(fifo.pushers)}"
                )
        pend = sorted(getattr(scu, "_elw_pending", ()))
        if pend:
            comparators.append(f"elw pending cores={pend}")
    plan = getattr(cluster, "faults", None)
    faults = list(plan.applied) if plan is not None else []
    return WaitForGraph(
        cycle=cluster.cycle, cores=cores, comparators=comparators, faults=faults
    )
