"""Microbenchmark programs reproducing the paper's Sec. 6.3 experiments.

``primitive_cost`` mirrors the paper's methodology: "we let the involved
cores execute a loop eight times that contains the respective primitive 32
times and average the resulting cycle count".  The synchronization-free
region (SFR) between primitives is a run of ``Compute`` cycles (the paper
uses ``nop`` runs), tunable to sweep Fig. 5.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from .engine import Cluster, ClusterStats, Compute
from .primitives import DEFAULT_COSTS
from .scu_unit import SCU

__all__ = ["MicrobenchResult", "run_barrier_bench", "run_mutex_bench", "run_nop_bench"]


@dataclasses.dataclass
class MicrobenchResult:
    variant: str
    primitive: str
    n_cores: int
    sfr: int
    iters: int
    cycles_total: int
    cycles_per_iter: float
    prim_cycles: float  # cycles_per_iter - ideal (SFR resp. N*T_crit)
    active_core_cycles_per_iter: float
    gated_core_cycles_per_iter: float
    tcdm_per_iter: float
    scu_per_iter: float
    stats: ClusterStats


def _make_cluster(n_cores: int, mode: str = "fastforward") -> Cluster:
    return Cluster(n_cores=n_cores, scu=SCU(n_cores=n_cores), mode=mode)


def _collect(
    variant: str,
    primitive: str,
    cl: Cluster,
    n_cores: int,
    sfr: int,
    iters: int,
    ideal_per_iter: float,
    warmup_stats: Optional[Tuple[int, Dict[str, float]]] = None,
) -> MicrobenchResult:
    st = cl.run()
    per_iter = st.cycles / iters
    return MicrobenchResult(
        variant=variant,
        primitive=primitive,
        n_cores=n_cores,
        sfr=sfr,
        iters=iters,
        cycles_total=st.cycles,
        cycles_per_iter=per_iter,
        prim_cycles=per_iter - ideal_per_iter,
        active_core_cycles_per_iter=st.total_active / iters,
        gated_core_cycles_per_iter=st.total_gated / iters,
        tcdm_per_iter=st.total_tcdm / iters,
        scu_per_iter=st.total_scu / iters,
        stats=st,
    )


def run_barrier_bench(
    variant: str, n_cores: int, sfr: int = 0, iters: int = 256, cost_model=None,
    mode: str = "fastforward",
) -> MicrobenchResult:
    """Loop of ``iters`` (SFR-compute + barrier) on every core.

    ``variant`` is any registered ``repro.sync`` policy name (legacy
    uppercase spellings like ``"SCU"`` resolve via aliases).  ``mode``
    selects the engine (``"fastforward"`` skips quiescent cycles;
    ``"lockstep"`` is the cycle-by-cycle reference -- identical stats).
    """
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    policy = get_policy(variant)
    cl = _make_cluster(n_cores, mode)
    state = policy.make_sim_state(n_cores)
    cm = cost_model or DEFAULT_COSTS

    def program(cluster, cid):
        for _ in range(iters):
            if sfr > 0:
                yield Compute(sfr)
            yield from policy.sim_barrier(cluster, cid, state, cm)

    cl.load([program] * n_cores)
    return _collect(variant, "barrier", cl, n_cores, sfr, iters, float(sfr))


def run_mutex_bench(
    variant: str, n_cores: int, t_crit: int = 0, sfr: int = 0, iters: int = 256,
    cost_model=None, mode: str = "fastforward",
) -> MicrobenchResult:
    """Loop of (SFR-compute + critical section) on every core.

    Following the paper, the reported primitive cost is the overhead over the
    ideal ``N_C * T_crit`` serialization of the critical sections
    (``T_ideal = N_C T_crit``, Sec. 6.3).
    """
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    policy = get_policy(variant)
    cl = _make_cluster(n_cores, mode)
    state = policy.make_sim_state(n_cores)
    cm = cost_model or DEFAULT_COSTS

    def program(cluster, cid):
        for _ in range(iters):
            if sfr > 0:
                yield Compute(sfr)
            yield from policy.sim_mutex(cluster, cid, t_crit, state, cm)

    cl.load([program] * n_cores)
    ideal = float(n_cores * t_crit + sfr)
    return _collect(variant, f"mutex_t{t_crit}", cl, n_cores, sfr, iters, ideal)


def run_nop_bench(
    n_cores: int, cycles: int = 512, mode: str = "fastforward"
) -> ClusterStats:
    """``cycles`` of straight-line compute on every core (the paper's 512-nop
    run used to normalize power, Sec. 6.3)."""
    cl = _make_cluster(n_cores, mode)

    def program(cluster, cid):
        yield Compute(cycles)

    cl.load([program] * n_cores)
    return cl.run()
