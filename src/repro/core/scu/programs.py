"""Microbenchmark programs reproducing the paper's Sec. 6.3 experiments.

``primitive_cost`` mirrors the paper's methodology: "we let the involved
cores execute a loop eight times that contains the respective primitive 32
times and average the resulting cycle count".  The synchronization-free
region (SFR) between primitives is a run of ``Compute`` cycles (the paper
uses ``nop`` runs), tunable to sweep Fig. 5.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .engine import Cluster, ClusterStats, Compute, FleetConfig, Mem, simulate_fleet
from .primitives import DEFAULT_COSTS
from .scu_unit import SCU

__all__ = [
    "FleetBench",
    "MicrobenchResult",
    "barrier_pipeline_programs",
    "make_fleet",
    "prep_barrier_bench",
    "prep_chain_bench",
    "prep_mutex_bench",
    "prep_work_queue_bench",
    "run_barrier_bench",
    "run_chain_bench",
    "run_mutex_bench",
    "run_nop_bench",
    "run_work_queue_bench",
    "split_quota",
    "work_queue_programs",
]


@dataclasses.dataclass
class MicrobenchResult:
    variant: str
    primitive: str
    n_cores: int
    sfr: int
    iters: int
    cycles_total: int
    cycles_per_iter: float
    prim_cycles: float  # cycles_per_iter - ideal (SFR resp. N*T_crit)
    active_core_cycles_per_iter: float
    gated_core_cycles_per_iter: float
    tcdm_per_iter: float
    scu_per_iter: float
    stats: ClusterStats


def _make_cluster(n_cores: int, mode: str = "fastforward") -> Cluster:
    return Cluster(n_cores=n_cores, scu=SCU(n_cores=n_cores), mode=mode)


def _lower_loop_programs(
    cl: Cluster,
    n_cores: int,
    programs,
    n_iters: int,
    emit_iter=None,
    frag_iter=None,
    label: str = "",
):
    """Lower per-core iteration-loop programs to :class:`TraceProgram`s.

    Strategy per core: the policy's explicit per-iteration trace emitter
    when it has one (``emit_iter``), marked per-iteration sentinel tracing
    when the policy declared its fragment trace-safe (``frag_iter``), else a
    declared generator fallback -- policies whose fragments depend on
    cross-core execution order (shared Python state the sentinel cannot
    observe) must never be sentinel-traced, so the absence of both hooks
    forces the fallback rather than attempting it.
    """
    from .trace import TraceProgram, lower_or_fallback

    out = []
    for cid in range(n_cores):
        program = programs[cid]
        if emit_iter is not None:

            def emit(tb, cid=cid):
                for it in range(n_iters):
                    tb.mark()
                    emit_iter(tb, cid, it)

            out.append(
                lower_or_fallback(program, cl, cid, emit=emit, label=f"{label}:{cid}")
            )
        elif frag_iter is not None:

            def frags(cid=cid):
                return [
                    (lambda cid=cid, it=it: frag_iter(cid, it))
                    for it in range(n_iters)
                ]

            out.append(
                lower_or_fallback(
                    program, cl, cid, fragments=frags, label=f"{label}:{cid}"
                )
            )
        else:
            out.append(TraceProgram(fallback=program, label=f"{label}:fb:{cid}"))
    return out


def _lower_whole_programs(cl: Cluster, programs, trace_safe: bool, label: str = ""):
    """Lower pre-built (monolithic) per-core programs: whole-program sentinel
    tracing when the policy declared the fragments order-independent, else
    declared generator fallbacks for every core."""
    from .trace import TraceProgram, lower_or_fallback

    if not trace_safe:
        return [
            TraceProgram(fallback=p, label=f"{label}:fb:{cid}")
            for cid, p in enumerate(programs)
        ]
    return [
        lower_or_fallback(p, cl, cid, label=f"{label}:{cid}")
        for cid, p in enumerate(programs)
    ]


def _finalizer(
    variant: str,
    primitive: str,
    n_cores: int,
    sfr: int,
    iters: int,
    ideal_per_iter: float,
) -> Callable[[ClusterStats], MicrobenchResult]:
    """Deferred result builder: wraps finished ClusterStats into a
    MicrobenchResult -- shared by the sequential run_* paths and the
    batched fleet dispatch (:func:`make_fleet`)."""

    def finalize(st: ClusterStats) -> MicrobenchResult:
        per_iter = st.cycles / iters
        return MicrobenchResult(
            variant=variant,
            primitive=primitive,
            n_cores=n_cores,
            sfr=sfr,
            iters=iters,
            cycles_total=st.cycles,
            cycles_per_iter=per_iter,
            prim_cycles=per_iter - ideal_per_iter,
            active_core_cycles_per_iter=st.total_active / iters,
            gated_core_cycles_per_iter=st.total_gated / iters,
            tcdm_per_iter=st.total_tcdm / iters,
            scu_per_iter=st.total_scu / iters,
            stats=st,
        )

    return finalize


def _collect(
    variant: str,
    primitive: str,
    cl: Cluster,
    n_cores: int,
    sfr: int,
    iters: int,
    ideal_per_iter: float,
    warmup_stats: Optional[Tuple[int, Dict[str, float]]] = None,
) -> MicrobenchResult:
    return _finalizer(variant, primitive, n_cores, sfr, iters, ideal_per_iter)(
        cl.run()
    )


@dataclasses.dataclass
class FleetBench:
    """One prepared microbenchmark: a fleet config plus its result builder.

    Built by the ``prep_*_bench`` twins of the ``run_*_bench`` functions and
    dispatched in batches through :func:`make_fleet`; running the config's
    cluster sequentially and finalizing produces the identical result (the
    fleet engine is bit-exact per config)."""

    config: FleetConfig
    finalize: Callable[[ClusterStats], MicrobenchResult]

    def run_sequential(self) -> MicrobenchResult:
        """One-at-a-time execution (the non-batched reference path)."""
        cl = self.config.cluster
        cl.load(self.config.programs)
        return self.finalize(cl.run(self.config.max_cycles))


def make_fleet(benches: Sequence[FleetBench]) -> List[MicrobenchResult]:
    """Run prepared microbenchmarks as one batched fleet.

    The whole list executes as a single flattened array program
    (:func:`repro.core.scu.engine.simulate_fleet`); per-bench results are
    bit-identical to calling ``run_sequential()`` on each bench.  This is
    the dispatch point the sweep benchmarks (Table 1, Fig. 5, chain, work
    queue) funnel through."""
    stats = simulate_fleet([b.config for b in benches])
    return [b.finalize(st) for b, st in zip(benches, stats)]


def prep_barrier_bench(
    variant: str, n_cores: int, sfr: int = 0, iters: int = 256, cost_model=None,
    mode: str = "fastforward", compiled: bool = False,
) -> FleetBench:
    """Prepare (without running) a barrier microbenchmark config.

    ``compiled=True`` lowers every core's program to a static trace
    (:mod:`repro.core.scu.trace`) -- bit-exact stats, and fully-traced runs
    collapse repeated whole-cluster periods instead of simulating them.
    """
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    policy = get_policy(variant)
    cl = _make_cluster(n_cores, mode)
    state = policy.make_sim_state(n_cores)
    cm = cost_model or DEFAULT_COSTS

    def program(cluster, cid):
        for _ in range(iters):
            if sfr > 0:
                yield Compute(sfr)
            yield from policy.sim_barrier(cluster, cid, state, cm)

    programs = [program] * n_cores
    if compiled:
        emit_iter = frag_iter = None
        if policy.trace_barrier is not None:

            def emit_iter(tb, cid, it):
                if sfr > 0:
                    tb.compute(sfr)
                policy.trace_barrier(tb, cl, cid, state, cm)

        elif policy.trace_safe_barrier:

            def frag_iter(cid, it):
                if sfr > 0:
                    yield Compute(sfr)
                yield from policy.sim_barrier(cl, cid, state, cm)

        programs = _lower_loop_programs(
            cl, n_cores, programs, iters, emit_iter, frag_iter,
            label=f"{variant}:barrier",
        )
    return FleetBench(
        config=FleetConfig(cluster=cl, programs=programs),
        finalize=_finalizer(variant, "barrier", n_cores, sfr, iters, float(sfr)),
    )


def run_barrier_bench(
    variant: str, n_cores: int, sfr: int = 0, iters: int = 256, cost_model=None,
    mode: str = "fastforward", compiled: bool = False,
) -> MicrobenchResult:
    """Loop of ``iters`` (SFR-compute + barrier) on every core.

    ``variant`` is any registered ``repro.sync`` policy name (legacy
    uppercase spellings like ``"SCU"`` resolve via aliases).  ``mode``
    selects the engine (``"fastforward"`` skips quiescent cycles;
    ``"lockstep"`` is the cycle-by-cycle reference -- identical stats).
    """
    return prep_barrier_bench(
        variant, n_cores, sfr=sfr, iters=iters, cost_model=cost_model,
        mode=mode, compiled=compiled,
    ).run_sequential()


def prep_mutex_bench(
    variant: str, n_cores: int, t_crit: int = 0, sfr: int = 0, iters: int = 256,
    cost_model=None, mode: str = "fastforward", compiled: bool = False,
) -> FleetBench:
    """Prepare (without running) a mutex microbenchmark config."""
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    policy = get_policy(variant)
    cl = _make_cluster(n_cores, mode)
    state = policy.make_sim_state(n_cores)
    cm = cost_model or DEFAULT_COSTS

    def program(cluster, cid):
        for _ in range(iters):
            if sfr > 0:
                yield Compute(sfr)
            yield from policy.sim_mutex(cluster, cid, t_crit, state, cm)

    programs = [program] * n_cores
    if compiled:
        emit_iter = frag_iter = None
        if policy.trace_mutex is not None:

            def emit_iter(tb, cid, it):
                if sfr > 0:
                    tb.compute(sfr)
                policy.trace_mutex(tb, cl, cid, t_crit, state, cm)

        elif policy.trace_safe_mutex:

            def frag_iter(cid, it):
                if sfr > 0:
                    yield Compute(sfr)
                yield from policy.sim_mutex(cl, cid, t_crit, state, cm)

        programs = _lower_loop_programs(
            cl, n_cores, programs, iters, emit_iter, frag_iter,
            label=f"{variant}:mutex",
        )
    ideal = float(n_cores * t_crit + sfr)
    return FleetBench(
        config=FleetConfig(cluster=cl, programs=programs),
        finalize=_finalizer(
            variant, f"mutex_t{t_crit}", n_cores, sfr, iters, ideal
        ),
    )


def run_mutex_bench(
    variant: str, n_cores: int, t_crit: int = 0, sfr: int = 0, iters: int = 256,
    cost_model=None, mode: str = "fastforward", compiled: bool = False,
) -> MicrobenchResult:
    """Loop of (SFR-compute + critical section) on every core.

    Following the paper, the reported primitive cost is the overhead over the
    ideal ``N_C * T_crit`` serialization of the critical sections
    (``T_ideal = N_C T_crit``, Sec. 6.3).
    """
    return prep_mutex_bench(
        variant, n_cores, t_crit=t_crit, sfr=sfr, iters=iters,
        cost_model=cost_model, mode=mode, compiled=compiled,
    ).run_sequential()


def barrier_pipeline_programs(policy, n_cores: int, work, state, cost_model=None):
    """Barrier-synchronous pipeline emulation (the non-FIFO baseline).

    The classic way to run a stage pipeline with only barriers: the whole
    cluster advances in lockstep ticks; at tick ``t`` stage ``s`` works on
    item ``t - s`` (if in range), then everybody meets at a global barrier.
    Stages that have nothing to do this tick still pay the barrier -- the
    exact cost the SCU's event FIFO removes (Sec. 4.3), which is what
    :func:`run_chain_bench` measures.
    """
    cm = cost_model or DEFAULT_COSTS
    items = len(work)

    def make(cid):
        def prog(cluster, _cid):
            for tick in range(items + n_cores - 1):
                item = tick - _cid
                if 0 <= item < items:
                    w = int(work[item][_cid])
                    if w > 0:
                        yield Compute(w)
                yield from policy.sim_barrier(cluster, _cid, state, cm)

        return prog

    return [make(c) for c in range(n_cores)]


def make_pipeline_programs(
    policy, cl: Cluster, n_cores: int, work, state, cost_model=None,
    depth: int = 8,
):
    """Pipeline-program dispatch shared by the chain bench and the
    pipelined apps: the policy's native ``make_pipeline_programs`` hook when
    it has one (validated against the actual SCU FIFO capacity -- a deeper
    credit window than the queues hold would drop events and deadlock),
    else the barrier-synchronous emulation."""
    cm = cost_model or DEFAULT_COSTS
    maker = getattr(policy, "make_pipeline_programs", None)
    if maker is None:
        return barrier_pipeline_programs(policy, n_cores, work, state, cm)
    if cl.scu is not None and depth > cl.scu.fifo.depth:
        raise ValueError(
            f"pipeline depth {depth} exceeds the SCU FIFO depth "
            f"{cl.scu.fifo.depth}; deepen the FIFOs or lower the bound"
        )
    return maker(n_cores, work, state, cm, depth)


def prep_chain_bench(
    variant: str,
    n_cores: int,
    sfr: int = 100,
    iters: int = 32,
    depth: int = 8,
    cost_model=None,
    mode: str = "fastforward",
    compiled: bool = False,
) -> FleetBench:
    """Prepare (without running) a pipelined-chain microbenchmark config."""
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    policy = get_policy(variant)
    cl = _make_cluster(n_cores, mode)
    state = policy.make_sim_state(n_cores)
    cm = cost_model or DEFAULT_COSTS
    work = [[sfr] * n_cores for _ in range(iters)]
    programs = make_pipeline_programs(
        policy, cl, n_cores, work, state, cost_model, depth
    )
    if compiled:
        if getattr(policy, "make_pipeline_programs", None) is not None:
            # native (FIFO) chain: monolithic per-core programs, traced whole
            programs = _lower_whole_programs(
                cl, programs, policy.trace_safe_barrier,
                label=f"{variant}:chain",
            )
        else:
            # barrier-synchronous emulation: per-tick loop, same lowering
            # split as the barrier bench
            emit_iter = frag_iter = None

            def _tick_work(cid, tick):
                item = tick - cid
                if 0 <= item < iters:
                    return int(work[item][cid])
                return 0

            if policy.trace_barrier is not None:

                def emit_iter(tb, cid, tick):
                    w = _tick_work(cid, tick)
                    if w > 0:
                        tb.compute(w)
                    policy.trace_barrier(tb, cl, cid, state, cm)

            elif policy.trace_safe_barrier:

                def frag_iter(cid, tick):
                    w = _tick_work(cid, tick)
                    if w > 0:
                        yield Compute(w)
                    yield from policy.sim_barrier(cl, cid, state, cm)

            programs = _lower_loop_programs(
                cl, n_cores, programs, iters + n_cores - 1, emit_iter,
                frag_iter, label=f"{variant}:chain",
            )
    return FleetBench(
        config=FleetConfig(cluster=cl, programs=programs),
        finalize=_finalizer(
            variant, f"chain_d{depth}", n_cores, sfr, iters, float(sfr)
        ),
    )


def run_chain_bench(
    variant: str,
    n_cores: int,
    sfr: int = 100,
    iters: int = 32,
    depth: int = 8,
    cost_model=None,
    mode: str = "fastforward",
    compiled: bool = False,
) -> MicrobenchResult:
    """Pipelined producer-consumer chain: ``n_cores`` stages, ``iters`` items.

    Every item costs ``sfr`` compute cycles at every stage, so the ideal
    steady-state cost is one item per ``sfr`` cycles (stages fully
    overlapped); ``prim_cycles`` is the per-item overhead over that ideal.
    Policies with a native ``make_pipeline_programs`` hook (the ``fifo``
    discipline's credit-bounded chain, bounded to ``depth`` in-flight items)
    run it; everything else falls back to the barrier-synchronous emulation
    -- the baseline the paper's FIFO extension exists to beat.
    """
    return prep_chain_bench(
        variant, n_cores, sfr=sfr, iters=iters, depth=depth,
        cost_model=cost_model, mode=mode, compiled=compiled,
    ).run_sequential()


WQ_CS_CYCLES = 6  # queue-pointer bookkeeping inside the dequeue/enqueue lock
WQ_RETRY_CYCLES = 8  # consumer backoff before re-polling an empty queue
A_WQ_LEVEL = 0x180  # advertised queue occupancy (test before locking)


class _WorkQueue:
    """Occupancy bookkeeping of the shared work queue.

    The item count is Python-side shared state, like the software barriers'
    local-sense arrays: the *synchronization traffic* (the occupancy word
    at :data:`A_WQ_LEVEL`, mutex acquire/release around every queue
    operation, the consumers' retry discipline, or the FIFO policy's native
    push/pop events) runs through simulated ops and is the measured
    quantity; the item payloads themselves are abstract.
    """

    def __init__(self):
        self.available = 0


def split_quota(items: int, n: int) -> list:
    """Fair partition of ``items`` over ``n`` workers (remainder first)."""
    return [items // n + (1 if i < items % n else 0) for i in range(n)]


def work_queue_programs(
    policy, n_producers: int, n_consumers: int, items: int,
    t_produce: int, t_consume: int, state, cost_model=None,
):
    """Multi-producer/multi-consumer work-queue programs for any policy.

    Policies with a native ``make_work_queue_programs`` hook (the ``fifo``
    discipline: blocking ``push_wait`` producers against hardware
    backpressure, clock-gated ``pop`` consumers) build their own programs;
    everything else runs the classic software shape -- a mutex-protected
    shared queue where producers enqueue under the lock and consumers
    poll-and-retry until their quota of items arrived.
    """
    cm = cost_model or DEFAULT_COSTS
    maker = getattr(policy, "make_work_queue_programs", None)
    if maker is not None:
        return maker(
            n_producers, n_consumers, items, t_produce, t_consume, state, cm
        )
    wq = _WorkQueue()

    def make_producer(quota):
        def prog(cluster, cid):
            for _ in range(quota):
                if t_produce > 0:
                    yield Compute(t_produce)
                yield from policy.sim_mutex(cluster, cid, WQ_CS_CYCLES, state, cm)
                wq.available += 1
                yield Mem("sw", A_WQ_LEVEL, wq.available)  # advertise

        return prog

    def make_consumer(quota):
        def prog(cluster, cid):
            got = 0
            while got < quota:
                # test before locking: poll the occupancy word with a plain
                # load and only contend for the lock when the queue looks
                # non-empty.  Besides being how real runtimes shape this
                # loop, it is essential for liveness here: under the
                # cycle-exact simulator, consumers hammering the lock on an
                # empty queue can resonate into perfectly periodic
                # starvation of the producers.  The backoff is additionally
                # staggered by core id (the simulated twin of randomized
                # backoff) so consumer herds don't re-synchronize.
                #
                # The load models the polling traffic; the *decision* reads
                # the Python-side count, which is the coherent value of the
                # occupancy word.  (A real TCDM load is coherent with the
                # enqueue that produced it; trusting the simulated store
                # data instead would re-introduce an artifact of our
                # modeling -- Mem data is captured at yield time but lands
                # at grant time, so a stale snapshot can be granted after a
                # newer one and park the advertised level at 0 forever.)
                yield Mem("lw", A_WQ_LEVEL)
                yield Compute(1 + cm.load_use)
                if wq.available <= 0:
                    yield Compute(WQ_RETRY_CYCLES + cid)
                    continue
                yield from policy.sim_mutex(cluster, cid, WQ_CS_CYCLES, state, cm)
                if wq.available > 0:
                    wq.available -= 1
                    yield Mem("sw", A_WQ_LEVEL, wq.available)
                    got += 1
                    if t_consume > 0:
                        yield Compute(t_consume)
                else:
                    yield Compute(WQ_RETRY_CYCLES + cid)

        return prog

    return [make_producer(q) for q in split_quota(items, n_producers)] + [
        make_consumer(q) for q in split_quota(items, n_consumers)
    ]


def prep_work_queue_bench(
    variant: str,
    n_producers: int,
    n_consumers: int,
    items: int = 64,
    t_produce: int = 30,
    t_consume: int = 30,
    cost_model=None,
    mode: str = "fastforward",
    compiled: bool = False,
) -> FleetBench:
    """Prepare (without running) a multi-producer work-queue config."""
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    policy = get_policy(variant)
    n_cores = n_producers + n_consumers
    cl = _make_cluster(n_cores, mode)
    state = policy.make_sim_state(n_cores)
    programs = work_queue_programs(
        policy, n_producers, n_consumers, items, t_produce, t_consume,
        state, cost_model,
    )
    if compiled:
        # the native FIFO queue programs are value- and order-independent
        # (trace-safe); the generic mutex-protected queue branches on shared
        # Python-side occupancy in cross-core execution order, so every
        # non-native policy is a declared generator fallback
        native = getattr(policy, "make_work_queue_programs", None) is not None
        programs = _lower_whole_programs(
            cl, programs, native and policy.trace_safe_barrier,
            label=f"{variant}:wq",
        )
    ideal = items * max(t_produce / n_producers, t_consume / n_consumers)
    return FleetBench(
        config=FleetConfig(cluster=cl, programs=programs),
        finalize=_finalizer(
            variant, f"wq_p{n_producers}c{n_consumers}", n_cores, t_produce,
            items, ideal / items,
        ),
    )


def run_work_queue_bench(
    variant: str,
    n_producers: int,
    n_consumers: int,
    items: int = 64,
    t_produce: int = 30,
    t_consume: int = 30,
    cost_model=None,
    mode: str = "fastforward",
    compiled: bool = False,
) -> MicrobenchResult:
    """Multi-producer work queue: P producers feed C consumers through one
    shared queue; every policy supplies its own queue discipline (see
    :func:`work_queue_programs`).

    The ideal steady state is bounded by the busier side of the queue --
    ``max(P * t_produce, C * t_consume) / (P*C)``-ish per item; we report
    ``cycles_per_iter`` per *item* and the overhead over the ideal
    ``items * max(t_produce / P, t_consume / C)`` schedule.
    """
    return prep_work_queue_bench(
        variant, n_producers, n_consumers, items=items, t_produce=t_produce,
        t_consume=t_consume, cost_model=cost_model, mode=mode,
        compiled=compiled,
    ).run_sequential()


def run_nop_bench(
    n_cores: int, cycles: int = 512, mode: str = "fastforward"
) -> ClusterStats:
    """``cycles`` of straight-line compute on every core (the paper's 512-nop
    run used to normalize power, Sec. 6.3)."""
    cl = _make_cluster(n_cores, mode)

    def program(cluster, cid):
        yield Compute(cycles)

    cl.load([program] * n_cores)
    return cl.run()
