"""Cluster energy model (paper Sec. 6.2: 22 nm, 0.8 V, 25 C, 350 MHz).

The paper measures post-layout energy of the whole cluster; we model it as a
linear combination of activity counters produced by the cycle-accurate
simulation:

    E = e_comp * comp  + e_wait * wait + e_gate * gated
      + e_mem  * tcdm  + e_scu  * scu  + e_static * wall_cycles

with per-event/energy coefficients in pJ:

  comp   -- core-cycles spent executing (incl. its I$ fetch share),
  wait   -- core-cycles clocked but held (LINT stall / elw grant window /
            wake sequencing): pipeline registers + clock tree only,
  gated  -- clock-gated core-cycles (leakage + local clock root),
  tcdm   -- TCDM bank accesses incl. the interconnect traversal,
  scu    -- SCU transactions over the private links,
  static -- cluster-wide per-cycle constant (leakage + global clock tree;
            the clock distribution network the paper emphasizes).

The default coefficients are CALIBRATED against the paper's Table 1 energy
column and the Fig. 5 minimum-SFR anchors (42 / 1622 / 1771 cycles @ 10%
energy overhead, 8 cores); see ``benchmarks/table1_primitives.py`` for the
reproduction and fit error, and :func:`calibrate` for the fitting procedure.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Sequence, Tuple

from .engine import ClusterStats

__all__ = ["EnergyModel", "DEFAULT_ENERGY", "Activity", "calibrate"]

F_CLK = 350e6  # Hz, the paper's reported operating point


@dataclasses.dataclass(frozen=True)
class Activity:
    """Activity counters for an execution window (absolute, not per-iter)."""

    comp: float
    wait: float
    gated: float
    tcdm: float
    scu: float
    cycles: float

    @staticmethod
    def from_stats(st: ClusterStats) -> "Activity":
        return Activity(
            comp=st.total_comp,
            wait=st.total_wait,
            gated=st.total_gated,
            tcdm=st.total_tcdm,
            scu=st.total_scu,
            cycles=st.cycles,
        )

    @staticmethod
    def per_iter(
        st: ClusterStats,
        iters: int,
        comp_offset: float = 0.0,
        cycles_offset: float = 0.0,
    ) -> "Activity":
        """Per-iteration activity of an ``iters``-iteration benchmark loop.

        ``comp_offset``/``cycles_offset`` subtract the ideal (paper-style)
        work per iteration so the remainder is the primitive's own activity
        -- e.g. ``n_cores * t_crit`` for the mutex benchmarks, where the
        critical sections themselves are not synchronization cost.  Used by
        the Table-1 / Fig-5 / chain benchmarks; FIFO pushes and pops are SCU
        transactions and land in ``scu`` like every other private-link
        access.
        """
        return Activity(
            comp=st.total_comp / iters - comp_offset,
            wait=st.total_wait / iters,
            gated=st.total_gated / iters,
            tcdm=st.total_tcdm / iters,
            scu=st.total_scu / iters,
            cycles=st.cycles / iters - cycles_offset,
        )

    def vector(self) -> Tuple[float, ...]:
        return (self.comp, self.wait, self.gated, self.tcdm, self.scu, self.cycles)


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """pJ coefficients; defaults calibrated to the paper (see module doc)."""

    e_comp: float = 2.0
    e_wait: float = 0.8
    e_gate: float = 0.2
    e_mem: float = 1.5
    e_scu: float = 1.0
    e_static: float = 12.0
    # Application (DSP) instruction mix: MACs/SIMD + ~1 TCDM access every
    # other instruction burn substantially more than the control/spin
    # instructions the Table-1 microbenchmarks execute.  Calibrated against
    # the Table-2 application energies (AES: ~68 pJ/cycle cluster-wide).
    e_dsp: float = 7.0
    mem_intensity: float = 0.5  # TCDM accesses per DSP compute cycle

    def app_energy_adjustment_pj(self, app_comp_cycles: float) -> float:
        """Extra energy of ``app_comp_cycles`` core-cycles of DSP work over
        the plain ``e_comp`` charge already accounted by the simulator."""
        return app_comp_cycles * (
            self.e_dsp - self.e_comp + self.mem_intensity * self.e_mem
        )

    def energy_pj(self, act: Activity) -> float:
        return (
            self.e_comp * act.comp
            + self.e_wait * act.wait
            + self.e_gate * act.gated
            + self.e_mem * act.tcdm
            + self.e_scu * act.scu
            + self.e_static * act.cycles
        )

    def energy_nj(self, act: Activity) -> float:
        return self.energy_pj(act) / 1e3

    def breakdown_pj(self, act: Activity) -> Dict[str, float]:
        """Per-component energy -- the Fig. 7 analogue."""
        return {
            "cores_active": self.e_comp * act.comp + self.e_wait * act.wait,
            "cores_gated": self.e_gate * act.gated,
            "tcdm+interco": self.e_mem * act.tcdm,
            "scu": self.e_scu * act.scu,
            "static+clktree": self.e_static * act.cycles,
        }

    def power_mw(self, act: Activity) -> float:
        """Average power over the window at the paper's 350 MHz."""
        if act.cycles == 0:
            return 0.0
        return self.energy_pj(act) / act.cycles * 1e-12 * F_CLK * 1e3

    def nop_power_per_cycle_pj(self, n_cores: int, n_total: int = 8) -> float:
        """P_comp,N: cluster energy/cycle with N cores running straight-line
        code and the rest clock-gated (the paper's 512-nop normalization)."""
        return (
            n_cores * self.e_comp
            + (n_total - n_cores) * self.e_gate
            + self.e_static
        )


DEFAULT_ENERGY = EnergyModel()


def calibrate(
    cells: Sequence[Tuple[Activity, float, int]],
    sfr_anchors: Sequence[Tuple[Activity, float, int, float]] = (),
    grids: Dict[str, Sequence[float]] | None = None,
) -> Tuple[EnergyModel, float]:
    """Fit coefficients to paper anchors by bounded grid search.

    ``cells``: (per-iteration activity, paper energy in pJ, n_cores).
    ``sfr_anchors``: (per-iter activity, paper min-SFR cycles @10%, n_cores,
    weight); the induced constraint is  E_prim == 0.1 * SFR * P_comp,N.

    Returns the best model and its RMS relative error over the cells.
    """
    grids = grids or {
        "e_comp": [1.5, 2.0, 2.5, 3.0],
        "e_wait": [0.4, 0.8, 1.2],
        "e_gate": [0.05, 0.1, 0.2],
        "e_mem": [2.0, 4.0, 6.0, 8.0],
        "e_scu": [0.5, 1.0, 2.0],
        "e_static": [2.0, 3.5, 5.0, 7.0],
    }
    names = list(grids)
    best: Tuple[float, EnergyModel] | None = None
    for combo in itertools.product(*(grids[n] for n in names)):
        m = EnergyModel(**dict(zip(names, combo)))
        err = 0.0
        for act, paper_pj, _n in cells:
            pred = m.energy_pj(act)
            err += ((pred - paper_pj) / paper_pj) ** 2
        for act, sfr, n, w in sfr_anchors:
            pred_sfr = m.energy_pj(act) / (0.1 * m.nop_power_per_cycle_pj(n))
            err += w * ((pred_sfr - sfr) / sfr) ** 2
        if best is None or err < best[0]:
            best = (err, m)
    assert best is not None
    n_cells = max(1, len(cells))
    return best[1], (best[0] / n_cells) ** 0.5
