"""Workload models of the paper's nine DSP applications (Table 2 / Fig. 6).

Each application is modelled as the parallel *synchronization skeleton* the
paper describes in Sec. 6.4: a sequence of parallel sections (SFRs) separated
by barriers, with per-core workload imbalance and sequential phases where
applicable.  The skeleton parameters (barrier count, mean SFR size, imbalance,
sequential fraction) are taken from Table 2 and the per-application
descriptions; the arithmetic inside an SFR is abstracted as ``Compute``
cycles (the synchronization behaviour -- the paper's subject -- is simulated
exactly, on the same engine and primitives as the microbenchmarks).

This lets us reproduce the paper's application-level claims: performance
improvements up to ~92% / 23% on average, energy up to ~98% / 39% on
average, with the largest gains for the small-SFR, high-imbalance apps
(Dijkstra, Livermore6, PCA) and the smallest for the large-SFR ones
(AES, FFT).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .energy import DEFAULT_ENERGY, Activity, EnergyModel
from .engine import Cluster, Compute
from .primitives import DEFAULT_COSTS
from .scu_unit import SCU

__all__ = ["AppModel", "APPS", "PIPELINED_APPS", "run_app", "run_app_pipelined", "AppResult"]


@dataclasses.dataclass(frozen=True)
class AppModel:
    """Synchronization skeleton of one application (Table 2 row).

    ``barriers``     -- number of barriers over the whole run.
    ``sfr``          -- mean synchronization-free region size in cycles.
    ``imbalance``    -- per-core relative stddev of each section's work
                        (lognormal-ish jitter; Table 2 'active (stddev)').
    ``seq_fraction`` -- fraction of sections where only one core works
                        (sequential phases, e.g. PCA's diagonalization).
    """

    name: str
    domain: str
    barriers: int
    sfr: int
    imbalance: float
    seq_fraction: float = 0.0


# Parameters from Table 2 (barrier count, SFR size) and Sec. 6.4 app
# descriptions (imbalance from the active-cycle stddev / mean; sequential
# fractions from the narratives).
APPS: Dict[str, AppModel] = {
    "dwt": AppModel("dwt", "signal processing", 10, 1050, 0.03),
    "dijkstra": AppModel("dijkstra", "graph search", 238, 110, 0.12, 0.05),
    "aes": AppModel("aes", "cryptography", 4, 10200, 0.005),
    "livermore6": AppModel("livermore6", "linear recurrence", 127, 104, 0.55),
    "livermore2": AppModel("livermore2", "gradient descent", 12, 744, 0.015),
    "fft": AppModel("fft", "frequency analysis", 4, 1480, 0.015),
    "fann": AppModel("fann", "machine learning", 160, 545, 0.03),
    "mfcc": AppModel("mfcc", "audio processing", 693, 725, 0.05),
    "pca": AppModel("pca", "data analysis", 2305, 375, 0.65, 0.30),
}


@dataclasses.dataclass
class AppResult:
    app: str
    variant: str
    cycles: int
    active_cycles: float  # mean over cores
    active_stddev: float
    energy_uj: float
    power_mw: float
    sync_total: float  # mean per-core cycles inside sync primitives (incl. wait)
    sync_active: float  # mean per-core *active* cycles inside sync primitives
    breakdown: Dict[str, float]


def _section_lengths(app: AppModel, n_cores: int, seed: int) -> np.ndarray:
    """(barriers, n_cores) per-core compute lengths between barriers."""
    rng = np.random.default_rng(seed)
    base = rng.normal(app.sfr, app.imbalance * app.sfr, size=(app.barriers, n_cores))
    base = np.maximum(1, base).astype(np.int64)
    if app.seq_fraction > 0:
        seq_rows = rng.random(app.barriers) < app.seq_fraction
        # sequential phase: core 0 does the combined work, others idle-wait
        base[seq_rows, 0] = np.maximum(1, base[seq_rows].sum(axis=1) // 2)
        base[seq_rows, 1:] = 1
    return base


def run_app(
    app: AppModel,
    variant: str,
    n_cores: int = 8,
    seed: int = 0,
    energy_model: EnergyModel = DEFAULT_ENERGY,
    mode: str = "fastforward",
) -> AppResult:
    """Run one application skeleton under one synchronization variant
    (any registered ``repro.sync`` policy).  ``mode`` selects the engine
    (event-driven fast path by default; ``"lockstep"`` for the reference)."""
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    policy = get_policy(variant)
    sections = _section_lengths(app, n_cores, seed)
    scu = SCU(n_cores=n_cores)
    cl = Cluster(n_cores=n_cores, scu=scu, mode=mode)
    sync_state = policy.make_sim_state(n_cores)

    # Track per-core sync cycles by sampling core state inside primitives.
    sync_marks: List[List[Tuple[int, int]]] = [[] for _ in range(n_cores)]

    def program(cluster, cid):
        for b in range(app.barriers):
            yield Compute(int(sections[b, cid]))
            t0 = cluster.cycle
            a0 = cluster.cores[cid].stats.active_cycles if cluster.cores else 0
            yield from policy.sim_barrier(cluster, cid, sync_state, DEFAULT_COSTS)
            a1 = cluster.cores[cid].stats.active_cycles
            sync_marks[cid].append((cluster.cycle - t0, a1 - a0))

    cl.load([program] * n_cores)
    st = cl.run(max_cycles=200_000_000)

    actives = np.array([c.active_cycles for c in st.cores], dtype=np.float64)
    sync_total = float(np.mean([sum(t for t, _ in m) for m in sync_marks]))
    sync_active = float(np.mean([sum(a for _, a in m) for m in sync_marks]))
    return _make_app_result(
        app, variant, st, actives, sync_total, sync_active,
        float(sections.sum()), energy_model,
    )


def _make_app_result(
    app: AppModel, variant: str, st, actives, sync_total, sync_active,
    app_comp_cycles: float, energy_model: EnergyModel,
) -> AppResult:
    # The compute sections are DSP work (MAC/SIMD + memory traffic), not the
    # nop/spin mix the base coefficients describe -- charge the difference.
    act = Activity.from_stats(st)
    adj_pj = energy_model.app_energy_adjustment_pj(app_comp_cycles)
    energy_pj = energy_model.energy_pj(act) + adj_pj
    breakdown = energy_model.breakdown_pj(act)
    breakdown["cores_active"] += adj_pj
    return AppResult(
        app=app.name,
        variant=variant,
        cycles=st.cycles,
        active_cycles=float(actives.mean()),
        active_stddev=float(actives.std()),
        energy_uj=energy_pj / 1e6,
        power_mw=energy_pj / st.cycles * 1e-12 * 350e6 * 1e3 if st.cycles else 0.0,
        sync_total=sync_total,
        sync_active=sync_active,
        breakdown=breakdown,
    )


# Apps whose structure is a natural stage pipeline (streaming items through
# per-core processing stages) -- the shape the SCU's event FIFO targets.
# mfcc is the canonical one: audio frames stream through framing / FFT /
# mel-filterbank / DCT stages.
PIPELINED_APPS = ("mfcc",)


def run_app_pipelined(
    app: AppModel,
    variant: str,
    n_cores: int = 8,
    seed: int = 0,
    depth: int = 8,
    energy_model: EnergyModel = DEFAULT_ENERGY,
    mode: str = "fastforward",
) -> AppResult:
    """Pipelined variant of an application skeleton (one stage per core).

    The app's per-barrier-interval work matrix is reinterpreted as ``items x
    stages``: interval ``b``'s per-core workloads become the per-stage costs
    of item ``b`` flowing through the pipeline.  Policies with a native
    ``make_pipeline_programs`` hook (the ``fifo`` discipline) overlap the
    stages through credit-bounded event queues; every other policy runs the
    barrier-synchronous emulation, paying one global barrier per pipeline
    tick.  ``sync_total``/``sync_active`` report the per-core overhead over
    the pure per-stage work (everything that is not the item's compute).
    """
    from repro.sync import get_policy  # deferred: repro.sync imports this pkg

    from .programs import make_pipeline_programs

    policy = get_policy(variant)
    sections = _section_lengths(app, n_cores, seed)
    cl = Cluster(n_cores=n_cores, scu=SCU(n_cores=n_cores), mode=mode)
    state = policy.make_sim_state(n_cores)
    cl.load(make_pipeline_programs(
        policy, cl, n_cores, sections.tolist(), state, DEFAULT_COSTS, depth
    ))
    st = cl.run(max_cycles=200_000_000)

    actives = np.array([c.active_cycles for c in st.cores], dtype=np.float64)
    stage_work = sections.sum(axis=0).astype(np.float64)  # per-core item work
    sync_total = float(np.mean(st.cycles - stage_work))
    sync_active = float(np.mean(actives - stage_work))
    return _make_app_result(
        app, variant, st, actives, sync_total, sync_active,
        float(sections.sum()), energy_model,
    )
