"""Static micro-op trace IR: core programs as data tables, not generators.

PR 5 measured the engine's ceiling: generator advances and spin replay are
per-micro-op *Python*, shared by every dispatch mode.  This module makes the
program side of that boundary static.  A :class:`TraceProgram` is a per-core
table of ``(op_kind, operands..., repeat)`` rows compiled from the existing
``Compute``/``Mem``/``Poll``/``Scu`` generator programs, with bounded loops
re-rolled into explicit ``LOOP`` rows and an explicit "not traceable ->
generator fallback" escape hatch.

Three consumers:

* :class:`_TraceCursor` -- a drop-in generator replacement (``send`` /
  ``__next__`` / ``StopIteration``) interpreting the table, so every
  existing engine tier (lockstep, fast-forward, fleet, ``SlotFleet.admit``)
  executes traces unchanged and bit-exactly.
* :class:`TraceRunMonitor` -- the compiled fast path.  Because a traced
  cluster's *entire* program state is (pc, repeat, loop counters, R), the
  monitor can digest the full cluster state at loop-head crossings, prove a
  whole-cluster period, and collapse all remaining loop iterations into one
  multiply of the per-period stat deltas -- no per-micro-op Python for the
  jumped span.  This is what moves the 8-core spin-heavy sweeps, which sit
  below the vectorization threshold and spin through shared-state phases
  the quiescent/spin tiers cannot jump.
* :func:`run_traces_xp` -- a self-contained batched array executor for
  pure-TCDM traces: program counters, round-robin arbitration and phase-5
  accounting as array kernels (numpy, or one ``jax.jit`` program behind
  :mod:`repro.compat`) with no per-micro-op Python in the loop.

Value semantics: a trace tracks one register ``R`` mirroring the engine's
``resume_value`` -- every granted transaction latches into it, exactly like
the value sent into a generator.  ``BR`` branches compare ``R`` against an
immediate; ``sw`` rows may store ``R + delta`` (latched at fetch time, like
a generator computing from the value it received).  Programs whose control
flow depends on values in ways the IR cannot express are detected by the
sentinel tracer (:func:`trace_generator`) and fall back to generators.

Lifecycle: like :class:`repro.core.scu.faults.FaultPlan`, a
:class:`TraceProgram` is **single-use** -- its cursor owns mutable run
state, and the lowering that produced it consumed one build of the (shared,
mutable) policy state.  Re-running a config means re-lowering or
:meth:`TraceProgram.clone`.
"""

from __future__ import annotations

import os
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .engine import _COUNTERS, Compute, Mem, Poll, Scu

__all__ = [
    "T_COMPUTE",
    "T_MEM",
    "T_POLL",
    "T_SCU",
    "T_JMP",
    "T_BR",
    "T_LOOP",
    "T_HALT",
    "Untraceable",
    "TraceBuilder",
    "TraceProgram",
    "TraceRunMonitor",
    "trace_generator",
    "trace_fragments",
    "lower_or_fallback",
    "run_traces_xp",
    "run_traces_jax",
]

# --------------------------------------------------------------------------
# Row encoding: (op, repeat, a0..a6) int tuples.  Control rows cost zero
# cycles and zero instructions -- branch/loop costs are already folded into
# the Compute cycles the generators charge (see primitives.CostModel).
# --------------------------------------------------------------------------

T_COMPUTE = 0  # a0 = cycles
T_MEM = 1  # a0 = kind code, a1 = addr, a2 = data, a3 = 1 if data is R + a2
T_POLL = 2  # a0 = kind, a1 = addr, a2 = until, a3..a6 = hit_c/miss_c/hit_i/miss_i
T_SCU = 3  # a0 = index into the program's scu op pool
T_JMP = 4  # a0 = target row
T_BR = 5  # a0 = immediate, a1 = target row; taken when R == a0
T_LOOP = 6  # a0 = target row, a1 = count of back-jumps before falling through
T_HALT = 7

_MK_LW, _MK_SW, _MK_TAS = 0, 1, 2
_MEM_KIND_CODE = {"lw": _MK_LW, "sw": _MK_SW, "tas": _MK_TAS}
_MEM_KIND_NAME = {v: k for k, v in _MEM_KIND_CODE.items()}

_DATA_OPS = (T_COMPUTE, T_MEM, T_POLL, T_SCU)

# Bound on resolved control rows per fetch: a trace whose control flow
# cycles without reaching a data op is malformed (it would hang the engine).
_CONTROL_GUARD = 100_000


class Untraceable(Exception):
    """The program's op stream depends on values the trace IR cannot carry."""


# --------------------------------------------------------------------------
# Sentinel tracer: prove value-independence by poisoning every resume value
# --------------------------------------------------------------------------


class _ValueUsed(Exception):
    pass


def _poison(*_a, **_k):
    raise _ValueUsed


class _Sentinel:
    """Poison resume value: any observation (comparison, arithmetic, truth
    test, hashing, conversion) raises; storing or ignoring it is allowed."""

    __slots__ = ()

    def __repr__(self) -> str:  # repr stays safe for error messages
        return "<trace sentinel>"


for _name in (
    "__eq__", "__ne__", "__lt__", "__le__", "__gt__", "__ge__", "__hash__",
    "__bool__", "__int__", "__index__", "__float__",
    "__add__", "__radd__", "__sub__", "__rsub__", "__mul__", "__rmul__",
    "__floordiv__", "__rfloordiv__", "__mod__", "__rmod__",
    "__and__", "__rand__", "__or__", "__ror__", "__xor__", "__rxor__",
    "__lshift__", "__rlshift__", "__rshift__", "__rrshift__", "__neg__",
    "__invert__", "__getitem__", "__iter__", "__len__", "__format__",
):
    setattr(_Sentinel, _name, _poison)

_SENTINEL = _Sentinel()


def _check_static(value: Any) -> Any:
    if isinstance(value, _Sentinel):
        raise Untraceable("micro-op embeds a value the program received")
    if isinstance(value, tuple):
        for item in value:
            _check_static(item)
    return value


# --------------------------------------------------------------------------
# Builder
# --------------------------------------------------------------------------


class TraceBuilder:
    """Append-only trace assembler with iteration marks and loop re-rolling.

    Emitters call :meth:`mark` at each iteration boundary; :meth:`build`
    re-rolls runs of identical marked segments (period 1..4, e.g. the
    sense-alternating barrier pair) into one segment plus a ``LOOP`` row --
    required for the table to stay small *and* for program counters to
    recur, which is what the period-collapse monitor keys on.  All branch
    targets must stay inside their own segment (asserted at build time).
    """

    def __init__(self) -> None:
        self._rows: List[Tuple[int, ...]] = []
        self._marks: List[int] = []
        self._scu_pool: List[Scu] = []
        self._scu_index: Dict[Tuple[Any, ...], int] = {}
        self._pinned: set = set()  # rows a label points at (no coalescing)

    # ------------------------------------------------------------- emitters
    def label(self) -> int:
        self._pinned.add(len(self._rows))
        return len(self._rows)

    def mark(self) -> None:
        if not self._marks or self._marks[-1] != len(self._rows):
            self._marks.append(len(self._rows))

    def _push(self, row: Tuple[int, ...]) -> int:
        idx = len(self._rows)
        self._rows.append(row)
        return idx

    def compute(self, cycles: int) -> int:
        cycles = int(_check_static(cycles))
        rows = self._rows
        if rows and len(rows) not in self._pinned:
            last = rows[-1]
            if last[0] == T_COMPUTE and last[2] == cycles and (
                not self._marks or self._marks[-1] != len(rows)
            ):
                rows[-1] = (T_COMPUTE, last[1] + 1, cycles, 0, 0, 0, 0, 0, 0)
                return len(rows) - 1
        return self._push((T_COMPUTE, 1, cycles, 0, 0, 0, 0, 0, 0))

    def mem(self, kind: str, addr: int, data: int = 0) -> int:
        code = _MEM_KIND_CODE[kind]
        return self._push((
            T_MEM, 1, code, int(_check_static(addr)), int(_check_static(data)),
            0, 0, 0, 0,
        ))

    def mem_delta(self, kind: str, addr: int, delta: int) -> int:
        """A store whose data is ``R + delta`` (latched at fetch time)."""
        code = _MEM_KIND_CODE[kind]
        return self._push((T_MEM, 1, code, int(addr), int(delta), 1, 0, 0, 0))

    def poll(
        self,
        kind: str,
        addr: int,
        until: int,
        hit_cycles: int,
        miss_cycles: int,
        hit_instr: int = 1,
        miss_instr: int = 2,
    ) -> int:
        code = _MEM_KIND_CODE[kind]
        return self._push((
            T_POLL, 1, code, int(_check_static(addr)),
            int(_check_static(until)), int(_check_static(hit_cycles)),
            int(_check_static(miss_cycles)), int(_check_static(hit_instr)),
            int(_check_static(miss_instr)),
        ))

    def scu(self, kind: str, addr: Any, data: int = 0) -> int:
        _check_static(addr)
        data = int(_check_static(data))
        key = (kind, addr, data)
        pool_idx = self._scu_index.get(key)
        if pool_idx is None:
            pool_idx = len(self._scu_pool)
            self._scu_pool.append(Scu(kind, addr, data))
            self._scu_index[key] = pool_idx
        return self._push((T_SCU, 1, pool_idx, 0, 0, 0, 0, 0, 0))

    def jmp(self, target: int = -1) -> int:
        return self._push((T_JMP, 1, target, 0, 0, 0, 0, 0, 0))

    def br_eq(self, imm: int, target: int = -1) -> int:
        return self._push((T_BR, 1, int(_check_static(imm)), target, 0, 0, 0, 0, 0))

    def set_target(self, row_idx: int, target: int) -> None:
        row = self._rows[row_idx]
        if row[0] == T_JMP:
            self._rows[row_idx] = (T_JMP, 1, target) + row[3:]
        elif row[0] == T_BR:
            self._rows[row_idx] = (T_BR, 1, row[2], target) + row[4:]
        else:  # pragma: no cover - programming error
            raise TypeError(f"row {row_idx} is not a branch")

    def emit_op(self, op: Any) -> None:
        """Record one engine micro-op object (the sentinel tracer's hook)."""
        t = type(op)
        if t is Compute:
            self.compute(op.cycles)
        elif t is Mem:
            self.mem(op.kind, op.addr, op.data)
        elif t is Poll:
            self.poll(
                op.kind, op.addr, op.until, op.hit_cycles, op.miss_cycles,
                op.hit_instr, op.miss_instr,
            )
        elif t is Scu:
            self.scu(op.kind, op.addr, op.data)
        else:
            raise Untraceable(f"not a static micro-op: {op!r}")

    # --------------------------------------------------------------- build
    @staticmethod
    def _target_of(row: Tuple[int, ...]) -> Optional[int]:
        if row[0] == T_JMP:
            return row[2]
        if row[0] == T_BR:
            return row[3]
        return None

    @staticmethod
    def _retarget(row: Tuple[int, ...], target: int) -> Tuple[int, ...]:
        if row[0] == T_JMP:
            return (T_JMP, row[1], target) + row[3:]
        return (T_BR, row[1], row[2], target) + row[4:]

    def _segments(self) -> List[Tuple[int, int]]:
        bounds = sorted({0, len(self._rows), *self._marks})
        return [
            (bounds[i], bounds[i + 1])
            for i in range(len(bounds) - 1)
            if bounds[i] < bounds[i + 1]
        ]

    def build(
        self,
        *,
        fallback: Optional[Callable[..., Any]] = None,
        label: str = "",
        roll: bool = True,
    ) -> "TraceProgram":
        segments = self._segments()
        # Canonical per-segment keys: rows with branch targets rebased to
        # segment-relative offsets, so identical iterations compare equal
        # wherever they land.  Cross-segment targets are a builder error --
        # re-rolling could not preserve them.
        keys: List[Tuple[Tuple[int, ...], ...]] = []
        for start, end in segments:
            seg = []
            for idx in range(start, end):
                row = self._rows[idx]
                tgt = self._target_of(row)
                if tgt is not None:
                    if tgt < 0:
                        raise ValueError(f"unpatched branch target at row {idx}")
                    # ``tgt == end`` is the fall-through target ("skip to the
                    # next iteration"): after re-rolling it lands on the next
                    # segment, the LOOP row, or the final HALT -- all of which
                    # continue the program exactly like falling off the end.
                    if not (start <= tgt <= end):
                        raise ValueError(
                            f"branch at row {idx} targets row {tgt} outside "
                            f"its iteration segment [{start}, {end}]"
                        )
                    row = self._retarget(row, tgt - start)
                seg.append(row)
            keys.append(tuple(seg))

        out: List[Tuple[int, ...]] = []

        def emit_segment(seg: Tuple[Tuple[int, ...], ...]) -> int:
            base = len(out)
            for row in seg:
                tgt = self._target_of(row)
                if tgt is not None:
                    row = self._retarget(row, tgt + base)
                out.append(row)
            return base

        i = 0
        n_seg = len(keys)
        while i < n_seg:
            rolled = False
            if roll:
                for period in (1, 2, 3, 4):
                    if i + 2 * period > n_seg:
                        break
                    group = keys[i:i + period]
                    reps = 0
                    j = i + period
                    while j + period <= n_seg and keys[j:j + period] == group:
                        reps += 1
                        j += period
                    if reps >= 1:
                        base = len(out)
                        for seg in group:
                            emit_segment(seg)
                        out.append((T_LOOP, 1, base, reps, 0, 0, 0, 0, 0))
                        i += period * (reps + 1)
                        rolled = True
                        break
            if not rolled:
                emit_segment(keys[i])
                i += 1
        out.append((T_HALT, 1, 0, 0, 0, 0, 0, 0, 0))
        return TraceProgram(
            rows=tuple(out),
            scu_pool=tuple(self._scu_pool),
            fallback=fallback,
            label=label,
        )


# --------------------------------------------------------------------------
# The program object and its cursor interpreter
# --------------------------------------------------------------------------


class TraceProgram:
    """A compiled per-core micro-op table (or a declared generator fallback).

    Duck-types as a ``Program``: calling it with ``(cluster, cid)`` yields a
    :class:`_TraceCursor`, which the engine drives exactly like a generator.
    Single-use, mirroring :class:`~repro.core.scu.faults.FaultPlan`: the
    second call raises -- :meth:`clone` (or re-lowering) produces a fresh
    usable instance for retries.
    """

    __slots__ = ("rows", "scu_pool", "fallback", "label", "_consumed", "_ops")

    def __init__(
        self,
        rows: Optional[Tuple[Tuple[int, ...], ...]] = None,
        scu_pool: Tuple[Scu, ...] = (),
        fallback: Optional[Callable[..., Any]] = None,
        label: str = "",
    ):
        if rows is None and fallback is None:
            raise ValueError("TraceProgram needs a row table or a fallback")
        self.rows = rows
        self.scu_pool = scu_pool
        self.fallback = fallback
        self.label = label
        self._consumed = False
        self._ops: Optional[List[Optional[Any]]] = None

    @property
    def is_traced(self) -> bool:
        """True when a static table exists (False: generator fallback)."""
        return self.rows is not None

    @property
    def consumed(self) -> bool:
        return self._consumed

    def clone(self) -> "TraceProgram":
        """A fresh, un-consumed program sharing the immutable tables."""
        return TraceProgram(
            rows=self.rows, scu_pool=self.scu_pool,
            fallback=self.fallback, label=self.label,
        )

    def addresses(self) -> Set[int]:
        """Union of the static TCDM addresses the table touches."""
        addrs: Set[int] = set()
        if self.rows:
            for row in self.rows:
                if row[0] in (T_MEM, T_POLL):
                    addrs.add(row[3])
        return addrs

    def n_data_rows(self) -> int:
        return sum(1 for r in self.rows or () if r[0] in _DATA_OPS)

    def __call__(self, cluster, cid: int):
        if self._consumed:
            raise RuntimeError(
                f"TraceProgram {self.label or cid!r} already consumed: trace "
                "cursors are single-use (like FaultPlan) -- re-lower the "
                "program or clone() a fresh instance for a retried run"
            )
        self._consumed = True
        if self.rows is None:
            return self.fallback(cluster, cid)
        return _TraceCursor(self, cluster, cid)

    def _op_cache(self) -> List[Optional[Any]]:
        """Per-row immutable micro-op objects (delta stores stay None --
        their data depends on R and is built fresh at fetch time)."""
        if self._ops is None:
            ops: List[Optional[Any]] = []
            for row in self.rows:
                kind = row[0]
                if kind == T_COMPUTE:
                    ops.append(Compute(row[2]))
                elif kind == T_MEM:
                    if row[5]:
                        ops.append(None)  # R + delta store
                    else:
                        ops.append(Mem(_MEM_KIND_NAME[row[2]], row[3], row[4]))
                elif kind == T_POLL:
                    ops.append(Poll(
                        _MEM_KIND_NAME[row[2]], row[3], row[4], row[5],
                        row[6], row[7], row[8],
                    ))
                elif kind == T_SCU:
                    ops.append(self.scu_pool[row[2]])
                else:
                    ops.append(None)
            self._ops = ops
        return self._ops


class _TraceCursor:
    """Generator-protocol interpreter over a :class:`TraceProgram` table.

    The engine's ``_advance`` drives it via ``__next__``/``send`` and sees
    only ``Compute``/``Mem``/``Poll``/``Scu`` objects -- control rows are
    resolved internally at zero cycles and zero instructions, so a traced
    program is bit-indistinguishable from the generator it was lowered
    from.  ``R`` mirrors the engine's ``resume_value``; ``crossed`` flags
    backward control transfers for the period-collapse monitor.
    """

    _is_trace_cursor = True

    __slots__ = ("prog", "cid", "pc", "R", "ctrs", "crossed", "_rep", "_ops")

    def __init__(self, prog: TraceProgram, cluster, cid: int):
        self.prog = prog
        self.cid = cid
        self.pc = 0
        self.R: Any = 0
        # armed LOOP rows: row index -> remaining back-jumps
        self.ctrs: Dict[int, int] = {}
        self.crossed = False
        self._rep = 0
        self._ops = prog._op_cache()

    def __iter__(self):
        return self

    def __next__(self):
        return self._fetch()

    def send(self, value):
        self.R = value
        return self._fetch()

    def _fetch(self):
        rows = self.prog.rows
        n = len(rows)
        pc = self.pc
        guard = 0
        while True:
            if pc >= n:
                self.pc = pc
                raise StopIteration
            row = rows[pc]
            kind = row[0]
            if kind <= T_SCU:  # data op
                rep = self._rep if self._rep else row[1]
                rep -= 1
                if rep == 0:
                    self.pc = pc + 1
                    self._rep = 0
                else:
                    self.pc = pc
                    self._rep = rep
                op = self._ops[pc]
                if op is None:  # R + delta store, latched now (fetch time)
                    row_t = rows[pc]
                    op = Mem(_MEM_KIND_NAME[row_t[2]], row_t[3], self.R + row_t[4])
                return op
            if kind == T_JMP:
                tgt = row[2]
                if tgt <= pc:
                    self.crossed = True
                pc = tgt
            elif kind == T_BR:
                if self.R == row[2]:
                    tgt = row[3]
                    if tgt <= pc:
                        self.crossed = True
                    pc = tgt
                else:
                    pc += 1
            elif kind == T_LOOP:
                rem = self.ctrs.get(pc)
                if rem is None:
                    rem = row[3]
                if rem > 0:
                    self.ctrs[pc] = rem - 1
                    self.crossed = True
                    pc = row[2]
                else:
                    self.ctrs.pop(pc, None)
                    pc += 1
            else:  # T_HALT
                self.pc = n
                raise StopIteration
            guard += 1
            if guard > _CONTROL_GUARD:  # pragma: no cover - malformed table
                raise RuntimeError(
                    f"trace {self.prog.label!r}: control flow cycled "
                    f"{_CONTROL_GUARD} rows without reaching a micro-op"
                )


# --------------------------------------------------------------------------
# Lowering helpers: sentinel-trace generators into tables
# --------------------------------------------------------------------------


def trace_generator(tb: TraceBuilder, gen, max_ops: int = 200_000) -> int:
    """Drain ``gen`` into ``tb``, feeding a poisoned sentinel as every
    resume value.  Completing without observing a value *proves* the op
    stream is value-independent, so the linear recording is exact for any
    engine schedule.  Raises :class:`Untraceable` otherwise."""
    n = 0
    try:
        op = next(gen)
    except StopIteration:
        return 0
    except _ValueUsed:
        raise Untraceable("program observed a resume value") from None
    while True:
        n += 1
        if n > max_ops:
            gen.close()
            raise Untraceable(
                f"program exceeded {max_ops} recorded micro-ops (unbounded "
                "or data-dependent loop)"
            )
        tb.emit_op(op)
        try:
            op = gen.send(_SENTINEL)
        except StopIteration:
            return n
        except _ValueUsed:
            raise Untraceable("program observed a resume value") from None


def trace_fragments(
    tb: TraceBuilder,
    fragments: Iterable[Callable[[], Any]],
    max_ops: int = 200_000,
) -> int:
    """Sentinel-trace a sequence of per-iteration generator factories,
    marking each boundary so :meth:`TraceBuilder.build` can re-roll the
    repeated iterations into ``LOOP`` rows."""
    total = 0
    for make in fragments:
        tb.mark()
        total += trace_generator(tb, make(), max_ops=max_ops)
        if total > max_ops:
            raise Untraceable(f"program exceeded {max_ops} recorded micro-ops")
    return total


def lower_or_fallback(
    program: Callable[..., Any],
    cluster,
    cid: int,
    *,
    fragments: Optional[Callable[[], Iterable[Callable[[], Any]]]] = None,
    emit: Optional[Callable[[TraceBuilder], None]] = None,
    label: str = "",
) -> TraceProgram:
    """Compile one core's program into a :class:`TraceProgram`.

    Strategy order: an explicit ``emit`` hook (policy-provided BR-based
    emitter for value-dependent fragments), then ``fragments`` (marked
    per-iteration sentinel tracing), then whole-program sentinel tracing of
    ``program(cluster, cid)``.  An :class:`Untraceable` program becomes a
    declared generator fallback carrying ``program`` -- the escape hatch,
    still a valid ``TraceProgram`` for every dispatch layer."""
    tb = TraceBuilder()
    try:
        if emit is not None:
            emit(tb)
        elif fragments is not None:
            trace_fragments(tb, fragments())
        else:
            trace_generator(tb, program(cluster, cid))
    except Untraceable:
        return TraceProgram(fallback=program, label=label or f"fallback:{cid}")
    return tb.build(label=label or f"trace:{cid}")


# --------------------------------------------------------------------------
# The compiled fast path: whole-cluster period collapse over trace state
# --------------------------------------------------------------------------


def _pending_key(op) -> Optional[Tuple[Any, ...]]:
    if op is None:
        return None
    t = type(op)
    if t is Mem:
        return ("m", op.kind, op.addr, op.data)
    if t is Poll:
        return (
            "p", op.kind, op.addr, op.until, op.hit_cycles, op.miss_cycles,
            op.hit_instr, op.miss_instr,
        )
    if t is Scu:
        return ("s", op.kind, op.addr, op.data)
    return ("c", op.cycles)


class TraceRunMonitor:
    """Collapse repeated whole-cluster periods of a fully-traced run.

    Activated by :meth:`Cluster.load` when every core runs a pure (table,
    no-fallback) :class:`_TraceCursor`, no fault plan is attached and no
    watchdog is armed.  At the top of the fast-forward scheduler loop,
    whenever some cursor crossed a loop head, the monitor digests the
    complete cluster state -- per-core scheduler fields, cursor positions
    and armed loop-counter keys (values excluded: they are the induction
    variables), the TCDM words at every statically-addressed location, all
    round-robin pointers and the SCU's :meth:`state_key`.  A recurring
    digest proves the cluster is periodic; every mechanism between the two
    digests (full steps, quiescent jumps, spin resolution) is deterministic
    given that state, so the remaining iterations collapse into one multiply
    of the per-period cycle/counter deltas, bounded so at least one full
    period of real execution remains before every loop counter expires and
    before ``max_cycles``.
    """

    __slots__ = ("cl", "cursors", "addrs", "seen")

    # runaway guard: aperiodic digests stop accumulating past this
    _SEEN_LIMIT = 4096

    def __init__(self, cluster, cursors: Sequence[_TraceCursor]):
        self.cl = cluster
        self.cursors = list(cursors)
        addrs: Set[int] = set()
        for cur in self.cursors:
            addrs |= cur.prog.addresses()
        self.addrs = sorted(addrs)
        self.seen: Dict[Any, Any] = {}

    def poll(self) -> None:
        crossed = False
        for cur in self.cursors:
            if cur.crossed:
                crossed = True
                cur.crossed = False
        if not crossed:
            return
        key = self._digest()
        prev = self.seen.get(key)
        snap = self._snapshot()
        if prev is None:
            if len(self.seen) >= self._SEEN_LIMIT:
                self.seen.clear()
            self.seen[key] = snap
        elif not self._jump(prev, snap):
            self.seen[key] = snap  # measure the next period from here

    # ------------------------------------------------------------ internals
    def _digest(self) -> Tuple[Any, ...]:
        cl = self.cl
        lanes = []
        for core, cur in zip(cl.cores, self.cursors):
            lanes.append((
                core.state.value, core.busy, core.wake_countdown,
                core.sleep_entry, core.elw_issued, core.resume_value,
                cur.pc, cur._rep, frozenset(cur.ctrs),
                _pending_key(core.pending),
            ))
        tcdm = cl.tcdm
        mem = tuple(tcdm.get(a, 0) for a in self.addrs)
        scu = cl.scu
        return (
            tuple(lanes), mem, cl._rr.tobytes(),
            scu.state_key() if scu is not None else None,
        )

    def _snapshot(self):
        cl = self.cl
        if cl._vec is not None:
            counters = cl._vec.counter_block.copy()
        else:
            counters = np.array(
                [[getattr(c, name) for c in cl.cores] for name in _COUNTERS],
                dtype=np.int64,
            )
        return (
            cl.cycle, counters, cl.stats.bank_conflicts, cl.stats.scu_events,
            [dict(cur.ctrs) for cur in self.cursors],
        )

    def _jump(self, prev, snap) -> bool:
        cl = self.cl
        cyc0, ctr0, bc0, ev0, loops0 = prev
        cyc1, ctr1, bc1, ev1, loops1 = snap
        period = cyc1 - cyc0
        if period <= 0:
            return False
        k: Optional[int] = None
        deltas: List[List[Tuple[int, int, int]]] = []
        for l0, l1 in zip(loops0, loops1):
            lane: List[Tuple[int, int, int]] = []
            for row, rem in l1.items():
                d = l0.get(row, rem) - rem
                if d <= 0:
                    continue  # inner loop, re-armed within the period
                kk = (rem - d) // d
                if kk <= 0:
                    return False
                k = kk if k is None else min(k, kk)
                lane.append((row, rem, d))
            deltas.append(lane)
        cap = (cl.max_cycles - cl.cycle) // period - 2
        k = cap if k is None else min(k, cap)
        if k <= 0:
            return False
        dC = ctr1 - ctr0
        if cl._vec is not None:
            cl._vec.counter_block += k * dC
        else:
            for i, name in enumerate(_COUNTERS):
                for j, core in enumerate(cl.cores):
                    setattr(core, name, getattr(core, name) + k * int(dC[i, j]))
        cl.stats.bank_conflicts += k * (bc1 - bc0)
        cl.stats.scu_events += k * (ev1 - ev0)
        cl.cycle += k * period
        for cur, lane in zip(self.cursors, deltas):
            for row, rem, d in lane:
                cur.ctrs[row] = rem - k * d
        cl.trace_jumps += 1
        cl.trace_jump_cycles += k * period
        self.seen.clear()
        return True


# --------------------------------------------------------------------------
# Batched array executor for pure-TCDM traces (numpy, and jax.jit via compat)
# --------------------------------------------------------------------------

_X_ACTIVE, _X_STALL, _X_DONE = 0, 1, 2


def _pack_tables(programs: Sequence[TraceProgram]):
    """Flatten trace tables into padded per-lane numpy arrays."""
    for p in programs:
        if not p.is_traced:
            raise ValueError("array executor needs pure traced programs")
        for row in p.rows:
            if row[0] == T_SCU:
                raise ValueError(
                    "array executor supports pure-TCDM traces only "
                    "(SCU rows need the full engine)"
                )
    n = len(programs)
    length = max(len(p.rows) for p in programs)
    addrs = sorted(set().union(*(p.addresses() for p in programs)))
    addr_idx = {a: i for i, a in enumerate(addrs)}
    tab = np.zeros((n, length, 9), dtype=np.int64)
    tab[:, :, 0] = T_HALT
    for lane, p in enumerate(programs):
        for r, row in enumerate(p.rows):
            tab[lane, r] = row
            if row[0] in (T_MEM, T_POLL):
                tab[lane, r, 3] = addr_idx[row[3]]
    return tab, np.array(addrs, dtype=np.int64)


def run_traces_xp(
    programs: Sequence[TraceProgram],
    *,
    n_banks: int,
    tas_cycles: int = 3,
    max_cycles: int = 10_000_000,
    xp=np,
):
    """Execute pure-TCDM traces as one batched array computation.

    A from-scratch implementation of the engine's TCDM semantics (issue,
    per-bank round-robin arbitration, Poll retry shadows, phase-5
    accounting) where every phase is an array kernel over all lanes -- no
    per-micro-op Python in the loop.  ``xp`` selects the array namespace:
    ``numpy`` (default; the no-jax CI path) or ``jax.numpy`` inside
    :func:`run_traces_jax`.  Returns a dict with ``cycles``, the nine
    counter rows, ``bank_conflicts``, ``finished_at`` and the final tcdm
    contents; parity vs the generator engine is enforced by
    ``tests/test_trace.py``.

    Consumes the programs (single-use), mirroring the cursor path.
    """
    for p in programs:
        if p._consumed:
            raise RuntimeError("TraceProgram already consumed (single-use)")
        p._consumed = True
    tab_np, addrs_np = _pack_tables(programs)
    n, length, _ = tab_np.shape
    is_np = xp is np

    tab = xp.asarray(tab_np)
    op_k = tab[:, :, 0]
    rep_n = tab[:, :, 1]
    a0, a1, a2 = tab[:, :, 2], tab[:, :, 3], tab[:, :, 4]
    a3, a4, a5, a6 = tab[:, :, 5], tab[:, :, 6], tab[:, :, 7], tab[:, :, 8]
    addr_bank = xp.asarray((addrs_np >> 2) % n_banks)
    lanes = xp.arange(n)

    state = {
        "pc": xp.zeros(n, dtype=xp.int64),
        "rep": xp.zeros(n, dtype=xp.int64),
        "R": xp.zeros(n, dtype=xp.int64),
        "st": xp.zeros(n, dtype=xp.int64),
        "busy": xp.zeros(n, dtype=xp.int64),
        "pend": xp.full((n,), -1, dtype=xp.int64),  # row idx of pending op
        "pdata": xp.zeros(n, dtype=xp.int64),  # latched store data
        "tcdm": xp.zeros(len(addrs_np), dtype=xp.int64),
        "rr": xp.zeros(n_banks, dtype=xp.int64),
        "cnt": xp.zeros((len(_COUNTERS), n), dtype=xp.int64),
        "conflicts": xp.zeros((), dtype=xp.int64),
        "fin": xp.full((n,), -1, dtype=xp.int64),
        "cycle": xp.zeros((), dtype=xp.int64),
    }

    def _set(arr, idx, val, mask):
        if is_np:
            out = arr.copy()
            out[idx] = np.where(mask, val, out[idx])
            return out
        sel = xp.where(mask, val, arr[idx])
        return arr.at[idx].set(sel)

    def _add(arr, idx, val, mask):
        # per-lane counter bump: arr[idx[lane], lane] += val[lane] where mask
        if is_np:
            out = arr.copy()
            v = val if np.isscalar(val) else val[mask]
            np.add.at(out, (idx[mask], np.asarray(lanes)[mask]), v)
            return out
        return arr.at[idx, lanes].add(xp.where(mask, val, 0))

    def decode_step(s):
        """Resolve one control row for every lane that needs a fetch."""
        pc, rep, R, st = s["pc"], s["rep"], s["R"], s["st"]
        row_k = xp.take_along_axis(op_k, pc[:, None], axis=1)[:, 0]
        fetching = s["fetch"] & (st == _X_ACTIVE)
        is_ctrl = fetching & (row_k >= T_JMP)
        r0 = xp.take_along_axis(a0, pc[:, None], axis=1)[:, 0]
        r1 = xp.take_along_axis(a1, pc[:, None], axis=1)[:, 0]
        # JMP
        jmp = is_ctrl & (row_k == T_JMP)
        new_pc = xp.where(jmp, r0, pc)
        # BR: taken when R == imm
        br = is_ctrl & (row_k == T_BR)
        new_pc = xp.where(br, xp.where(R == r0, r1, pc + 1), new_pc)
        # LOOP: per-(lane, row) counters; -1 = not armed yet
        lp = is_ctrl & (row_k == T_LOOP)
        ctr = s["ctr"]
        cur = xp.take_along_axis(ctr, pc[:, None], axis=1)[:, 0]
        cur = xp.where(cur < 0, r1, cur)
        take = lp & (cur > 0)
        new_pc = xp.where(lp, xp.where(cur > 0, r0, pc + 1), new_pc)
        new_ctr_val = xp.where(take, cur - 1, -1)
        if is_np:
            ctr = ctr.copy()
            ctr[lanes[lp], pc[lp]] = new_ctr_val[lp]
        else:
            ctr = ctr.at[lanes, pc].set(
                xp.where(lp, new_ctr_val, ctr[lanes, pc])
            )
        # HALT
        halt = is_ctrl & (row_k == T_HALT)
        st = xp.where(halt, _X_DONE, st)
        fin = xp.where(halt & (s["fin"] < 0), s["cycle"], s["fin"])
        s = dict(s)
        s.update(pc=new_pc, st=st, fin=fin, ctr=ctr)
        s["fetch"] = fetching & is_ctrl & ~halt
        return s

    def issue_data(s):
        """Lanes whose pc sits on a data row: issue it (instr, busy/stall)."""
        pc, rep = s["pc"], s["rep"]
        fetch = s["fetch"]
        row_k = xp.take_along_axis(op_k, pc[:, None], axis=1)[:, 0]
        data = fetch & (row_k <= T_SCU)
        rn = xp.take_along_axis(rep_n, pc[:, None], axis=1)[:, 0]
        r = xp.where(rep > 0, rep, rn) - 1
        new_pc = xp.where(data & (r == 0), pc + 1, pc)
        new_rep = xp.where(data, r, rep)
        cnt = s["cnt"]
        cnt = _add(cnt, 5 * xp.ones(n, dtype=xp.int64), 1, data)  # instructions
        # COMPUTE: busy = max(0, c - 1), stay ACTIVE
        c0 = xp.take_along_axis(a0, pc[:, None], axis=1)[:, 0]
        comp = data & (row_k == T_COMPUTE)
        busy = xp.where(comp, xp.maximum(c0 - 1, 0), s["busy"])
        # MEM / POLL: pend at the issuing row, STALL; delta stores latch now
        memp = data & ((row_k == T_MEM) | (row_k == T_POLL))
        st = xp.where(memp, _X_STALL, s["st"])
        pend = xp.where(memp, pc, s["pend"])
        d_imm = xp.take_along_axis(a2, pc[:, None], axis=1)[:, 0]
        d_flag = xp.take_along_axis(a3, pc[:, None], axis=1)[:, 0]
        pdata = xp.where(
            data & (row_k == T_MEM),
            xp.where(d_flag == 1, s["R"] + d_imm, d_imm),
            s["pdata"],
        )
        s = dict(s)
        s.update(pc=new_pc, rep=new_rep, busy=busy, st=st, pend=pend,
                 pdata=pdata, cnt=cnt)
        s["fetch"] = s["fetch"] & ~data
        return s

    def grant(s):
        """Per-bank round-robin arbitration + transaction effects."""
        st, pend = s["st"], s["pend"]
        req = st == _X_STALL
        p_row = xp.where(req, pend, 0)
        r_kind = op_k[lanes, p_row]  # T_MEM / T_POLL
        m_kind = a0[lanes, p_row]
        aidx = a1[lanes, p_row]
        bank = addr_bank[aidx]
        key = (lanes - s["rr"][bank]) % n
        big = n + 1
        kmat = xp.where(
            req[None, :] & (bank[None, :] == xp.arange(n_banks)[:, None]),
            key[None, :], big,
        )
        wlane = xp.argmin(kmat, axis=1)
        has = kmat[xp.arange(n_banks), wlane] < big
        win = xp.zeros(n, dtype=bool)
        if is_np:
            win = win.copy()
            win[wlane[has]] = True
        else:
            # scatter-add, not set: banks with no requester still argmin to
            # lane 0 with has=False, and a duplicate-index set could let
            # that clobber lane 0's real grant
            win = xp.zeros(n, dtype=xp.int32).at[wlane].add(
                has.astype(xp.int32)
            ) > 0
        conflicts = s["conflicts"] + req.sum() - has.sum()
        rr = _set(s["rr"], xp.arange(n_banks), (wlane + 1) % n, has)
        # effects
        cnt = s["cnt"]
        cnt = _add(cnt, 6 * xp.ones(n, dtype=xp.int64), 1, win)  # tcdm
        val = s["tcdm"][aidx]
        is_poll = win & (r_kind == T_POLL)
        is_tas = win & (m_kind == _MK_TAS)
        cnt = _add(cnt, 7 * xp.ones(n, dtype=xp.int64), 1, is_tas)  # tas
        # tas (Mem or Poll) writes -1 and pays the 3-cycle latency
        tcdm = _set(s["tcdm"], aidx, -1, is_tas)
        base = xp.where(is_tas, tas_cycles - 1, 0)
        # Poll: hit vs miss
        until = a2[lanes, p_row]
        hit_c, miss_c = a3[lanes, p_row], a4[lanes, p_row]
        hit_i, miss_i = a5[lanes, p_row], a6[lanes, p_row]
        hit = is_poll & (val == until)
        miss = is_poll & (val != until)
        busy = s["busy"]
        busy = xp.where(hit, base + hit_c, busy)
        busy = xp.where(miss, base + miss_c, busy)
        cnt = _add(cnt, 5 * xp.ones(n, dtype=xp.int64),
                   xp.where(hit, hit_i, miss_i), is_poll)
        R = xp.where(hit, val, s["R"])
        # plain Mem
        is_lw = win & (r_kind == T_MEM) & (m_kind == _MK_LW)
        is_sw = win & (r_kind == T_MEM) & (m_kind == _MK_SW)
        is_mtas = win & (r_kind == T_MEM) & (m_kind == _MK_TAS)
        R = xp.where(is_lw | is_mtas, val, R)
        R = xp.where(is_sw, 0, R)
        tcdm = _set(tcdm, aidx, s["pdata"], is_sw)
        busy = xp.where(is_mtas, tas_cycles - 1, busy)
        busy = xp.where(is_lw | is_sw, busy, busy)
        # resolution: winners go ACTIVE; polls stay armed on a miss
        done_req = win & ~miss
        pend = xp.where(done_req, -1, pend)
        new_st = xp.where(win, _X_ACTIVE, st)
        s = dict(s)
        s.update(st=new_st, pend=pend, busy=busy, R=R, tcdm=tcdm, rr=rr,
                 cnt=cnt, conflicts=conflicts)
        return s

    def account(s):
        st = s["st"]
        clocked = st != _X_DONE
        act = st == _X_ACTIVE
        stall = st == _X_STALL
        cnt = s["cnt"]
        inc = xp.stack([
            clocked.astype(xp.int64),  # active
            act.astype(xp.int64),  # comp
            stall.astype(xp.int64),  # wait
            xp.zeros(n, dtype=xp.int64),  # gated
            stall.astype(xp.int64),  # stall
        ])
        if is_np:
            cnt = cnt.copy()
            cnt[:5] += inc
        else:
            cnt = cnt.at[:5].add(inc)
        s = dict(s)
        s["cnt"] = cnt
        s["cycle"] = s["cycle"] + 1
        return s

    def cycle_step(s):
        # Phase 1: issue.  busy countdown; armed polls re-enter the queue
        # (one instruction, like the engine's re-issue); everyone else
        # fetches through the table until a data op lands.
        st, busy, pend = s["st"], s["busy"], s["pend"]
        act = st == _X_ACTIVE
        counting = act & (busy > 0)
        advancing = act & (busy <= 0)
        s = dict(s)
        s["busy"] = xp.where(counting, busy - 1, busy)
        reissue = advancing & (pend >= 0)
        s["st"] = xp.where(reissue, _X_STALL, st)
        s["cnt"] = _add(s["cnt"], 5 * xp.ones(n, dtype=xp.int64), 1, reissue)
        s["fetch"] = advancing & (pend < 0)
        # decode until every fetching lane reached a data op or halted
        if is_np:
            while bool(np.any(s["fetch"])):
                s = issue_data(s)
                if not bool(np.any(s["fetch"])):
                    break
                s = decode_step(s)
        else:
            import jax

            def body(ss):
                ss = issue_data(ss)
                return decode_step(ss)

            s = jax.lax.while_loop(
                lambda ss: ss["fetch"].any(), body, s,
            )
            s = issue_data(s)
        s.pop("fetch", None)
        # Phase 2: arbitration + grants.  Phase 5: accounting.
        s = grant(s)
        s = account(s)
        return s

    state["ctr"] = xp.full((n, length), -1, dtype=xp.int64)

    if is_np:
        while True:
            if bool(np.all(state["st"] == _X_DONE)):
                break
            if int(state["cycle"]) >= max_cycles:
                raise RuntimeError(
                    f"traced run did not finish within {max_cycles} cycles"
                )
            state["fetch"] = np.zeros(n, dtype=bool)
            state = cycle_step(state)
    else:
        import jax

        def cond(s):
            return (~(s["st"] == _X_DONE).all()) & (s["cycle"] < max_cycles)

        def body(s):
            s = dict(s)
            s["fetch"] = xp.zeros(n, dtype=bool)
            return cycle_step(s)

        state = jax.lax.while_loop(cond, body, state)

    counters = {
        name: np.asarray(state["cnt"][i])
        for i, name in enumerate(_COUNTERS)
    }
    return {
        "cycles": int(state["cycle"]),
        "counters": counters,
        "bank_conflicts": int(state["conflicts"]),
        "finished_at": np.asarray(state["fin"]),
        "tcdm": dict(zip(addrs_np.tolist(), np.asarray(state["tcdm"]).tolist())),
    }


def run_traces_jax(
    programs: Sequence[TraceProgram],
    *,
    n_banks: int,
    tas_cycles: int = 3,
    max_cycles: int = 10_000_000,
):
    """The same batched executor as one ``jax.jit`` program (XLA while
    loop).  Requires jax; gate callers on :data:`repro.compat.HAS_JAX`."""
    from repro.compat import HAS_JAX

    if not HAS_JAX:
        raise RuntimeError(
            "jax is unavailable (REPRO_NO_JAX or import failure); "
            "use run_traces_xp with numpy"
        )
    import jax.numpy as jnp

    return run_traces_xp(
        programs, n_banks=n_banks, tas_cycles=tas_cycles,
        max_cycles=max_cycles, xp=jnp,
    )
