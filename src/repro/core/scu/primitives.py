"""The three synchronization-primitive implementations compared by the paper.

* ``SW``  -- pure software: spin-locks on TAS-protected L1 variables
             (Sec. 6.1, "purely spin-lock based").
* ``TAS`` -- software + idle-waiting: failed contenders sleep on an SCU
             notifier event; the releasing core broadcasts a notifier
             (Sec. 6.1, second baseline).
* ``SCU`` -- the paper's contribution: single-``elw`` hardware barrier /
             mutex (Sec. 5).

Each primitive is a generator *fragment* whose instruction footprint follows
the paper's description (Sec. 6.3): SW lock attempt = 2 instructions, TAS
retry = 5 instructions incl. idle-wait handling, SCU = 1 instruction (plus
address setup); leaving a critical section = 1 instruction (SW/SCU) vs 2
(TAS).  On top of the raw instruction counts, :class:`CostModel` charges the
micro-architectural overheads of the RI5CY-class in-order cores the paper
uses (taken-branch penalty, load-use stall, call/return + local-sense
bookkeeping) -- its defaults are calibrated against Table 1 (see
``benchmarks/table1_primitives.py`` for the validation).

TCDM layout: synchronization variables live in distinct words (and hence,
with word interleaving, distinct banks) to avoid artificial bank conflicts --
matching how a real runtime lays them out.
"""

from __future__ import annotations

import dataclasses
from typing import Generator

from .engine import Compute, Mem, Poll, Scu

__all__ = [
    "CostModel",
    "BarrierState",
    "sw_barrier",
    "tas_barrier",
    "scu_barrier",
    "sw_mutex_section",
    "tas_mutex_section",
    "scu_mutex_section",
    "VARIANTS",
]

# --- shared-variable addresses (word-aligned; word-interleaved banks) -------
A_BAR_LOCK = 0x100
A_BAR_COUNT = 0x104
A_BAR_SENSE = 0x108
A_MUTEX = 0x10C

_TAS_FREE = 0  # TAS returns the stored value and writes -1; 0 == free


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Micro-architectural cycle charges for the software primitives.

    Calibrated so the simulated Table-1 costs match the paper (RI5CY-class
    4-stage in-order pipeline: taken branches flush ~2 extra cycles, loads
    have a 1-cycle load-use shadow, primitives are called functions that
    maintain a local sense / queue state).
    """

    branch_taken: int = 2  # extra cycles for a taken branch
    load_use: int = 1  # load-to-use interlock
    call: int = 3  # call + prologue of the (non-inlined parts of) primitive
    ret: int = 2  # epilogue + return
    sense_setup: int = 5  # local-sense flip: lw/xori/sw + core-id indexing
    mask_setup: int = 2  # event-mask + elw address setup on the TAS path
    crit_extra: int = 8  # runtime bookkeeping inside the barrier lock
    # (team state / barrier-id address computation on the shared state --
    # the core-id-dependent address calculation the SCU removes, Sec. 2).
    # Values fitted against the paper's Table 1 (see benchmarks/
    # table1_primitives.py); barrier rows match within ~4%.


DEFAULT_COSTS = CostModel()


class BarrierState:
    """Per-run software-barrier bookkeeping shared by all cores.

    Holds the *local sense* of every core for the sense-reversal barrier.
    The actual counter/sense/lock words live in simulated TCDM.
    """

    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        self.local_sense = [0] * n_cores


# ---------------------------------------------------------------------------
# Barriers
# ---------------------------------------------------------------------------


def _sw_barrier_body(cl, cid: int, st: BarrierState, cm: CostModel, idle_wait: bool):
    """Sense-reversal barrier on a TAS-protected counter (SW / TAS variants)."""
    n = st.n_cores
    sense = st.local_sense[cid] ^ 1
    st.local_sense[cid] = sense
    yield Compute(cm.call + cm.sense_setup)
    # -- acquire the barrier lock: "2 instructions per locking attempt" ------
    # (declarative TAS spin: bnez falls through on the free value, else the
    # taken branch loops back to the atomic -- see engine.Poll)
    yield Poll(
        "tas", A_BAR_LOCK, until=_TAS_FREE,
        hit_cycles=1, miss_cycles=1 + cm.branch_taken,
        hit_instr=1, miss_instr=1,
    )
    # -- critical: bump the arrival counter ----------------------------------
    if cm.crit_extra > 0:
        yield Compute(cm.crit_extra)  # team state / barrier-id bookkeeping
    c = yield Mem("lw", A_BAR_COUNT)
    yield Compute(1 + cm.load_use)  # addi after load-use shadow
    if c + 1 == n:
        # last arrival: reset counter, flip shared sense, release the lock
        yield Compute(1)  # beq taken on the count compare
        yield Mem("sw", A_BAR_COUNT, 0)
        yield Mem("sw", A_BAR_SENSE, sense)
        yield Mem("sw", A_BAR_LOCK, 0)
        if idle_wait:
            yield Scu("write", ("notifier", 0, "trigger"), 0)  # broadcast wake
        yield Compute(cm.ret)
    else:
        yield Compute(1)  # bne not taken
        yield Mem("sw", A_BAR_COUNT, c + 1)
        yield Mem("sw", A_BAR_LOCK, 0)
        if idle_wait:
            # idle-wait + re-check loop ("five instructions" per retry)
            while True:
                s = yield Mem("lw", A_BAR_SENSE)
                yield Compute(1 + cm.load_use)
                if s == sense:
                    break
                yield Compute(cm.mask_setup)
                yield Scu("elw", ("notifier", 0, "wait"))
                yield Compute(1 + cm.branch_taken)  # loop back to re-check
        else:
            # -- spin on the sense word (busy waiting) -----------------------
            # (declarative lw spin: load + check each round, bne taken back
            # to the poll on a miss -- see engine.Poll)
            yield Poll(
                "lw", A_BAR_SENSE, until=sense,
                hit_cycles=1 + cm.load_use,
                miss_cycles=1 + cm.load_use + cm.branch_taken,
                hit_instr=1, miss_instr=2,
            )
        yield Compute(cm.ret)


def sw_barrier(cl, cid: int, st: BarrierState, cm: CostModel = DEFAULT_COSTS):
    yield from _sw_barrier_body(cl, cid, st, cm, idle_wait=False)


def tas_barrier(cl, cid: int, st: BarrierState, cm: CostModel = DEFAULT_COSTS):
    yield from _sw_barrier_body(cl, cid, st, cm, idle_wait=True)


def scu_barrier(cl, cid: int, barrier_id: int = 0) -> Generator:
    """Hardware barrier: address setup + a single elw (Sec. 5, Fig. 4)."""
    yield Compute(1)  # elw address calculation (counted by the paper)
    yield Scu("elw", ("barrier", barrier_id, "wait_all"))


# ---------------------------------------------------------------------------
# Critical sections (mutex)
# ---------------------------------------------------------------------------


def sw_mutex_section(
    cl, cid: int, t_crit: int, cm: CostModel = DEFAULT_COSTS
) -> Generator:
    """Spin-lock entry, ``t_crit`` cycles of work, single-store exit."""
    yield Poll(
        "tas", A_MUTEX, until=_TAS_FREE,
        hit_cycles=1, miss_cycles=1 + cm.branch_taken,
        hit_instr=1, miss_instr=1,
    )
    if t_crit > 0:
        yield Compute(t_crit)
    yield Mem("sw", A_MUTEX, 0)


def tas_mutex_section(
    cl, cid: int, t_crit: int, cm: CostModel = DEFAULT_COSTS
) -> Generator:
    """TAS entry with notifier idle-wait; exit = store + notifier (2 instr).

    Failed contenders sleep on a notifier event; on wake-up they *re-test*
    the variable with a plain load first ("quickly wake up and re-test the
    TAS-variable, with all but the elected one immediately going back to
    sleep", Sec. 6.3) -- a test-and-test-and-set that keeps the thundering
    herd off the TAS bank.
    """
    v = yield Mem("tas", A_MUTEX)
    first = True
    while v != _TAS_FREE:
        if first:
            yield Compute(1 + cm.branch_taken)  # bnez taken into the wait path
            first = False
        # "five instructions ... to handle the idle-wait functionality"
        yield Compute(cm.mask_setup)
        yield Scu("elw", ("notifier", 1, "wait"))
        t = yield Mem("lw", A_MUTEX)  # re-test before the atomic
        yield Compute(1 + cm.load_use)
        if t != _TAS_FREE:
            yield Compute(cm.branch_taken)
            continue  # someone else was elected; back to sleep
        v = yield Mem("tas", A_MUTEX)
    yield Compute(1)  # bnez falls through
    if t_crit > 0:
        yield Compute(t_crit)
    yield Mem("sw", A_MUTEX, 0)
    yield Scu("write", ("notifier", 1, "trigger"), 0)  # wake the queued cores


def scu_mutex_section(
    cl, cid: int, t_crit: int, mutex_id: int = 0
) -> Generator:
    """Hardware mutex: elw-lock (elects one core), work, single-write unlock."""
    yield Compute(1)  # address setup
    yield Scu("elw", ("mutex", mutex_id, "lock"))
    if t_crit > 0:
        yield Compute(t_crit)
    yield Scu("write", ("mutex", mutex_id, "unlock"), 0)


# ---------------------------------------------------------------------------
# Trace-IR emitters (repro.core.scu.trace)
#
# The SW/TAS barrier and the TAS mutex are the value-*dependent* primitives:
# their generators branch on loaded values (the arrival count, the TAS
# re-test), so sentinel tracing rejects them.  These twins express the same
# control flow as explicit BR/JMP rows over the trace register R, which
# mirrors the engine's resume_value -- the row streams they produce are
# bit-identical to the generators under every schedule (the lowering parity
# suite in tests/test_trace.py holds them to that at 8/64/256 cores).
# ---------------------------------------------------------------------------


def trace_sw_barrier_body(tb, cid: int, st: BarrierState, cm: CostModel,
                          idle_wait: bool) -> None:
    """One sense-reversal barrier iteration as trace rows (SW/TAS twins).

    Mirrors :func:`_sw_barrier_body` row for row; the last-arrival decision
    becomes ``BR_EQ(n-1)`` on the loaded counter value.  Mutates the shared
    ``local_sense`` exactly like the generator -- the trace *replaces* the
    generator, consuming the same one build of the barrier state.
    """
    n = st.n_cores
    sense = st.local_sense[cid] ^ 1
    st.local_sense[cid] = sense
    tb.compute(cm.call + cm.sense_setup)
    tb.poll(
        "tas", A_BAR_LOCK, _TAS_FREE,
        hit_cycles=1, miss_cycles=1 + cm.branch_taken,
        hit_instr=1, miss_instr=1,
    )
    if cm.crit_extra > 0:
        tb.compute(cm.crit_extra)
    tb.mem("lw", A_BAR_COUNT)  # R = c
    tb.compute(1 + cm.load_use)
    br_last = tb.br_eq(n - 1)  # c + 1 == n -> last arrival
    # -- not the last arrival: publish c+1, release the lock, wait ----------
    tb.compute(1)
    tb.mem_delta("sw", A_BAR_COUNT, 1)  # store R + 1
    tb.mem("sw", A_BAR_LOCK, 0)
    if idle_wait:
        recheck = tb.label()
        tb.mem("lw", A_BAR_SENSE)  # R = s
        tb.compute(1 + cm.load_use)
        br_out = tb.br_eq(sense)
        tb.compute(cm.mask_setup)
        tb.scu("elw", ("notifier", 0, "wait"))
        tb.compute(1 + cm.branch_taken)
        tb.jmp(recheck)
        tb.set_target(br_out, tb.label())
    else:
        tb.poll(
            "lw", A_BAR_SENSE, sense,
            hit_cycles=1 + cm.load_use,
            miss_cycles=1 + cm.load_use + cm.branch_taken,
            hit_instr=1, miss_instr=2,
        )
    tb.compute(cm.ret)
    j_end = tb.jmp()
    # -- last arrival: reset, flip the shared sense, release ----------------
    tb.set_target(br_last, tb.label())
    tb.compute(1)
    tb.mem("sw", A_BAR_COUNT, 0)
    tb.mem("sw", A_BAR_SENSE, sense)
    tb.mem("sw", A_BAR_LOCK, 0)
    if idle_wait:
        tb.scu("write", ("notifier", 0, "trigger"), 0)
    tb.compute(cm.ret)
    tb.set_target(j_end, tb.label())


def trace_tas_mutex_section(tb, cid: int, t_crit: int, cm: CostModel) -> None:
    """One TAS idle-wait critical section as trace rows.

    Mirrors :func:`tas_mutex_section`: the test-and-test-and-set re-test
    loop becomes BR rows on the TAS / re-test load values.
    """
    tb.mem("tas", A_MUTEX)  # R = v
    br_acq0 = tb.br_eq(_TAS_FREE)
    tb.compute(1 + cm.branch_taken)  # first-attempt bnez taken
    wait = tb.label()
    tb.compute(cm.mask_setup)
    tb.scu("elw", ("notifier", 1, "wait"))
    tb.mem("lw", A_MUTEX)  # R = t (re-test before the atomic)
    tb.compute(1 + cm.load_use)
    br_retry = tb.br_eq(_TAS_FREE)
    tb.compute(cm.branch_taken)
    tb.jmp(wait)  # someone else was elected; back to sleep
    tb.set_target(br_retry, tb.label())
    tb.mem("tas", A_MUTEX)  # R = v
    br_acq1 = tb.br_eq(_TAS_FREE)
    tb.jmp(wait)  # lost the race; no first-attempt branch this time
    acquired = tb.label()
    tb.set_target(br_acq0, acquired)
    tb.set_target(br_acq1, acquired)
    tb.compute(1)  # bnez falls through
    if t_crit > 0:
        tb.compute(t_crit)
    tb.mem("sw", A_MUTEX, 0)
    tb.scu("write", ("notifier", 1, "trigger"), 0)


def _deprecated_variants():
    import warnings

    warnings.warn(
        "repro.core.scu.primitives.VARIANTS is deprecated; use "
        "repro.sync.available_policies() (legacy uppercase spellings "
        "resolve via aliases)",
        DeprecationWarning,
        stacklevel=3,
    )
    return ("SCU", "TAS", "SW")


def __getattr__(name: str):
    # Legacy spelling of the paper's triad, kept as a deprecation shim only.
    # The authoritative list of disciplines (including extensions such as
    # the tree and fifo policies) is ``repro.sync.available_policies()``.
    if name == "VARIANTS":
        return _deprecated_variants()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
