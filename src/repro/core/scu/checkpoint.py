"""Deterministic, bit-exact checkpoint/restore for in-flight cluster jobs.

A :class:`MemberCheckpoint` captures the complete semantic state of one
running cluster at a **full-step boundary** (between scheduler rounds --
never mid-step, mirroring the recovery-mechanism constraint every fault
hook already obeys):

* per-core scheduler state and stat counters (state code, countdowns,
  pending micro-op, resume value, the nine counters -- read uniformly
  through the ``_Core``/``_VecCore`` attribute layer, so one capture path
  covers the scalar, vectorized and fleet-attached engines),
* the SCU: base-unit registers, latched elw wait masks and pending set,
  the lost-wake drop filter, every extension instance's comparator state
  (armed sets are re-derived on restore via the ``_*_touched`` hooks) and
  the watchdog's progress clock,
* TCDM contents, per-bank round-robin pointers, the local clock and cap,
  cluster-level stats (bank conflicts, SCU events),
* the :class:`~repro.core.scu.faults.FaultPlan` cursor -- a restored run
  resumes mid-plan and replays the remaining schedule bit-exactly,
* per-core trace-cursor program counters.

Checkpointability rides on the PR-8 trace IR: a core is captureable iff
its program is a compiled :class:`~repro.core.scu.trace.TraceProgram`
cursor (table rows are plain ints; the cursor's mutable state is five
scalars and a loop-counter dict).  Generator-backed programs hold opaque
Python frames and are **explicitly non-checkpointable**:
:func:`capture_cluster` raises :class:`NotCheckpointable` and the caller
falls back to restart -- never a wrong resume.

The crown invariant (enforced by ``tests/test_checkpoint.py`` and the
``scripts/fault_fuzz.py --snapshot`` lane): a restored run produces
bit-identical :class:`~repro.core.scu.engine.ClusterStats` to an
uninterrupted one, across lockstep, fastforward and fleet tiers, into any
slot of any fleet.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, Optional, Tuple

from .engine import (
    _COUNTERS,
    Cluster,
    Compute,
    CoreState,
    FleetConfig,
    Mem,
    Poll,
    Scu,
)
from .faults import FaultPlan, Watchdog
from .scu_unit import SCU
from .trace import TraceProgram, _TraceCursor

__all__ = [
    "CARRY_FAULTS",
    "NotCheckpointable",
    "CoreCheckpoint",
    "ScuCheckpoint",
    "MemberCheckpoint",
    "capture_cluster",
    "resume_config",
    "restore_cluster",
    "apply_cluster_state",
]

# ``faults=`` sentinel: replay the checkpointed plan cursor.  ``None``
# strips the plan (live migration to a healthy domain must not carry the
# sick domain's remaining fault schedule along); a FaultPlan overrides.
CARRY_FAULTS = "carry"


class NotCheckpointable(RuntimeError):
    """The cluster's state cannot be captured exactly (generator-backed
    program, already finished, or a tripped watchdog).  Callers fall back
    to restart-from-zero -- never a wrong resume."""


# ---------------------------------------------------------------------------
# Micro-op value serialization (engine code only type-checks and reads
# fields, so a rebuilt instance is operationally identical)
# ---------------------------------------------------------------------------


def _op_spec(op: Any) -> Tuple:
    t = type(op)
    if t is Compute:
        return ("compute", op.cycles)
    if t is Mem:
        return ("mem", op.kind, op.addr, op.data)
    if t is Poll:
        return ("poll", op.kind, op.addr, op.until, op.hit_cycles,
                op.miss_cycles, op.hit_instr, op.miss_instr)
    if t is Scu:
        return ("scu", op.kind, op.addr, op.data)
    raise NotCheckpointable(f"unknown pending micro-op {op!r}")


def _op_from_spec(spec: Tuple) -> Any:
    tag = spec[0]
    if tag == "compute":
        return Compute(spec[1])
    if tag == "mem":
        return Mem(spec[1], spec[2], spec[3])
    if tag == "poll":
        return Poll(*spec[1:])
    return Scu(spec[1], spec[2], spec[3])


# ---------------------------------------------------------------------------
# Checkpoint records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CoreCheckpoint:
    """One core's scheduler + accounting + trace-cursor state."""

    state: int  # CoreState code
    busy: int
    wake_countdown: int
    sleep_entry: int
    started: bool
    resume_value: Any
    elw_issued: bool
    finished_at: Optional[int]
    counters: Tuple[int, ...]  # the nine _COUNTERS, in order
    pending: Optional[Tuple]  # _op_spec of the outstanding op
    prog: TraceProgram  # shared, immutable row table
    cursor: Tuple  # (pc, R, ctrs dict, crossed, _rep)


@dataclasses.dataclass
class ScuCheckpoint:
    """Complete SCU state: registers, extensions, drop filter, watchdog."""

    n_cores: int
    ev_buf: Tuple[int, ...]
    ev_mask: Tuple[int, ...]
    irq_mask: Tuple[int, ...]
    ntf_target: Tuple[int, ...]
    elw_wait: Tuple[int, ...]
    elw_pending: frozenset
    drop: Tuple[int, ...]
    dropped_events: int
    drop_armed: bool
    barriers: Tuple[Tuple[int, int, int], ...]  # worker/target/status
    mutexes: Tuple[Tuple, ...]  # (owner, message, pending queue)
    fifos: Tuple[Tuple, ...]  # (depth, fifo, poppers, pushers, msgs, ...)
    watchdog: Optional[Tuple]  # (timeout, mode, max_rel, progress, ...)


@dataclasses.dataclass
class MemberCheckpoint:
    """A whole in-flight job, captured at a full-step boundary.

    In-memory and slot-geometry free: restorable into the same slot, a
    different slot, a different :class:`~repro.core.scu.engine.SlotFleet`,
    or a standalone :class:`~repro.core.scu.engine.Cluster` in either
    engine mode.  The trace tables are shared by reference (immutable);
    everything mutable is copied at capture time, so one checkpoint backs
    arbitrarily many restores.
    """

    n_cores: int
    banking_factor: int
    cycle: int  # absolute local clock at the boundary
    max_cycles: int  # absolute cap of the interrupted run
    n_done: int
    tcdm: Dict[int, int]
    rr: Tuple[int, ...]  # per-bank round-robin pointers
    bank_conflicts: int
    scu_events: int
    cores: Tuple[CoreCheckpoint, ...]
    scu: Optional[ScuCheckpoint]
    faults: Optional[Tuple]  # (events, cursor index, applied log)

    @property
    def progress_cycles(self) -> int:
        """Cycles of work this checkpoint preserves on restore."""
        return self.cycle


# ---------------------------------------------------------------------------
# Capture
# ---------------------------------------------------------------------------


def _capture_scu(scu: SCU) -> ScuCheckpoint:
    wd = scu.watchdog
    wd_ck = None
    if wd is not None:
        if wd.tripped is not None:
            raise NotCheckpointable(
                "watchdog already tripped; the member is failed, not "
                "suspendable"
            )
        wd_ck = (wd.timeout, wd.mode, wd.max_releases, wd.last_progress,
                 wd.release_count, [dict(e) for e in wd.release_log])
    base = scu.base
    return ScuCheckpoint(
        n_cores=scu.n_cores,
        ev_buf=tuple(int(x) for x in base.ev_buf),
        ev_mask=tuple(int(x) for x in base.ev_mask),
        irq_mask=tuple(int(x) for x in base.irq_mask),
        ntf_target=tuple(int(x) for x in base.ntf_target),
        elw_wait=tuple(int(x) for x in scu.elw_wait),
        elw_pending=frozenset(scu._elw_pending),
        drop=tuple(int(x) for x in base.drop),
        dropped_events=int(base.dropped_events),
        drop_armed=bool(base._drop_armed),
        barriers=tuple(
            (b.worker_mask, b.target_mask, b.status) for b in scu.barriers
        ),
        mutexes=tuple(
            (m.owner, m.message, tuple(m.pending)) for m in scu.mutexes
        ),
        fifos=tuple(
            (f.depth, tuple(f.fifo), tuple(f.poppers), tuple(f.pushers),
             tuple(sorted(f.messages.items())), f.dropped, f.pushed)
            for f in scu.fifos
        ),
        watchdog=wd_ck,
    )


def capture_cluster(cluster: Cluster) -> MemberCheckpoint:
    """Checkpoint a running cluster at the current full-step boundary.

    Works on standalone clusters (either engine mode) and fleet-attached
    members (the ``_VecCore`` property layer reads the segment views).
    Raises :class:`NotCheckpointable` when any core runs a generator-backed
    program, the cluster already finished, or the watchdog tripped.
    """
    cores = cluster.cores
    if not cores:
        raise NotCheckpointable("cluster has no loaded program")
    for c in cores:
        if not getattr(c.gen, "_is_trace_cursor", False):
            raise NotCheckpointable(
                f"core {c.cid} runs a generator-backed program; only "
                "compiled TraceProgram cursors are checkpointable "
                "(lower with compiled=True) -- falling back to restart"
            )
    if cluster._n_done >= cluster.n_cores:
        raise NotCheckpointable("cluster already finished")
    scu_ck = _capture_scu(cluster.scu) if cluster.scu is not None else None
    plan = cluster.faults
    faults_ck = None
    if plan is not None:
        faults_ck = (tuple(plan.events), plan._next,
                     [dict(e) for e in plan.applied])
    core_cks = []
    for c in cores:
        cur = c.gen
        pending = c.pending
        core_cks.append(CoreCheckpoint(
            state=int(c.state.value),
            busy=int(c.busy),
            wake_countdown=int(c.wake_countdown),
            sleep_entry=int(c.sleep_entry),
            started=bool(c.started),
            resume_value=c.resume_value,
            elw_issued=bool(c.elw_issued),
            finished_at=c.finished_at,
            counters=tuple(int(getattr(c, n)) for n in _COUNTERS),
            pending=None if pending is None else _op_spec(pending),
            prog=cur.prog,
            cursor=(cur.pc, cur.R, dict(cur.ctrs), cur.crossed, cur._rep),
        ))
    return MemberCheckpoint(
        n_cores=cluster.n_cores,
        banking_factor=cluster.n_banks // cluster.n_cores,
        cycle=int(cluster.cycle),
        max_cycles=int(cluster.max_cycles),
        n_done=int(cluster._n_done),
        tcdm=dict(cluster.tcdm),
        rr=tuple(int(x) for x in cluster._rr),
        bank_conflicts=int(cluster.stats.bank_conflicts),
        scu_events=int(cluster.stats.scu_events),
        cores=tuple(core_cks),
        scu=scu_ck,
        faults=faults_ck,
    )


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------


def _restore_scu(ck: ScuCheckpoint) -> SCU:
    wd = None
    if ck.watchdog is not None:
        timeout, mode, max_rel, progress, rel_count, rel_log = ck.watchdog
        wd = Watchdog(timeout, mode=mode, max_releases=max_rel)
        wd.last_progress = progress
        wd.release_count = rel_count
        wd.release_log = [dict(e) for e in rel_log]
    scu = SCU(
        ck.n_cores,
        n_barriers=len(ck.barriers),
        n_mutexes=len(ck.mutexes),
        fifo_depth=ck.fifos[0][0] if ck.fifos else None,
        n_fifos=len(ck.fifos) if ck.fifos else None,
        watchdog=wd,
    )
    base = scu.base
    base.ev_buf[:] = ck.ev_buf
    base.ev_mask[:] = ck.ev_mask
    base.irq_mask[:] = ck.irq_mask
    base.ntf_target[:] = ck.ntf_target
    scu.elw_wait[:] = ck.elw_wait
    scu._elw_pending = set(ck.elw_pending)
    base.drop[:] = ck.drop
    base.dropped_events = ck.dropped_events
    base._drop_armed = ck.drop_armed
    for b, (wm, tm, status) in zip(scu.barriers, ck.barriers):
        b.worker_mask = wm
        b.target_mask = tm
        b.status = status
    for m, (owner, message, pending) in zip(scu.mutexes, ck.mutexes):
        m.owner = owner
        m.message = message
        m.pending = deque(pending)
    for f, (depth, fifo, poppers, pushers, msgs, dropped, pushed) in zip(
        scu.fifos, ck.fifos
    ):
        f.depth = depth
        f.fifo = deque(fifo)
        f.poppers = deque(poppers)
        f.pushers = deque(pushers)
        f.messages = dict(msgs)
        f.dropped = dropped
        f.pushed = pushed
    # armed sets are derivable state: re-derive from the restored
    # comparators so evaluate/next_event_bound see exactly the captured
    # firing conditions
    for i in range(len(scu.barriers)):
        scu._barrier_touched(i)
    for i in range(len(scu.mutexes)):
        scu._mutex_touched(i)
    for i in range(len(scu.fifos)):
        scu._fifo_touched(i)
    return scu


def _restore_plan(faults_ck: Tuple) -> FaultPlan:
    events, nxt, applied = faults_ck
    # the event tuple is already in plan order; FaultPlan's stable sort
    # rebuilds every derived cache (cycle index, blackout windows) from it
    plan = FaultPlan(list(events))
    plan._next = nxt
    plan.applied = [dict(e) for e in applied]
    return plan


def _resume_program(prog: TraceProgram, cursor_state: Tuple):
    """A ``Program`` resuming ``prog`` at a saved cursor position.

    Bypasses ``TraceProgram.__call__`` (and its single-use guard) on
    purpose: restores share the original -- possibly consumed -- program
    object, cursors only read its immutable tables.  The closure is
    idempotent, so one checkpoint backs many restores, and it is *not* a
    :class:`TraceProgram` instance, so the serve layer's trace-cloning
    admission hook passes it through untouched.
    """
    pc, R, ctrs, crossed, rep = cursor_state

    def make(cluster, cid):
        cur = _TraceCursor(prog, cluster, cid)
        cur.pc = pc
        cur.R = R
        cur.ctrs = dict(ctrs)
        cur.crossed = crossed
        cur._rep = rep
        return cur

    return make


def _plan_for(ckpt: MemberCheckpoint, faults) -> Optional[FaultPlan]:
    if faults == CARRY_FAULTS:
        return _restore_plan(ckpt.faults) if ckpt.faults is not None else None
    return faults


def resume_config(ckpt: MemberCheckpoint, faults=CARRY_FAULTS) -> FleetConfig:
    """A fresh :class:`FleetConfig` that resumes ``ckpt`` when admitted.

    The config passes every fleet admission check (fresh cluster, cycle 0);
    after attachment the caller must run :func:`apply_cluster_state` to
    overwrite the scheduler state -- :meth:`SlotFleet.restore` does both.
    ``faults=CARRY_FAULTS`` replays the checkpointed plan cursor; ``None``
    strips it (migration semantics); a :class:`FaultPlan` overrides.
    """
    scu = _restore_scu(ckpt.scu) if ckpt.scu is not None else None
    cl = Cluster(
        ckpt.n_cores,
        scu=scu,
        banking_factor=ckpt.banking_factor,
        mode="fastforward",
        faults=_plan_for(ckpt, faults),
    )
    programs = [_resume_program(c.prog, c.cursor) for c in ckpt.cores]
    return FleetConfig(cluster=cl, programs=programs,
                       max_cycles=ckpt.max_cycles)


def apply_cluster_state(cluster: Cluster, ckpt: MemberCheckpoint) -> None:
    """Overwrite a freshly loaded (or fleet-attached) cluster with the
    checkpointed scheduler state.  Must run at attachment time, before the
    next step/round; the clock and cap stay absolute, so timeout and
    watchdog semantics continue exactly where the interrupted run left
    off."""
    cluster.cycle = ckpt.cycle
    cluster.max_cycles = ckpt.max_cycles
    cluster._n_done = ckpt.n_done
    cluster.tcdm.clear()
    cluster.tcdm.update(ckpt.tcdm)
    cluster._rr[:] = ckpt.rr
    cluster.stats.bank_conflicts = ckpt.bank_conflicts
    cluster.stats.scu_events = ckpt.scu_events
    V = cluster._vec
    for core, ck in zip(cluster.cores, ckpt.cores):
        core.state = CoreState(ck.state)
        core.busy = ck.busy
        core.wake_countdown = ck.wake_countdown
        core.sleep_entry = ck.sleep_entry
        core.started = ck.started
        core.resume_value = ck.resume_value
        core.elw_issued = ck.elw_issued
        core.finished_at = ck.finished_at
        for name, value in zip(_COUNTERS, ck.counters):
            setattr(core, name, value)
        op = _op_from_spec(ck.pending) if ck.pending is not None else None
        core.pending = op
        if V is not None:
            # derived SoA lanes the property layer does not cover
            cid = core.cid
            if op is not None and (type(op) is Mem or type(op) is Poll):
                V.pend_bank[cid] = cluster._bank_of(op.addr)
                V.has_poll[cid] = type(op) is Poll
            else:
                V.pend_bank[cid] = -1
                V.has_poll[cid] = False


def restore_cluster(
    ckpt: MemberCheckpoint, mode: str = "fastforward", faults=CARRY_FAULTS
) -> Cluster:
    """A standalone cluster resuming ``ckpt``; continue with
    ``cluster.run(ckpt.max_cycles)`` (the clock is absolute, so the cap
    carries over).  ``mode`` picks the engine tier -- lockstep restores are
    the parity reference for the fleet restore paths."""
    scu = _restore_scu(ckpt.scu) if ckpt.scu is not None else None
    cl = Cluster(
        ckpt.n_cores,
        scu=scu,
        banking_factor=ckpt.banking_factor,
        mode=mode,
        faults=_plan_for(ckpt, faults),
    )
    cl.load([_resume_program(c.prog, c.cursor) for c in ckpt.cores])
    apply_cluster_state(cl, ckpt)
    return cl
