"""Tier-1 faithful reproduction: cycle-accurate SCU cluster simulator."""

from .energy import DEFAULT_ENERGY, Activity, EnergyModel, calibrate
from .engine import Cluster, ClusterStats, Compute, CoreState, Mem, Poll, Scu
from .extensions import Barrier, EventFifo, Mutex, Notifier
from .primitives import (
    DEFAULT_COSTS,
    BarrierState,
    CostModel,
    scu_barrier,
    scu_mutex_section,
    sw_barrier,
    sw_mutex_section,
    tas_barrier,
    tas_mutex_section,
)
from .programs import (
    MicrobenchResult,
    barrier_pipeline_programs,
    run_barrier_bench,
    run_chain_bench,
    run_mutex_bench,
    run_nop_bench,
)
from .scu_unit import EV, SCU, BaseUnit, BaseUnits
from .apps import (
    APPS,
    PIPELINED_APPS,
    AppModel,
    AppResult,
    run_app,
    run_app_pipelined,
)

__all__ = [
    "APPS",
    "Activity",
    "AppModel",
    "AppResult",
    "Barrier",
    "BarrierState",
    "BaseUnit",
    "BaseUnits",
    "Poll",
    "Cluster",
    "ClusterStats",
    "Compute",
    "CoreState",
    "CostModel",
    "DEFAULT_COSTS",
    "DEFAULT_ENERGY",
    "EV",
    "EnergyModel",
    "EventFifo",
    "Mem",
    "MicrobenchResult",
    "Mutex",
    "Notifier",
    "PIPELINED_APPS",
    "SCU",
    "Scu",
    "barrier_pipeline_programs",
    "calibrate",
    "run_app",
    "run_app_pipelined",
    "run_barrier_bench",
    "run_chain_bench",
    "run_mutex_bench",
    "run_nop_bench",
    "scu_barrier",
    "scu_mutex_section",
    "sw_barrier",
    "sw_mutex_section",
    "tas_barrier",
    "tas_mutex_section",
]
