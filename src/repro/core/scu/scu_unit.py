"""SCU base units and top-level unit (paper Sec. 4.2-4.4).

One *base unit* per core provides:

  * 32 level-sensitive event lines latched into an *event buffer*,
  * an *event mask* (which buffered events allow elw to complete) and an
    *interrupt mask* (which trigger the irq FSM state; Sec. 5.1),
  * the active/sleep/interrupt FSM and the core clock-enable control --
    realized in :class:`repro.core.scu.engine.Cluster` by the grant-withhold
    and wake sequencing driven from :meth:`SCU.elw_poll`.

Extensions (notifier / barrier / mutex / event FIFO) are shared and generate
per-core events; see :mod:`repro.core.scu.extensions`.

Addressing: the real SCU aliases a 1 Kibit address space per core over the
private links.  We model addresses symbolically as tuples, e.g.::

    ("barrier", 0, "wait_all")      elw: arrive + sleep until barrier fires
    ("mutex", 0, "lock")            elw: try-lock, sleep until elected
    ("mutex", 0, "unlock")          write: release, wake next waiter
    ("notifier", 3, "trigger")      write: send event 3 to mask in data
    ("notifier", 3, "wait")         elw: sleep until notifier event 3
    ("fifo", 2, "push")             write: push event (data) into FIFO 2
    ("fifo", 2, "pop")              elw: sleep until an event is matched,
                                    response carries the popped value
    ("fifo", 2, "level")            read: current FIFO occupancy
    ("event", "wait_any")           elw: sleep until any masked event
    ("mask", "event")               write: set event mask
    ("buffer", "clear")             write: clear event buffer bits in data

Event line allocation (32 lines, Sec. 4.2):
  0..7    notifier events 0..7
  8       barrier event (per-core OR over all barrier instances, Sec. 4.3)
  9       mutex event (OR over all mutex instances)
  10      event-FIFO non-empty
  11..31  external / specialized-PE events (available to users)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from .extensions import Barrier, EventFifo, Mutex, Notifier

__all__ = ["EV", "BaseUnit", "SCU"]


class EV:
    """Event line numbers."""

    NOTIFIER0 = 0  # .. NOTIFIER7 = 7
    BARRIER = 8
    MUTEX = 9
    FIFO = 10
    EXT0 = 11


@dataclasses.dataclass
class BaseUnit:
    """Per-core event buffer / masks (Sec. 4.2)."""

    cid: int
    event_buffer: int = 0
    event_mask: int = 0
    irq_mask: int = 0
    notifier_target_mask: int = 0  # target register for read-triggered notify

    def buffer_set(self, line: int) -> None:
        self.event_buffer |= 1 << line

    def buffer_clear(self, bits: int) -> None:
        self.event_buffer &= ~bits

    def pending_masked(self) -> int:
        return self.event_buffer & self.event_mask

    def pending_irq(self) -> int:
        return self.event_buffer & self.irq_mask


class SCU:
    """Top-level synchronization and communication unit.

    Parameters mirror the paper's design-time knobs: ``n_barriers``
    (:math:`N_B`, paper default ``n_cores/2``) and ``n_mutexes``
    (:math:`N_{Mx}`, paper default 1).
    """

    def __init__(
        self,
        n_cores: int,
        n_barriers: Optional[int] = None,
        n_mutexes: int = 1,
        fifo_depth: Optional[int] = None,
        n_fifos: Optional[int] = None,
    ):
        self.n_cores = n_cores
        n_barriers = max(1, n_cores // 2) if n_barriers is None else n_barriers
        # FIFO defaults scale with the cluster so the producer-consumer
        # discipline (per-core release queues + per-link chain queues, see
        # repro/sync/fifo.py) fits without per-benchmark tuning.
        if fifo_depth is None:
            fifo_depth = max(16, 2 * n_cores)
        if n_fifos is None:
            n_fifos = 2 * n_cores + 8
        self.base: List[BaseUnit] = [BaseUnit(cid=i) for i in range(n_cores)]
        self.barriers: List[Barrier] = [
            Barrier(index=i, n_cores=n_cores) for i in range(n_barriers)
        ]
        self.mutexes: List[Mutex] = [
            Mutex(index=i, n_cores=n_cores) for i in range(n_mutexes)
        ]
        self.notifier = Notifier(n_cores=n_cores)
        self.fifos: List[EventFifo] = [
            EventFifo(index=i, depth=fifo_depth) for i in range(n_fifos)
        ]
        # instance 0 doubles as the legacy cluster-external event queue
        self.fifo = self.fifos[0]
        # FIFO instances whose comparator is armed (queued event AND pending
        # popper).  Maintained at the mutation points (push / pop
        # registration / delivery) so the per-cycle evaluate and the
        # fast-forward bound scan touch only armed instances instead of all
        # 2*n_cores+8 -- the engine hot loop must not pay for idle FIFOs.
        self._armed_fifos: set = set()
        self.cluster = None
        # response data latched per core for the in-flight elw (Fig. 4: the
        # read response carries the event buffer or extension data).
        self._elw_response: Dict[int, int] = {}

    # ----------------------------------------------------------------- wiring
    def attach(self, cluster) -> None:
        self.cluster = cluster

    # ------------------------------------------------------------ plain access
    def access(self, cid: int, kind: str, addr: Any, data: int = 0) -> Optional[int]:
        """Single-cycle read/write over the private link (non-elw)."""
        unit = self.base[cid]
        tag = addr[0]
        if kind == "write":
            if tag == "mask":
                if addr[1] == "event":
                    unit.event_mask = data
                else:
                    unit.irq_mask = data
            elif tag == "buffer":
                unit.buffer_clear(data)
            elif tag == "notifier":
                self.notifier.trigger(addr[1], data, self.base)
            elif tag == "mutex":
                if addr[2] == "unlock":
                    self.mutexes[addr[1]].unlock(cid, data, self.base)
            elif tag == "barrier":
                b = self.barriers[addr[1]]
                if addr[2] == "workers":
                    b.worker_mask = data
                elif addr[2] == "targets":
                    b.target_mask = data
                elif addr[2] == "arrive_only":
                    # non-blocking arrival (producer that does not wait)
                    b.arrive(cid, self.base)
            elif tag == "fifo":
                if addr[2] == "push":
                    self.fifos[addr[1]].push(data)
                    self._fifo_touched(addr[1])
            elif tag == "target_reg":
                unit.notifier_target_mask = data
            return None
        else:  # read
            if tag == "buffer":
                return unit.event_buffer
            if tag == "barrier":
                return self.barriers[addr[1]].status
            if tag == "mutex":
                return 1 if self.mutexes[addr[1]].owner is not None else 0
            if tag == "fifo":
                return len(self.fifos[addr[1]].fifo)  # occupancy level
            return 0

    # ------------------------------------------------------------------ elw
    def elw_trigger(self, cid: int, addr: Any) -> None:
        """Extension side-effect of an elw transaction (fires exactly once)."""
        tag = addr[0]
        if tag == "barrier":
            if addr[2] in ("wait_all", "arrive_wait"):
                self.barriers[addr[1]].arrive(cid, self.base)
            # addr[2] == "wait": pure target wait, no arrival
        elif tag == "mutex":
            self.mutexes[addr[1]].try_lock(cid, self.base)
        elif tag == "fifo":
            # blocking pop: queue as a popper; the FIFO comparator matches
            # queued events to poppers one per cycle (extensions.EventFifo)
            self.fifos[addr[1]].register_popper(cid)
            self._fifo_touched(addr[1])
        elif tag == "notifier" and addr[2] == "trigger_wait":
            # read-triggered notify using the per-core target register
            self.notifier.trigger(addr[1], self.base[cid].notifier_target_mask, self.base)
        # ("event","wait_any") and ("notifier", n, "wait"): no trigger action

    def _wait_mask(self, cid: int, addr: Any) -> int:
        tag = addr[0]
        if tag == "barrier":
            return 1 << EV.BARRIER
        if tag == "mutex":
            return 1 << EV.MUTEX
        if tag == "fifo":
            return 1 << EV.FIFO
        if tag == "notifier":
            return 1 << (EV.NOTIFIER0 + addr[1])
        if tag == "event":
            return self.base[cid].event_mask or 0xFFFFFFFF
        raise ValueError(addr)

    def elw_would_grant(self, cid: int, addr: Any) -> bool:
        """Side-effect-free preview of :meth:`elw_poll`'s grant decision.

        Used by the fast-forward scheduler: a sleeping core whose waited-on
        event is not buffered cannot wake during a quiescent span (events are
        only generated by core transactions or armed comparators, both of
        which force a full step)."""
        return bool(self.base[cid].event_buffer & self._wait_mask(cid, addr))

    def elw_poll(self, cid: int, addr: Any) -> Tuple[bool, int]:
        """Grant decision for a pending elw; returns (granted, response)."""
        unit = self.base[cid]
        wait_mask = self._wait_mask(cid, addr)
        hit = unit.event_buffer & wait_mask
        if not hit:
            return False, 0
        # Response channel data (Sec. 5): mutex passes the 32-bit message of
        # the unlocking core, a FIFO pop returns the matched event value;
        # otherwise the event buffer content is returned.
        if addr[0] == "mutex":
            value = self.mutexes[addr[1]].message
        elif addr[0] == "fifo":
            value = self.fifos[addr[1]].take_message(cid)
        else:
            value = unit.event_buffer
        # Auto-clear (address-controlled in hardware; we always auto-clear the
        # lines belonging to the waited-on extension, the common case).
        unit.buffer_clear(wait_mask)
        return True, value

    # ------------------------------------------------------------- evaluate
    def evaluate(self, cycle: int) -> int:
        """Per-cycle extension evaluation -> event generation (phase 4)."""
        n = 0
        for b in self.barriers:
            n += b.evaluate(self.base)
        for m in self.mutexes:
            n += m.evaluate(self.base)
        if self._armed_fifos:
            for idx in sorted(self._armed_fifos):
                n += self.fifos[idx].evaluate(self.base)
                self._fifo_touched(idx)
        return n

    def next_event_bound(self) -> Optional[int]:
        """Min over the extensions' ``next_event_bound`` hooks (see
        :mod:`repro.core.scu.extensions` for the contract): cycles until any
        comparator could generate an event absent new core transactions.
        0 forces the engine to take a full lockstep step; ``None`` means
        every comparator is disarmed until a core acts."""
        if self._armed_fifos:
            # an armed FIFO comparator fires next cycle (EventFifo's bound
            # contract: 0 while an event can be matched to a popper)
            return 0
        bound: Optional[int] = None
        for ext in (*self.barriers, *self.mutexes):
            b = ext.next_event_bound()
            if b is None:
                continue
            if b <= 0:
                return 0
            if bound is None or b < bound:
                bound = b
        return bound

    def _fifo_touched(self, idx: int) -> None:
        """Re-derive instance ``idx``'s armed state after a mutation."""
        f = self.fifos[idx]
        if f.fifo and f.poppers:
            self._armed_fifos.add(idx)
        else:
            self._armed_fifos.discard(idx)

    # ------------------------------------------------------------- external
    def push_external_event(self, event_id: int) -> None:
        self.fifo.push(event_id)
        self._fifo_touched(0)
