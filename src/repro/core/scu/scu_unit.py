"""SCU base units and top-level unit (paper Sec. 4.2-4.4).

One *base unit* per core provides:

  * 32 level-sensitive event lines latched into an *event buffer*,
  * an *event mask* (which buffered events allow elw to complete) and an
    *interrupt mask* (which trigger the irq FSM state; Sec. 5.1),
  * the active/sleep/interrupt FSM and the core clock-enable control --
    realized in :class:`repro.core.scu.engine.Cluster` by the grant-withhold
    and wake sequencing driven from :meth:`SCU.elw_poll`.

The per-core registers are stored structure-of-arrays (numpy int64 vectors
indexed by core id) so the engine's vectorized kernels can scan event
buffers and wait masks for all cores at once; :class:`BaseUnit` is a
per-core view for the scalar paths and the extension API.

Extensions (notifier / barrier / mutex / event FIFO) are shared and generate
per-core events; see :mod:`repro.core.scu.extensions`.  The SCU tracks which
extension instances are *armed* (comparator could fire without a new core
transaction) at the mutation points, so the per-cycle :meth:`SCU.evaluate`
and the fast-forward :meth:`SCU.next_event_bound` touch only armed instances
-- on a 256-core cluster with 128 barrier and 520 FIFO instances the engine
hot loop must not pay for idle comparators.

Addressing: the real SCU aliases a 1 Kibit address space per core over the
private links.  We model addresses symbolically as tuples, e.g.::

    ("barrier", 0, "wait_all")      elw: arrive + sleep until barrier fires
    ("mutex", 0, "lock")            elw: try-lock, sleep until elected
    ("mutex", 0, "unlock")          write: release, wake next waiter
    ("notifier", 3, "trigger")      write: send event 3 to mask in data
    ("notifier", 3, "wait")         elw: sleep until notifier event 3
    ("fifo", 2, "push")             write: push event (data) into FIFO 2
    ("fifo", 2, "push_wait")        elw: blocking push -- sleep until the
                                    queue accepts the event in data
    ("fifo", 2, "pop")              elw: sleep until an event is matched,
                                    response carries the popped value
    ("fifo", 2, "level")            read: current FIFO occupancy
    ("event", "wait_any")           elw: sleep until any masked event
    ("mask", "event")               write: set event mask
    ("buffer", "clear")             write: clear event buffer bits in data

Event line allocation (32 lines, Sec. 4.2):
  0..7    notifier events 0..7
  8       barrier event (per-core OR over all barrier instances, Sec. 4.3)
  9       mutex event (OR over all mutex instances)
  10      event-FIFO non-empty
  11..31  external / specialized-PE events (available to users)
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

import numpy as np

from .extensions import Barrier, EventFifo, Mutex, Notifier

__all__ = ["EV", "BaseUnit", "BaseUnits", "SCU"]


class EV:
    """Event line numbers."""

    NOTIFIER0 = 0  # .. NOTIFIER7 = 7
    BARRIER = 8
    MUTEX = 9
    FIFO = 10
    EXT0 = 11


class BaseUnit:
    """Per-core view of the structure-of-arrays base-unit registers."""

    __slots__ = ("cid", "_U")

    def __init__(self, cid: int, units: "BaseUnits"):
        self.cid = cid
        self._U = units

    # -- register access ----------------------------------------------------
    @property
    def event_buffer(self) -> int:
        return int(self._U.ev_buf[self.cid])

    @event_buffer.setter
    def event_buffer(self, value: int) -> None:
        self._U.ev_buf[self.cid] = value

    @property
    def event_mask(self) -> int:
        return int(self._U.ev_mask[self.cid])

    @event_mask.setter
    def event_mask(self, value: int) -> None:
        self._U.ev_mask[self.cid] = value

    @property
    def irq_mask(self) -> int:
        return int(self._U.irq_mask[self.cid])

    @irq_mask.setter
    def irq_mask(self, value: int) -> None:
        self._U.irq_mask[self.cid] = value

    @property
    def notifier_target_mask(self) -> int:
        return int(self._U.ntf_target[self.cid])

    @notifier_target_mask.setter
    def notifier_target_mask(self, value: int) -> None:
        self._U.ntf_target[self.cid] = value

    def buffer_set(self, line: int) -> None:
        U = self._U
        if U._drop_armed:
            bit = 1 << line
            if U.drop[self.cid] & bit:
                # armed lost-wake fault: this delivery is silently eaten
                U.drop[self.cid] &= ~bit
                U.dropped_events += 1
                if not U.drop.any():
                    U._drop_armed = False
                return
        U.ev_buf[self.cid] |= 1 << line

    def buffer_clear(self, bits: int) -> None:
        self._U.ev_buf[self.cid] &= ~bits

    def pending_masked(self) -> int:
        return self.event_buffer & self.event_mask

    def pending_irq(self) -> int:
        return self.event_buffer & self.irq_mask


class BaseUnits:
    """All per-core base-unit registers, structure-of-arrays (Sec. 4.2).

    Sequence of :class:`BaseUnit` views for the per-core API; the numpy
    vectors (``ev_buf``, ``ev_mask``, ...) are the storage and what the
    vectorized engine kernels and extension deliveries operate on.
    """

    __slots__ = (
        "n_cores", "ev_buf", "ev_mask", "irq_mask", "ntf_target", "_views",
        "drop", "dropped_events", "_drop_armed",
    )

    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        self.ev_buf = np.zeros(n_cores, dtype=np.int64)
        self.ev_mask = np.zeros(n_cores, dtype=np.int64)
        self.irq_mask = np.zeros(n_cores, dtype=np.int64)
        self.ntf_target = np.zeros(n_cores, dtype=np.int64)
        self._views = [BaseUnit(i, self) for i in range(n_cores)]
        # lost-wake fault filter (repro.core.scu.faults): per-core one-shot
        # drop masks -- the next delivery of a dropped line to that core is
        # suppressed and the armed bit consumed.  ``_drop_armed`` keeps the
        # fault-free delivery fast path branch-cheap.  Deliberately NOT part
        # of adopt_views: drops are per-tenant state and must never leak
        # across slot recycling.
        self.drop = np.zeros(n_cores, dtype=np.int64)
        self.dropped_events = 0
        self._drop_armed = False

    def arm_drop(self, cid: int, lines: int = 0xFFFFFFFF) -> None:
        """Arm a one-shot lost-wake filter: the next delivery of any line in
        ``lines`` to core ``cid`` is suppressed (one line consumed per hit)."""
        self.drop[cid] |= lines
        self._drop_armed = True

    def __len__(self) -> int:
        return self.n_cores

    def __getitem__(self, cid: int) -> BaseUnit:
        return self._views[cid]

    def __iter__(self):
        return iter(self._views)

    def target_bools(self, target_mask: int) -> np.ndarray:
        """Decode a core bitmask (arbitrary precision) into a bool vector."""
        n = self.n_cores
        raw = target_mask.to_bytes((n + 7) // 8, "little")
        return np.unpackbits(
            np.frombuffer(raw, dtype=np.uint8), bitorder="little"
        )[:n].astype(bool)

    def deliver(self, line: int, target_mask: int) -> int:
        """Set event ``line`` in every targeted core's buffer (vectorized);
        returns the number of events actually delivered (armed lost-wake
        drops suppress their target and are excluded from the count)."""
        full = (1 << self.n_cores) - 1
        target_mask &= full
        if self._drop_armed:
            return self._deliver_filtered(line, target_mask)
        if target_mask == full:
            self.ev_buf |= 1 << line
            return self.n_cores
        targets = self.target_bools(target_mask)
        self.ev_buf[targets] |= 1 << line
        return int(targets.sum())

    def _deliver_filtered(self, line: int, target_mask: int) -> int:
        """Delivery with the lost-wake drop filter armed (fault injection)."""
        bit = 1 << line
        targets = self.target_bools(target_mask)
        victims = targets & ((self.drop & bit) != 0)
        if victims.any():
            hit = targets & ~victims
            self.ev_buf[hit] |= bit
            self.drop[victims] &= ~bit
            self.dropped_events += int(victims.sum())
            if not self.drop.any():
                self._drop_armed = False
            return int(hit.sum())
        self.ev_buf[targets] |= bit
        return int(targets.sum())


class SCU:
    """Top-level synchronization and communication unit.

    Parameters mirror the paper's design-time knobs: ``n_barriers``
    (:math:`N_B`, paper default ``n_cores/2``) and ``n_mutexes``
    (:math:`N_{Mx}`, paper default 1).
    """

    def __init__(
        self,
        n_cores: int,
        n_barriers: Optional[int] = None,
        n_mutexes: int = 1,
        fifo_depth: Optional[int] = None,
        n_fifos: Optional[int] = None,
        watchdog=None,
    ):
        self.n_cores = n_cores
        n_barriers = max(1, n_cores // 2) if n_barriers is None else n_barriers
        # FIFO defaults scale with the cluster so the producer-consumer
        # discipline (per-core release queues + per-link chain queues, see
        # repro/sync/fifo.py) fits without per-benchmark tuning.
        if fifo_depth is None:
            fifo_depth = max(16, 2 * n_cores)
        if n_fifos is None:
            n_fifos = 2 * n_cores + 8
        self.base = BaseUnits(n_cores)
        self.barriers: List[Barrier] = [
            Barrier(index=i, n_cores=n_cores) for i in range(n_barriers)
        ]
        self.mutexes: List[Mutex] = [
            Mutex(index=i, n_cores=n_cores) for i in range(n_mutexes)
        ]
        self.notifier = Notifier(n_cores=n_cores)
        self.fifos: List[EventFifo] = [
            EventFifo(index=i, depth=fifo_depth) for i in range(n_fifos)
        ]
        # instance 0 doubles as the legacy cluster-external event queue
        self.fifo = self.fifos[0]
        # Armed-instance tracking: an extension instance is armed when its
        # comparator could fire without a new core transaction
        # (next_event_bound() == 0).  Maintained at the mutation points
        # (arrivals, lock/unlock, push/pop registration, delivery) so the
        # per-cycle evaluate and the fast-forward bound scan touch only
        # armed instances -- the engine hot loop must not pay for idle
        # extensions (see the extensions.py module docstring).
        self._armed_barriers: set = set()
        self._armed_mutexes: set = set()
        self._armed_fifos: set = set()
        self.cluster = None
        # Wait mask of each core's in-flight elw, latched at trigger time
        # (the mask cannot change while the core is stalled/asleep on the
        # elw): lets the engine scan all pending elws against the event
        # buffers in one vectorized pass.
        self.elw_wait = np.zeros(n_cores, dtype=np.int64)
        # Stuck-comparator watchdog (repro.core.scu.faults.Watchdog) and the
        # cores with an in-flight elw it guards.  Progress = any SCU-visible
        # activity (access / trigger / grant / comparator event); the
        # watchdog's bound rides next_event_bound() so the fast-forward
        # tiers land exactly on the firing cycle.
        self.watchdog = watchdog
        self._elw_pending: set = set()

    # ----------------------------------------------------------------- wiring
    def attach(self, cluster) -> None:
        self.cluster = cluster

    def state_key(self):
        """Hashable snapshot of the complete SCU state.

        Used by the compiled-trace monitor (:mod:`repro.core.scu.trace`) as
        part of its whole-cluster recurrence digest: two equal keys mean the
        unit will evolve identically from both points.  Covers the per-core
        register file, every extension instance's own ``state_key`` and the
        latched elw wait masks; the armed sets are derivable from extension
        state and the drop filter is fault-only (the monitor is disabled
        under fault plans), so neither is included."""
        base = self.base
        return (
            base.ev_buf.tobytes(), base.ev_mask.tobytes(),
            base.irq_mask.tobytes(), base.ntf_target.tobytes(),
            self.elw_wait.tobytes(), frozenset(self._elw_pending),
            tuple(b.state_key() for b in self.barriers),
            tuple(m.state_key() for m in self.mutexes),
            tuple(f.state_key() for f in self.fifos),
        )

    def adopt_views(
        self,
        ev_buf: np.ndarray,
        ev_mask: np.ndarray,
        irq_mask: np.ndarray,
        ntf_target: np.ndarray,
        elw_wait: np.ndarray,
    ) -> None:
        """Re-home the per-core register storage onto caller-provided views.

        Used by the fleet engine (:func:`repro.core.scu.engine.simulate_fleet`)
        to partition the base-unit registers of many independent clusters as
        contiguous segments of flattened fleet-level arrays: this SCU keeps
        operating on its own cores only (the views span exactly its
        segment), while the fleet's batched kernels scan every config's
        event buffers and latched elw wait masks in one pass.  Current
        register contents are copied into the views before binding."""
        views = (ev_buf, ev_mask, irq_mask, ntf_target, elw_wait)
        currents = (
            self.base.ev_buf, self.base.ev_mask, self.base.irq_mask,
            self.base.ntf_target, self.elw_wait,
        )
        for view, cur in zip(views, currents):
            if view.shape != cur.shape:
                raise ValueError(
                    f"adopt_views: shape {view.shape} != {cur.shape}"
                )
            view[:] = cur
        self.base.ev_buf, self.base.ev_mask = ev_buf, ev_mask
        self.base.irq_mask, self.base.ntf_target = irq_mask, ntf_target
        self.elw_wait = elw_wait

    # ------------------------------------------------------------ plain access
    def _progress(self) -> None:
        """Record SCU-visible activity for the watchdog's progress clock."""
        wd = self.watchdog
        if wd is not None and self.cluster is not None:
            wd.last_progress = self.cluster.cycle

    def access(self, cid: int, kind: str, addr: Any, data: int = 0) -> Optional[int]:
        """Single-cycle read/write over the private link (non-elw)."""
        if self.watchdog is not None:
            self._progress()
        unit = self.base[cid]
        tag = addr[0]
        if kind == "write":
            if tag == "mask":
                if addr[1] == "event":
                    unit.event_mask = data
                else:
                    unit.irq_mask = data
            elif tag == "buffer":
                unit.buffer_clear(data)
            elif tag == "notifier":
                self.notifier.trigger(addr[1], data, self.base)
            elif tag == "mutex":
                if addr[2] == "unlock":
                    self.mutexes[addr[1]].unlock(cid, data, self.base)
                    self._mutex_touched(addr[1])
            elif tag == "barrier":
                b = self.barriers[addr[1]]
                if addr[2] == "workers":
                    b.worker_mask = data
                elif addr[2] == "targets":
                    b.target_mask = data
                elif addr[2] == "arrive_only":
                    # non-blocking arrival (producer that does not wait)
                    b.arrive(cid, self.base)
                self._barrier_touched(addr[1])
            elif tag == "fifo":
                if addr[2] == "push":
                    self.fifos[addr[1]].push(data)
                    self._fifo_touched(addr[1])
            elif tag == "target_reg":
                unit.notifier_target_mask = data
            return None
        else:  # read
            if tag == "buffer":
                return unit.event_buffer
            if tag == "barrier":
                return self.barriers[addr[1]].status
            if tag == "mutex":
                return 1 if self.mutexes[addr[1]].owner is not None else 0
            if tag == "fifo":
                return len(self.fifos[addr[1]].fifo)  # occupancy level
            return 0

    # ------------------------------------------------------------------ elw
    def elw_trigger(self, cid: int, addr: Any, data: int = 0) -> None:
        """Extension side-effect of an elw transaction (fires exactly once)."""
        tag = addr[0]
        if tag == "barrier":
            if addr[2] in ("wait_all", "arrive_wait"):
                self.barriers[addr[1]].arrive(cid, self.base)
                self._barrier_touched(addr[1])
            # addr[2] == "wait": pure target wait, no arrival
        elif tag == "mutex":
            self.mutexes[addr[1]].try_lock(cid, self.base)
            self._mutex_touched(addr[1])
        elif tag == "fifo":
            if addr[2] == "push_wait":
                # blocking push: queue as a pending pusher; the comparator
                # accepts the event once the queue has room, generating the
                # producer's wake event (backpressure without credits)
                self.fifos[addr[1]].register_pusher(cid, data)
            else:
                # blocking pop: queue as a popper; the FIFO comparator
                # matches queued events to poppers one per cycle
                self.fifos[addr[1]].register_popper(cid)
            self._fifo_touched(addr[1])
        elif tag == "notifier" and addr[2] == "trigger_wait":
            # read-triggered notify using the per-core target register
            self.notifier.trigger(addr[1], self.base[cid].notifier_target_mask, self.base)
        # ("event","wait_any") and ("notifier", n, "wait"): no trigger action
        self.elw_wait[cid] = self._wait_mask(cid, addr)
        self._elw_pending.add(cid)
        if self.watchdog is not None:
            self._progress()

    def _wait_mask(self, cid: int, addr: Any) -> int:
        tag = addr[0]
        if tag == "barrier":
            return 1 << EV.BARRIER
        if tag == "mutex":
            return 1 << EV.MUTEX
        if tag == "fifo":
            return 1 << EV.FIFO
        if tag == "notifier":
            return 1 << (EV.NOTIFIER0 + addr[1])
        if tag == "event":
            return self.base[cid].event_mask or 0xFFFFFFFF
        raise ValueError(addr)

    def scu_blacked(self, cycle: Optional[int] = None) -> bool:
        """True while an injected ``scu_blackout`` fault window covers the
        cluster's current cycle (see :class:`repro.core.scu.faults.FaultEvent`):
        comparators neither evaluate nor grant.  Triggers still latch and
        deliveries still buffer -- the armed state replays on the first
        ungated evaluate after the window, and buffered grants release then.
        The fault plan pins its ``next_event_bound`` to 0 through the whole
        window, so every engine tier takes full steps across it and the
        gating stays bit-exact between lockstep and fastforward."""
        cl = self.cluster
        if cl is None:
            return False
        plan = getattr(cl, "faults", None)
        if plan is None:
            return False
        return plan.scu_blacked(cl.cycle if cycle is None else cycle)

    def elw_would_grant(self, cid: int, addr: Any) -> bool:
        """Side-effect-free preview of :meth:`elw_poll`'s grant decision.

        Used by the fast-forward scheduler: a sleeping core whose waited-on
        event is not buffered cannot wake during a quiescent span (events are
        only generated by core transactions or armed comparators, both of
        which force a full step)."""
        if self.scu_blacked():
            return False
        return bool(self.base.ev_buf[cid] & self._wait_mask(cid, addr))

    def elw_any_grantable(self, cids: np.ndarray) -> bool:
        """Vectorized :meth:`elw_would_grant` over cores with in-flight elws."""
        if self.scu_blacked():
            return False
        return bool(np.any(self.base.ev_buf[cids] & self.elw_wait[cids]))

    def elw_grantable_mask(self, cids: np.ndarray) -> np.ndarray:
        """Bool mask over ``cids``: whose waited-on event is buffered now."""
        if self.scu_blacked():
            return np.zeros(len(cids), dtype=bool)
        return (self.base.ev_buf[cids] & self.elw_wait[cids]) != 0

    def elw_poll(self, cid: int, addr: Any) -> Tuple[bool, int]:
        """Grant decision for a pending elw; returns (granted, response)."""
        if self.scu_blacked():
            return False, 0
        unit = self.base[cid]
        wait_mask = self._wait_mask(cid, addr)
        hit = unit.event_buffer & wait_mask
        if not hit:
            return False, 0
        # Response channel data (Sec. 5): mutex passes the 32-bit message of
        # the unlocking core, a FIFO pop/push_wait returns the matched event
        # value; otherwise the event buffer content is returned.
        if addr[0] == "mutex":
            value = self.mutexes[addr[1]].message
        elif addr[0] == "fifo":
            value = self.fifos[addr[1]].take_message(cid)
        else:
            value = unit.event_buffer
        # Auto-clear (address-controlled in hardware; we always auto-clear the
        # lines belonging to the waited-on extension, the common case).
        unit.buffer_clear(wait_mask)
        self._elw_pending.discard(cid)
        if self.watchdog is not None:
            self._progress()
        return True, value

    # ------------------------------------------------------------- evaluate
    def evaluate(self, cycle: int) -> int:
        """Per-cycle extension evaluation -> event generation (phase 0).

        Only armed instances are visited; the armed sets are maintained at
        the mutation points (see the class docstring), and re-derived after
        each evaluation because firing usually disarms the comparator.
        During an injected ``scu_blackout`` window the comparator visits are
        gated (armed state persists and replays at window end); the watchdog
        branch still runs -- a blackout reads as zero progress, which is
        exactly the escalation signal the service layer quarantines on."""
        n = 0
        if self.scu_blacked(cycle):
            wd = self.watchdog
            if wd is not None and self._elw_pending and wd.due(cycle):
                wd.fire(self, cycle)
            return 0
        if self._armed_barriers:
            for idx in sorted(self._armed_barriers):
                n += self.barriers[idx].evaluate(self.base)
                self._barrier_touched(idx)
        if self._armed_mutexes:
            for idx in sorted(self._armed_mutexes):
                n += self.mutexes[idx].evaluate(self.base)
                self._mutex_touched(idx)
        if self._armed_fifos:
            for idx in sorted(self._armed_fifos):
                n += self.fifos[idx].evaluate(self.base)
                self._fifo_touched(idx)
        wd = self.watchdog
        if wd is not None:
            if n:
                wd.last_progress = cycle
            elif self._elw_pending and wd.due(cycle):
                wd.fire(self, cycle)
        return n

    def watchdog_due(self, cycle: int) -> bool:
        """True when the watchdog deadline has elapsed with waiters parked
        (the fleet step's phase-0 gate: evaluate must run so the watchdog
        can fire even with every comparator disarmed)."""
        wd = self.watchdog
        return wd is not None and bool(self._elw_pending) and wd.due(cycle)

    def next_event_bound(self) -> Optional[int]:
        """Min over the armed extensions' ``next_event_bound`` hooks (see
        :mod:`repro.core.scu.extensions` for the contract): cycles until any
        comparator could generate an event absent new core transactions.
        0 forces the engine to take a full step; ``None`` means every
        comparator is disarmed until a core acts.  All builtin extensions
        have 0/None bounds, so armed-set membership is the whole answer --
        plus, when a watchdog guards parked elw waiters, its (timed)
        deadline: progress only ever pushes the firing later, so the bound
        never over-estimates."""
        if self._armed_barriers or self._armed_mutexes or self._armed_fifos:
            return 0
        wd = self.watchdog
        if wd is not None and self._elw_pending and self.cluster is not None:
            return wd.bound(self.cluster.cycle)
        return None

    def _barrier_touched(self, idx: int) -> None:
        """Re-derive barrier ``idx``'s armed state after a mutation."""
        if self.barriers[idx].next_event_bound() == 0:
            self._armed_barriers.add(idx)
        else:
            self._armed_barriers.discard(idx)

    def _mutex_touched(self, idx: int) -> None:
        """Re-derive mutex ``idx``'s armed state after a mutation."""
        if self.mutexes[idx].next_event_bound() == 0:
            self._armed_mutexes.add(idx)
        else:
            self._armed_mutexes.discard(idx)

    def _fifo_touched(self, idx: int) -> None:
        """Re-derive FIFO ``idx``'s armed state after a mutation."""
        if self.fifos[idx].next_event_bound() == 0:
            self._armed_fifos.add(idx)
        else:
            self._armed_fifos.discard(idx)

    # ------------------------------------------------------------- external
    def push_external_event(self, event_id: int) -> None:
        self.fifo.push(event_id)
        self._fifo_touched(0)
