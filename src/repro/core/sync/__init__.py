"""Legacy home of the training-layer sync strategies.

The implementations moved to the unified :mod:`repro.sync` policy registry;
:mod:`repro.core.sync.strategies` remains as a compatibility shim.
"""

from repro.core.sync.strategies import STRATEGIES, opt_state_specs, shape_gradients

__all__ = ["STRATEGIES", "opt_state_specs", "shape_gradients"]
