"""DEPRECATED legacy home of the training-layer sync strategies.

The implementations live in the unified :mod:`repro.sync` policy registry;
this package only forwards (with a :class:`DeprecationWarning`) through
:mod:`repro.core.sync.strategies`.
"""

__all__ = ["STRATEGIES", "opt_state_specs", "shape_gradients"]


def __getattr__(name: str):
    if name in __all__:
        from repro.core.sync import strategies

        return getattr(strategies, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
