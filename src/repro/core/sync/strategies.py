"""Backward-compat shim over the unified ``repro.sync`` policy registry.

The training-schedule implementations that used to live here (the paper's
three disciplines transplanted to the gradient-synchronization schedule of
a data-parallel step -- see ``repro/sync/policies.py`` for the mapping)
are now layer (c) of the :class:`repro.sync.SyncPolicy` objects.  This
module keeps the old string-keyed call surface working:

  * ``STRATEGIES``                 -- the paper's original triad (frozen for
    compatibility; use :func:`repro.sync.available_policies` to enumerate
    every registered discipline, including extensions like ``tree``),
  * ``shape_gradients(strategy, ...)`` / ``opt_state_specs(strategy, ...)``
    -- dispatch through the registry.
"""

from __future__ import annotations

from typing import Any

from jax.sharding import Mesh

from repro.sync import get_policy

__all__ = ["STRATEGIES", "shape_gradients", "opt_state_specs"]

STRATEGIES = ("scu", "tas", "sw")


def shape_gradients(
    strategy: str, grads: Any, params_shape: Any, mesh: Mesh, cfg=None
) -> Any:
    """Impose the named policy's synchronization discipline on the grads."""
    return get_policy(strategy).shape_gradients(grads, params_shape, mesh, cfg=cfg)


def opt_state_specs(strategy: str, params_shape: Any, mesh: Mesh, cfg=None) -> Any:
    """Sharding specs for master/m/v under the named policy."""
    return get_policy(strategy).opt_state_specs(params_shape, mesh, cfg=cfg)
