"""DEPRECATED compatibility shim -- use the :mod:`repro.sync` registry.

The training-schedule implementations that used to live here are layer (c)
of the :class:`repro.sync.SyncPolicy` objects; every attribute below is a
one-line deprecation wrapper that warns and forwards.  Enumerate
disciplines with :func:`repro.sync.available_policies` and dispatch with
:func:`repro.sync.get_policy` instead.
"""

from __future__ import annotations

import warnings
from typing import Any

__all__ = ["STRATEGIES", "shape_gradients", "opt_state_specs"]


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.sync.strategies.{name} is deprecated; use the "
        "repro.sync registry (get_policy / available_policies)",
        DeprecationWarning,
        stacklevel=3,
    )


def shape_gradients(strategy: str, grads: Any, params_shape: Any, mesh, cfg=None):
    _warn("shape_gradients")
    from repro.sync import get_policy

    return get_policy(strategy).shape_gradients(grads, params_shape, mesh, cfg=cfg)


def opt_state_specs(strategy: str, params_shape: Any, mesh, cfg=None):
    _warn("opt_state_specs")
    from repro.sync import get_policy

    return get_policy(strategy).opt_state_specs(params_shape, mesh, cfg=cfg)


def __getattr__(name: str):
    if name == "STRATEGIES":
        _warn("STRATEGIES")
        return ("scu", "tas", "sw")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
