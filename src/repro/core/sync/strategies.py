"""SyncEngine: the paper's three synchronization disciplines at chip scale.

The SCU paper compares three implementations of the same synchronization
semantics (Sec. 6.1): ``SW`` (spin-lock, fully serialized), ``TAS``
(coarse lock + idle-wait, one big blocking sync), ``SCU`` (hardware
primitives: fine-grain, O(1)-cost, overlappable).  Transplanted to the
gradient-synchronization schedule of a data-parallel training step:

  * ``sw``  -- per-tensor *serialized* synchronization: an optimization-
    barrier chain forces XLA to issue one gradient collective per parameter
    tensor, strictly in order (the spin-lock analogue: maximal launch count,
    zero overlap).
  * ``tas`` -- one coarse synchronization point: all gradients are fused
    into a single blocking sync at the end of the backward pass (idle-wait
    analogue: minimal launch count, but compute and communication cannot
    overlap across the barrier).
  * ``scu`` -- the paper's discipline: fine-grain *bucketed* reduce-scatter
    with ZeRO-sharded optimizer state; no artificial barriers, so the XLA
    latency-hiding scheduler overlaps gradient collectives with remaining
    backward compute, and the "critical section" (optimizer update) is
    shard-parallel instead of replicated.  New bf16 params are all-gathered.

The strategies are *numerically identical* (same loss, same update); they
differ only in schedule/collectives -- exactly like the paper's variants.
The dry-run collective analysis (EXPERIMENTS.md §Roofline) quantifies the
difference in the collective roofline term; ``benchmarks/jax_barriers.py``
measures the wall-clock difference on real (host) devices.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import param_specs, zero_spec

__all__ = ["STRATEGIES", "shape_gradients", "opt_state_specs"]

STRATEGIES = ("scu", "tas", "sw")


def _barrier_chain(tree: Any) -> Any:
    """Serialize all leaves with an optimization-barrier dependency chain."""
    leaves, treedef = jax.tree.flatten(tree)
    token = jnp.zeros((), jnp.float32)
    out = []
    for leaf in leaves:
        leaf, token = jax.lax.optimization_barrier((leaf, token))
        token = token + 0.0  # keep the chain explicit
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def shape_gradients(
    strategy: str, grads: Any, params_shape: Any, mesh: Mesh, cfg=None
) -> Any:
    """Impose the synchronization discipline on the gradient tree."""
    if strategy == "sw":
        # per-tensor serialized sync: barrier chain forces one collective per
        # tensor in program order
        return _barrier_chain(grads)
    if strategy == "tas":
        # single coarse sync point between backward and optimizer
        return jax.lax.optimization_barrier(grads)
    if strategy == "scu":
        # fine-grain reduce-scatter onto the ZeRO shards; no barriers
        specs = param_specs(params_shape, mesh, cfg=cfg)
        zspecs = jax.tree.map(
            lambda s, p: zero_spec(s, tuple(p.shape), mesh),
            specs,
            params_shape,
            is_leaf=lambda x: isinstance(x, P),
        )
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, jax.sharding.NamedSharding(mesh, s)
            ),
            grads,
            zspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
    raise ValueError(f"unknown sync strategy {strategy!r}")


def opt_state_specs(strategy: str, params_shape: Any, mesh: Mesh, cfg=None) -> Any:
    """Sharding specs for master/m/v under the given strategy.

    ``scu`` ZeRO-shards the optimizer state over the data axes; the
    baselines keep it sharded like the params (replicated over data) --
    the paper's 'every contestant keeps its own copy spinning' analogue.
    """
    specs = param_specs(params_shape, mesh, cfg=cfg)
    if strategy == "scu":
        specs = jax.tree.map(
            lambda s, p: zero_spec(s, tuple(p.shape), mesh),
            specs,
            params_shape,
            is_leaf=lambda x: isinstance(x, P),
        )
    return {"master": specs, "m": specs, "v": specs}
