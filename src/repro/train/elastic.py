"""Elastic scaling and straggler mitigation policies.

At thousand-node scale, failures are routine.  The framework's contract:

  1. **Detection** -- the step watchdog (``loop.py``) flags stragglers;
     at the launcher level, a missing heartbeat marks a pod/host dead.
  2. **Re-carve** -- :func:`shrink_mesh` computes the largest healthy mesh
     compatible with the sharding rules (data axis shrinks first -- model
     parallel degree is preserved so every parameter spec stays valid) and
     :func:`rescale_batch` keeps the *global* batch constant by raising
     grad-accumulation, so training dynamics are unchanged.
  3. **Restore** -- checkpoints are topology-independent
     (``checkpoint.restore_checkpoint`` reassembles global arrays and
     re-shards onto the new mesh), and the data pipeline is a pure function
     of ``step`` -- the restarted run is bit-compatible with a never-failed
     run at the same global batch.
  4. **Straggler mitigation without restart** -- the hierarchical `scu`
     sync schedule confines slow-pod effects: intra-pod collectives
     proceed; only the small inter-pod reduction waits (the paper's
     'do not make everyone spin because one PE is late', Sec. 3.1, at pod
     granularity).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple


__all__ = ["HealthState", "shrink_mesh", "rescale_batch", "plan_recovery"]


@dataclasses.dataclass
class HealthState:
    total_devices: int
    failed_devices: List[int]

    @property
    def healthy(self) -> int:
        return self.total_devices - len(self.failed_devices)


def shrink_mesh(
    health: HealthState, model_parallel: int = 16, pod_size: int = 256
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest mesh (pod, data, model) that fits the healthy device count.

    The model axis is preserved (param shardings stay valid); whole
    data-parallel replicas are dropped; pods drop when a pod loses too many
    members to host a single replica.
    """
    assert health.healthy >= model_parallel, "cannot preserve model parallelism"
    replicas = health.healthy // model_parallel
    pods = max(1, health.total_devices // pod_size)
    per_pod_replicas = max(1, replicas // pods)
    if pods > 1:
        return (pods, per_pod_replicas, model_parallel), ("pod", "data", "model")
    return (per_pod_replicas, model_parallel), ("data", "model")


def rescale_batch(
    global_batch: int, old_replicas: int, new_replicas: int, grad_accum: int
) -> Tuple[int, int]:
    """Keep the global batch constant across a re-carve: per-replica batch
    rises via gradient accumulation.  Returns (per_replica_batch, accum)."""
    per_replica = global_batch // new_replicas
    # grow accumulation so the per-microbatch size stays what it was
    old_micro = max(1, global_batch // (old_replicas * grad_accum))
    new_accum = max(1, per_replica // old_micro)
    return per_replica, new_accum


def plan_recovery(
    health: HealthState,
    global_batch: int,
    old_mesh_shape: Tuple[int, ...],
    grad_accum: int = 1,
    model_parallel: int = 16,
) -> dict:
    """Full recovery plan: new mesh + batch plan + restore instructions."""
    new_shape, axes = shrink_mesh(health, model_parallel)
    old_replicas = 1
    for d, a in zip(old_mesh_shape, ("pod", "data", "model")[: len(old_mesh_shape)]):
        if a in ("pod", "data"):
            old_replicas *= d
    new_replicas = 1
    for d, a in zip(new_shape, axes):
        if a in ("pod", "data"):
            new_replicas *= d
    per_replica, accum = rescale_batch(
        global_batch, old_replicas, new_replicas, grad_accum
    )
    return {
        "mesh_shape": new_shape,
        "mesh_axes": axes,
        "per_replica_batch": per_replica,
        "grad_accum": accum,
        "action": "restore latest committed checkpoint onto the new mesh; "
        "the data pipeline replays from the checkpointed step",
    }
