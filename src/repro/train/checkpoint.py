"""Sharded checkpointing with atomic commit and restart/elastic re-carve.

Design (no external deps -- orbax is unavailable by construction):

  * every host saves the process-local shards of every array
    (``.addressable_shards``) into one ``.npz`` per host, with a msgpack-free
    JSON index mapping tree paths -> (global shape, dtype, shard indices);
  * writes go to ``<dir>/step_<n>.tmp_<uuid>/`` and the directory is
    atomically renamed on completion -- a crash mid-save never corrupts the
    latest checkpoint (restart picks the newest *committed* step);
  * restore reassembles global arrays via ``jax.make_array_from_callback``
    against the *current* mesh/sharding -- the checkpoint is
    topology-independent, so a restart may re-carve onto a different mesh
    (elastic downscale after node failure: see ``repro/train/elastic.py``);
  * an async mode snapshots device arrays to host memory synchronously and
    writes to disk on a background thread (training continues).
"""

from __future__ import annotations

import json
import os
import threading
import uuid
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_SEP = "/"

# numpy's format cannot store bf16/f8 natively: view them as uint bits
_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2"}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        bits = np.uint16 if arr.dtype.itemsize == 2 else np.uint8
        return arr.view(bits), name
    return arr, name


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p)
            for p in path
        )
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree: Any) -> Path:
    """Atomic sharded save.  Returns the committed directory."""
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step:09d}.tmp_{uuid.uuid4().hex[:8]}"
    final = base / f"step_{step:09d}"
    tmp.mkdir()

    flat = _flatten_with_paths(tree)
    index: Dict[str, Any] = {"step": step, "arrays": {}}
    payload: Dict[str, np.ndarray] = {}
    for key, leaf in flat.items():
        arr = leaf
        if isinstance(arr, jax.Array):
            shards = arr.addressable_shards
            idx_list = []
            dname = str(arr.dtype)
            for i, sh in enumerate(shards):
                name = f"{key}@@{i}"
                enc, dname = _encode(np.asarray(sh.data))
                payload[name] = enc
                idx_list.append(
                    {"slot": i, "index": _serialize_index(sh.index, arr.shape)}
                )
            index["arrays"][key] = {
                "shape": list(arr.shape),
                "dtype": dname,
                "shards": idx_list,
            }
        else:
            enc, dname = _encode(np.asarray(arr))
            payload[f"{key}@@0"] = enc
            index["arrays"][key] = {
                "shape": list(np.shape(arr)),
                "dtype": dname,
                "shards": [{"slot": 0, "index": None}],
            }
    np.savez(tmp / "host_0.npz", **payload)
    (tmp / "index.json").write_text(json.dumps(index))
    os.replace(tmp, final)  # atomic commit
    return final


def _serialize_index(idx: Tuple[slice, ...], shape) -> list:
    out = []
    for sl, dim in zip(idx, shape):
        out.append([sl.start or 0, sl.stop if sl.stop is not None else dim])
    return out


def latest_step(directory: str) -> Optional[int]:
    base = Path(directory)
    if not base.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in base.iterdir()
        if p.name.startswith("step_") and ".tmp_" not in p.name
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    target: Any,
    shardings: Optional[Any] = None,
) -> Any:
    """Restore into the structure of ``target`` (SDS or arrays), placing
    shards per ``shardings`` (defaults to replicated host arrays).

    Works across mesh changes: data is reassembled globally, then
    re-sharded by the current sharding -- the elastic re-carve path.
    """
    base = Path(directory) / f"step_{step:09d}"
    index = json.loads((base / "index.json").read_text())
    data = np.load(base / "host_0.npz")

    flat_target = _flatten_with_paths(target)
    flat_shardings = _flatten_with_paths(shardings) if shardings is not None else {}

    restored: Dict[str, Any] = {}
    for key, meta in index["arrays"].items():
        shape = tuple(meta["shape"])
        dtype = _np_dtype(meta["dtype"])

        def _decode(piece):
            if meta["dtype"] in _EXOTIC:
                return piece.view(dtype)
            return piece.astype(dtype, copy=False)

        if shape == ():
            full = _decode(data[f"{key}@@0"]).reshape(())
        else:
            full = np.zeros(shape, dtype)
            for sh in meta["shards"]:
                piece = _decode(data[f"{key}@@{sh['slot']}"])
                if sh["index"] is None:
                    full = piece.reshape(shape)
                    break
                slices = tuple(slice(a, b) for a, b in sh["index"])
                full[slices] = piece
        sharding = flat_shardings.get(key)
        if sharding is not None:
            arr = jax.make_array_from_callback(
                shape, sharding, lambda idx, f=full: f[idx]
            )
        else:
            arr = jax.numpy.asarray(full)
        restored[key] = arr

    # rebuild the tree in target order
    leaves, treedef = jax.tree_util.tree_flatten(target)
    keys = list(_flatten_with_paths(target).keys())
    new_leaves = [restored[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class CheckpointManager:
    """Keep-last-k manager with optional async disk writes."""

    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        # snapshot to host memory synchronously (device buffers may be
        # donated/overwritten by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._thread is not None:
            self._thread.join()

        def work():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        base = Path(self.directory)
        steps = sorted(
            p for p in base.iterdir()
            if p.name.startswith("step_") and ".tmp_" not in p.name
        )
        for p in steps[: -self.keep]:
            for f in p.iterdir():
                f.unlink()
            p.rmdir()
