"""Deterministic, sharded, resumable token pipeline.

Two sources:
  * ``SyntheticLM``  -- seeded pseudo-corpus (Zipfian unigram + Markov-ish
    mixing) for tests/examples: a *learnable* distribution so tiny training
    runs show decreasing loss;
  * ``MemmapTokens`` -- flat binary token file (np.memmap), the production
    path: documents are sliced into (seq+1)-length windows.

Determinism & fault tolerance: batches are indexed by ``step`` -- the
pipeline is a pure function ``(seed, step, shard) -> batch``, so a restart
from a checkpoint at step k reproduces exactly the batches the lost run
would have seen (no iterator state to persist), and elastic reshards only
change the ``(shard, n_shards)`` mapping while preserving the global batch
sequence.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["SyntheticLM", "MemmapTokens", "make_batch_fn"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    order: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # a fixed random bigram transition table with strong structure:
        # next-token = f(prev) + small noise -> learnable by tiny models
        self._next = rng.integers(0, self.vocab_size, size=(self.vocab_size,))

    def batch(self, step: int, batch_size: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard
        )
        b = batch_size // n_shards
        toks = np.empty((b, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=b)
        noise = rng.random((b, self.seq_len))
        for t in range(self.seq_len):
            follow = self._next[toks[:, t]]
            rand = rng.integers(0, self.vocab_size, size=b)
            toks[:, t + 1] = np.where(noise[:, t] < 0.9, follow, rand)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class MemmapTokens:
    path: str
    vocab_size: int
    seq_len: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // self.seq_len

    def batch(self, step: int, batch_size: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed * 1_000_003 + step))
        b = batch_size // n_shards
        idx = rng.integers(0, self._n_windows, size=batch_size)[
            shard * b : (shard + 1) * b
        ]
        rows = np.stack(
            [self._data[i * self.seq_len : i * self.seq_len + self.seq_len + 1]
             for i in idx]
        ).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_batch_fn(source, batch_size: int):
    """(step) -> full global batch (host numpy)."""

    def fn(step: int) -> Dict[str, np.ndarray]:
        return source.batch(step, batch_size)

    return fn
