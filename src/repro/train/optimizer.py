"""AdamW (from scratch) with ZeRO sharding and gradient compression.

The optimizer state holds fp32 master weights + first/second moments; model
params are kept in the compute dtype (bf16).  Under the ``scu`` sync
strategy the optimizer state is ZeRO-sharded over the data axes (see
:func:`repro.parallel.sharding.zero_spec`); gradients are reduce-scattered
and updated shard-locally, and fresh bf16 params are all-gathered -- the
overlap-friendly schedule.

Gradient compression (beyond-paper §Perf lever): ``bf16`` keeps gradients in
bf16 on the wire (default -- free, since params are bf16); ``int8`` applies
per-tensor scale quantization with error feedback before the gradient
collective, quartering the collective roofline term at the cost of an extra
fp32 residual state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "compress_decompress"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    compression: str = "none"  # none | int8


def init_opt_state(params: Any) -> Dict[str, Any]:
    """fp32 master + moments (+ int8 error-feedback residual when enabled)."""
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"master": master, "m": zeros, "v": jax.tree.map(jnp.copy, zeros)}


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(
    g: jnp.ndarray, residual: Optional[jnp.ndarray]
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """int8 per-tensor scale quantization with error feedback.

    Returns (dequantized gradient to feed the collective path, new residual).
    """
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_residual = gf - deq if residual is not None else None
    return deq.astype(g.dtype), new_residual


def _lr_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def adamw_update(
    cfg: OptConfig,
    grads: Any,
    opt_state: Dict[str, Any],
    step: jnp.ndarray,
    param_dtype=jnp.bfloat16,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (new bf16 params, new opt state, metrics)."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    # global-norm clip
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)) + 1e-30
    )
    clip = jnp.minimum(1.0, cfg.grad_clip / gnorm)
    g32 = jax.tree.map(lambda g: g * clip, g32)

    lr = _lr_schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(p, m, v, g):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return p, m, v

    flat_p, treedef = jax.tree.flatten(opt_state["master"])
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_g = jax.tree.leaves(g32)
    new_p, new_m, new_v = [], [], []
    for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g):
        p2, m2, v2 = upd(p, m, v, g)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    master = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "master": master,
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
    }
    params = jax.tree.map(lambda p: p.astype(param_dtype), master)
    return params, new_state, {"grad_norm": gnorm, "lr": lr}
