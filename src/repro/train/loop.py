"""Training driver: init -> (restore?) -> step loop -> checkpoint/metrics.

Fault-tolerance contract (DESIGN.md Sec. 5):
  * checkpoint every ``ckpt_every`` steps (atomic, async, keep-last-k);
  * on start, resume from the latest committed step if one exists;
  * the data pipeline is a pure function of ``step`` -- restart reproduces
    the exact batch sequence;
  * straggler / failure handling wraps the step in a watchdog that raises
    after ``step_timeout_s`` so the supervisor (launch script) can re-carve
    the mesh (see ``repro/train/elastic.py``) and restart from the last
    checkpoint.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import init_lm
from repro.train.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.train.optimizer import init_opt_state
from repro.train.step import TrainConfig, make_train_step

__all__ = ["TrainerConfig", "train"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    step_timeout_s: float = 3600.0


def train(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    trainer: TrainerConfig,
    mesh,
    batch_fn: Callable[[int], Dict[str, np.ndarray]],
    on_metrics: Optional[Callable[[int, Dict[str, float]], None]] = None,
):
    """Run the training loop; returns (params, opt_state, metrics history)."""
    step_fn, (in_sh, batch_sh_fn), out_sh, params_sds = make_train_step(
        cfg, tcfg, mesh
    )
    params_sh, opt_sh, step_sh = in_sh[0], in_sh[1], in_sh[2]

    with mesh:
        start = 0
        if trainer.ckpt_dir and (ls := latest_step(trainer.ckpt_dir)) is not None:
            print(f"[train] resuming from step {ls}")
            params0 = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), params_sds
            )
            state_target = {
                "params": params0,
                "opt": init_opt_state(params0),
                "step": jnp.zeros((), jnp.int32),
            }
            restored = restore_checkpoint(
                trainer.ckpt_dir,
                ls,
                state_target,
                {"params": params_sh, "opt": opt_sh, "step": step_sh},
            )
            params, opt_state = restored["params"], restored["opt"]
            start = int(restored["step"])
        else:
            key = jax.random.PRNGKey(trainer.seed)
            params = jax.jit(
                lambda k: init_lm(k, cfg, jnp.dtype(tcfg.param_dtype)),
                out_shardings=params_sh,
            )(key)
            opt_state = jax.jit(init_opt_state, out_shardings=opt_sh)(params)

        jitted = jax.jit(
            step_fn,
            in_shardings=(params_sh, opt_sh, step_sh, None),
            out_shardings=out_sh,
            donate_argnums=(0, 1),
        )

        ckpt = (
            CheckpointManager(trainer.ckpt_dir) if trainer.ckpt_dir else None
        )
        history = []
        step = jnp.asarray(start, jnp.int32)
        for i in range(start, trainer.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in batch_fn(i).items()}
            params, opt_state, step, metrics = jitted(params, opt_state, step, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_time_s"] = time.time() - t0
            if metrics["step_time_s"] > trainer.step_timeout_s:
                raise TimeoutError(
                    f"step {i} exceeded {trainer.step_timeout_s}s -- straggler; "
                    "supervisor should re-carve (elastic.py) and restart"
                )
            history.append(metrics)
            if on_metrics:
                on_metrics(i, metrics)
            if trainer.log_every and i % trainer.log_every == 0:
                print(
                    f"[train] step {i:5d} loss {metrics['loss']:.4f} "
                    f"gnorm {metrics['grad_norm']:.3f} "
                    f"({metrics['step_time_s']*1e3:.0f} ms)"
                )
            if ckpt and (i + 1) % trainer.ckpt_every == 0:
                ckpt.save(i + 1, {"params": params, "opt": opt_state, "step": step})
        if ckpt:
            ckpt.wait()
        return params, opt_state, history
