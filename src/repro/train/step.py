"""Training step factory: loss -> grads -> SyncEngine -> AdamW.

``make_train_step`` builds the jit-able step for a (model config, train
config, mesh) triple, together with the in/out shardings needed for
``jax.jit(...).lower()`` -- used by both the real trainer and the dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, validate_sync_policy
from repro.models.lm import init_lm, lm_loss
from repro.parallel.sharding import batch_spec, param_specs
from repro.sync import SyncPolicy, get_policy
from repro.train.optimizer import OptConfig, adamw_update, compress_decompress

__all__ = ["TrainConfig", "make_train_step", "train_state_specs", "abstract_params"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    sync_strategy: str = "scu"  # any registered repro.sync policy name
    remat_policy: str = "full"
    param_dtype: str = "bfloat16"
    sequence_parallel: bool = True  # shard the residual carry over "model"
    grad_accum: int = 1  # microbatches per step (activation-memory knob)

    def __post_init__(self):
        # canonicalize + fail fast on unknown policies (the error names the
        # registered ones) instead of erroring deep inside a jitted step
        object.__setattr__(
            self, "sync_strategy", validate_sync_policy(self.sync_strategy)
        )

    @property
    def sync_policy(self) -> SyncPolicy:
        return get_policy(self.sync_strategy)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree of the model parameters (no allocation)."""
    sds = jax.eval_shape(
        functools.partial(init_lm, cfg=cfg, dtype=dtype), jax.random.PRNGKey(0)
    )
    return sds


def train_state_specs(
    cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh
) -> Dict[str, Any]:
    """PartitionSpec trees for (params, opt_state, step)."""
    params_sds = abstract_params(cfg, jnp.dtype(tcfg.param_dtype))
    pspecs = param_specs(params_sds, mesh, cfg=cfg)
    ospecs = tcfg.sync_policy.opt_state_specs(params_sds, mesh, cfg=cfg)
    return {"params": pspecs, "opt": ospecs, "step": P()}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh):
    """Returns (step_fn, in_shardings, out_shardings, abstract_state).

    ``step_fn(params, opt_state, step, batch) -> (params, opt_state, step,
    metrics)``.  All sharding is communicated via in/out shardings; the
    gradient path is shaped by the configured ``repro.sync`` policy.
    """
    policy = tcfg.sync_policy
    param_dtype = jnp.dtype(tcfg.param_dtype)
    params_sds = abstract_params(cfg, param_dtype)
    specs = train_state_specs(cfg, tcfg, mesh)

    def to_shardings(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    params_sh = to_shardings(specs["params"])
    opt_sh = to_shardings(specs["opt"])
    step_sh = NamedSharding(mesh, P())
    bspec = NamedSharding(mesh, batch_spec(mesh, extra_dims=1))
    bspec3 = NamedSharding(mesh, batch_spec(mesh, extra_dims=2))

    def batch_shardings(batch_sds: Dict[str, Any]):
        return {
            k: (bspec3 if v.ndim == 3 else bspec) for k, v in batch_sds.items()
        }

    use_int8 = tcfg.opt.compression == "int8"

    residual_sh = (
        NamedSharding(mesh, P(tuple(a for a in mesh.axis_names if a in ("pod", "data")), "model", None))
        if (tcfg.sequence_parallel and mesh.shape.get("model", 1) > 1)
        else None
    )

    embed_grad_sh = params_sh["embed"]["table"]
    logits_sh = NamedSharding(
        mesh,
        P(
            tuple(a for a in mesh.axis_names if a in ("pod", "data")),
            None,
            "model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0 else None,
        ),
    )

    accum = max(1, tcfg.grad_accum)

    def loss_fn(p, b):
        return lm_loss(
            p, cfg, b, remat_policy=tcfg.remat_policy,
            residual_spec=residual_sh, embed_grad_spec=embed_grad_sh,
            logits_spec=logits_sh,
        )

    def step_fn(params, opt_state, step, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = policy.shape_gradients(grads, params_sds, mesh, cfg=cfg)
        else:
            # gradient accumulation: scan over microbatches; the f32
            # accumulators live on the ZeRO/FSDP shards (constrained per
            # microbatch), so they cost params/world_size, not params.
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )

            def mb(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                g = policy.shape_gradients(g, params_sds, mesh, cfg=cfg)
                gsum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p_: jnp.zeros(p_.shape, jnp.float32), params
            )
            g0 = policy.shape_gradients(g0, params_sds, mesh, cfg=cfg)
            (gsum, lsum), _ = jax.lax.scan(
                mb, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum

        if use_int8:
            grads = jax.tree.map(
                lambda g: compress_decompress(g, None)[0], grads
            )

        new_params, new_opt, metrics = adamw_update(
            tcfg.opt, grads, opt_state, step, param_dtype
        )
        # params return to their TP sharding (all-gather under ZeRO)
        new_params = jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(p, s),
            new_params,
            params_sh,
        )
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, step + 1, metrics

    in_shardings = (params_sh, opt_sh, step_sh, None)  # batch filled at lower
    out_shardings = (params_sh, opt_sh, step_sh, None)
    return step_fn, (in_shardings, batch_shardings), out_shardings, params_sds
