"""jax version-portability shims.

The repo targets current jax APIs (``jax.shard_map``, ``jax.sharding.
AxisType``, ``pltpu.CompilerParams``); the pinned container jax may predate
them.  Every version-sensitive construct is funneled through this module so
the rest of the code reads as if it were written against one jax.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import Mesh

__all__ = ["CompilerParams", "axis_size", "make_axis_mesh", "shard_map"]


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis from inside shard_map/pmap.

    ``jax.lax.axis_size`` where it exists; otherwise ``psum(1, axis)``,
    which constant-folds to a concrete int under a bound axis.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis)

# pltpu.TPUCompilerParams was renamed to pltpu.CompilerParams in newer jax.
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def make_axis_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map``, falling back to the experimental spelling."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
