"""jax availability + version-portability shims.

The repo targets current jax APIs (``jax.shard_map``, ``jax.sharding.
AxisType``, ``pltpu.CompilerParams``); the pinned container jax may predate
them.  Every version-sensitive construct is funneled through this module so
the rest of the code reads as if it were written against one jax.

The simulator core and the compiled trace path are pure numpy and must run
where jax is absent (or deliberately disabled with ``REPRO_NO_JAX=1``, the
CI fast lane): :data:`HAS_JAX` is the single gate, and the shims below raise
a clear ImportError only when actually called without jax.
"""

from __future__ import annotations

import os

__all__ = [
    "HAS_JAX",
    "CompilerParams",
    "axis_size",
    "make_axis_mesh",
    "require_jax",
    "shard_map",
]

if os.environ.get("REPRO_NO_JAX"):
    jax = None
else:
    try:
        import jax
    except ImportError:  # pragma: no cover - container always ships jax
        jax = None

HAS_JAX = jax is not None


def require_jax(feature: str = "this feature"):
    """Return the jax module or raise a actionable ImportError."""
    if jax is None:
        raise ImportError(
            f"{feature} needs jax, which is unavailable "
            "(REPRO_NO_JAX set or jax not installed); the numpy simulator "
            "and compiled-trace paths work without it"
        )
    return jax


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis from inside shard_map/pmap.

    ``jax.lax.axis_size`` where it exists; otherwise ``psum(1, axis)``,
    which constant-folds to a concrete int under a bound axis.
    """
    j = require_jax("axis_size")
    fn = getattr(j.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return j.lax.psum(1, axis)


if HAS_JAX:
    from jax.experimental.pallas import tpu as pltpu

    # pltpu.TPUCompilerParams was renamed to pltpu.CompilerParams in newer jax.
    CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
else:
    CompilerParams = None


def make_axis_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    j = require_jax("make_axis_mesh")
    axis_type = getattr(j.sharding, "AxisType", None)
    if axis_type is not None:
        return j.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return j.make_mesh(shape, axes)


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map``, falling back to the experimental spelling."""
    j = require_jax("shard_map")
    sm = getattr(j, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
