"""Sharding rules: logical parameter roles -> mesh PartitionSpecs.

Parallelism layout (DESIGN.md Sec. 5):

  * ``("pod", "data")`` -- data parallelism (+ ZeRO for optimizer state),
  * ``"model"``         -- tensor parallelism: attention heads, MLP hidden,
                           MoE experts (EP), vocab; decode shards the KV
                           cache *sequence* over "model" (SP-decode).

Specs are derived from the parameter tree by path+shape rules (the tree
structure is the one built by ``repro.models.lm.init_lm``); any axis whose
size does not divide the mesh axis falls back to replication -- sharding
must never be silently wrong.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "dp_axes",
    "model_axis_size",
    "param_specs",
    "param_shardings",
    "batch_spec",
    "zero_spec",
    "tree_size_bytes",
]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


# parameter-name -> (shard output dim over model?) rules; see module doc.
_COL_SHARDED = {"wq", "wk", "wv", "gate", "up", "in_z", "in_x", "w_uk", "w_uv"}
_ROW_SHARDED = {"wo", "down", "out_proj"}
_REPLICATED = {"router", "w_dkv", "w_kr", "in_B", "in_C", "in_dt"}
_VOCAB_TABLES = {"embed", "lm_head"}
# head-aligned sharding guards: sharding a head-structured projection over
# "model" is only profitable when the head count divides the axis --
# otherwise XLA factorizes the sharding across the head boundary and falls
# back to involuntary rematerialization at the attention reshape.
_Q_HEAD_PARAMS = {"wq", "wo", "w_uk", "w_uv"}
_KV_HEAD_PARAMS = {"wk", "wv"}


def _spec_for(
    path: Tuple[str, ...], shape: Tuple[int, ...], model: int, cfg=None
) -> P:
    """Sharding spec for one parameter, ignoring any stacked layer axis."""
    # innermost named ancestor that identifies the role
    names = set(path)
    leaf = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    if cfg is not None and model > 1:
        role = parent if parent in (_Q_HEAD_PARAMS | _KV_HEAD_PARAMS) else (
            leaf if leaf in (_Q_HEAD_PARAMS | _KV_HEAD_PARAMS) else None
        )
        if role in _Q_HEAD_PARAMS and cfg.n_heads % model != 0:
            return P(*([None] * len(shape)))
        if role in _KV_HEAD_PARAMS and cfg.n_kv_heads % model != 0:
            return P(*([None] * len(shape)))

    if parent in _VOCAB_TABLES and leaf == "table":
        return P("model", None) if _div(shape[0], model) else P(None, None)

    if parent in _REPLICATED or leaf in _REPLICATED:
        return P(*([None] * len(shape)))

    # MoE expert stacks: (E, d_in, d_out) -> experts over model (EP)
    if parent in ("gate", "up", "down") and len(shape) == 3 or (
        leaf in ("gate", "up", "down") and len(shape) == 3
    ):
        return (
            P("model", None, None) if _div(shape[0], model) else P(None, None, None)
        )

    if (parent in _COL_SHARDED or leaf in _COL_SHARDED) and len(shape) == 2:
        return P(None, "model") if _div(shape[1], model) else P(None, None)
    if (parent in _COL_SHARDED) and len(shape) == 1:  # bias of a col-sharded proj
        return P("model") if _div(shape[0], model) else P(None)

    if (parent in _ROW_SHARDED or leaf in _ROW_SHARDED) and len(shape) == 2:
        return P("model", None) if _div(shape[0], model) else P(None, None)
    if parent in _ROW_SHARDED and len(shape) == 1:
        return P(None)

    if leaf in ("conv_x",):  # (d_conv, d_inner): channel = model axis
        return P(None, "model") if _div(shape[1], model) else P(None, None)
    if leaf in ("conv_bx", "norm_scale"):
        return P("model") if _div(shape[0], model) else P(None)
    # everything else (norms, scalars, conv_B/C, A_log, D, dt_bias): replicate
    return P(*([None] * len(shape)))


def param_specs(params_tree: Any, mesh: Mesh, fsdp: bool = True, cfg=None) -> Any:
    """PartitionSpec tree matching ``params_tree`` (arrays or SDS leaves).

    With ``fsdp=True`` (default) every parameter additionally shards its
    first yet-unsharded, divisible axis over the data axes (weight-sharded
    data parallelism): mandatory for the 100B-class archs to fit HBM, and
    XLA SPMD turns the per-layer weight gathers into scan-local all-gathers
    that the latency-hiding scheduler overlaps with compute.
    """
    model = model_axis_size(mesh)

    def one(path, leaf):
        names = tuple(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        )
        shape = tuple(leaf.shape)
        stacked = "blocks" in names  # scan-stacked: leading layer axis
        if stacked:
            inner = _spec_for(names, shape[1:], model, cfg)
            if fsdp and len(shape) >= 3:
                # never FSDP-shard the stacked layer axis (scan slices it)
                inner = zero_spec(inner, shape[1:], mesh)
            return P(None, *inner)
        spec = _spec_for(names, shape, model, cfg)
        if fsdp and len(shape) >= 2:
            spec = zero_spec(spec, shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_tree)


def param_shardings(params_tree: Any, mesh: Mesh, cfg=None) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_tree, mesh, cfg=cfg),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """Batch sharded over all data axes; remaining dims replicated."""
    return P(dp_axes(mesh), *([None] * extra_dims))


def zero_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Upgrade a param spec with ZeRO sharding of the optimizer state:
    shard the first yet-unsharded axis divisible by the DP world size over
    the data axes.  Falls back to the original spec."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if dp_size <= 1:
        return spec
    # already ZeRO/FSDP-sharded somewhere: a mesh axis may appear only once
    used = set()
    for e in spec:
        for n in e if isinstance(e, tuple) else ((e,) if e else ()):
            used.add(n)
    if used & set(dp):
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (s, dim) in enumerate(zip(entries, shape)):
        if s is None and _div(dim, dp_size):
            entries[i] = dp
            return P(*entries)
    return spec


def tree_size_bytes(tree: Any) -> int:
    return sum(
        leaf.size * jnp.dtype(leaf.dtype).itemsize for leaf in jax.tree.leaves(tree)
    )
