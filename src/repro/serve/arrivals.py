"""Seedable arrival traces for the sweep service (deterministic, offline).

Arrival times are **scheduler rounds** of the slot fleet, not wall-clock or
simulated cycles: one round is one call to ``SlotFleet.advance()``, the
machine-independent time axis every latency number in ``fleet_service`` and
``benchmarks/traffic.py`` is quoted on.  Traces are non-decreasing integer
sequences; two jobs may share a round (a burst lands at once).

Both generators are pure functions of their arguments -- same seed, same
trace, on any machine -- so benchmark artifacts and tests stay
reproducible without recording traces on disk.
"""

from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["poisson_trace", "bursty_trace"]


def poisson_trace(rate: float, n_jobs: int, seed: int) -> List[int]:
    """Poisson arrivals: i.i.d. exponential gaps with mean ``1/rate`` rounds.

    ``rate`` is jobs per scheduler round (e.g. 0.02 = one job every 50
    rounds on average).  Gaps are floored, so high rates degenerate into
    same-round batches -- exactly the stress the service should absorb.
    """
    if rate <= 0:
        raise ValueError(f"poisson_trace: rate must be > 0, got {rate}")
    if n_jobs < 0:
        raise ValueError(f"poisson_trace: n_jobs must be >= 0, got {n_jobs}")
    rng = np.random.default_rng(seed)
    gaps = np.floor(rng.exponential(1.0 / rate, size=n_jobs)).astype(np.int64)
    return np.cumsum(gaps).tolist()


def bursty_trace(
    n_bursts: int,
    burst_size: int,
    gap_rounds: int,
    seed: int,
    jitter: int = 0,
) -> List[int]:
    """Bursty arrivals: ``n_bursts`` bursts of ``burst_size`` jobs, bursts
    ``gap_rounds`` apart, each job's arrival jittered by up to ``jitter``
    rounds (uniform, per job).

    This is the adversarial pattern for fixed-batch dispatch: a burst wider
    than the fleet forces queueing, and the long inter-burst gap is where a
    drain-the-fleet baseline leaves lanes idle while stragglers finish.
    """
    if n_bursts < 0 or burst_size < 0:
        raise ValueError("bursty_trace: n_bursts/burst_size must be >= 0")
    if gap_rounds < 0 or jitter < 0:
        raise ValueError("bursty_trace: gap_rounds/jitter must be >= 0")
    rng = np.random.default_rng(seed)
    times: List[int] = []
    for b in range(n_bursts):
        base = b * gap_rounds
        for _ in range(burst_size):
            j = int(rng.integers(0, jitter + 1)) if jitter else 0
            times.append(base + j)
    times.sort()
    return times
