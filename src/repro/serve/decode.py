"""Serving: prefill + single-token decode with distributed KV/SSM caches.

Cache layout mirrors the scan-stacked parameter layout: one entry per group
position, stacked over scan groups (leading ``G`` axis), plus unstacked
prelude entries.  Cache kinds:

  * GQA attention:  ``{"k","v"}: (G, b, S, kv_heads, head_dim)``
  * MLA:            ``{"c_kv": (G, b, S, kv_lora), "k_r": (G, b, S, rope)}``
                    -- the compressed-latent cache (the MLA memory win);
                    decode uses the *absorbed* formulation (scores against
                    c_kv directly, W_uk folded into the query).
  * SSD (mamba2):   ``{"ssm": (G, b, H, P, N), "conv": (G, b, w, conv_dim)}``
                    -- O(1)-size state, no sequence axis at all.

Sequence-parallel decode: the KV cache's sequence axis is sharded over the
``model`` mesh axis.  The decode attention is written so the SPMD
partitioner keeps S sharded: per-shard partial scores -> global max/sum
(the log-sum-exp combine) -> per-shard weighted values -> all-reduce.  This
is distributed flash-decode expressed in pure jnp + sharding constraints.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.blocks import group_pattern, prelude_layers
from repro.models.layers.attention import attention_qkv
from repro.models.layers.basics import apply_norm, dense, embed, mlp_apply, unembed
from repro.models.layers.basics import apply_rope, rope_frequencies
from repro.models.layers.moe import moe_apply
from repro.models.layers.ssm import ssm_decode_step, ssm_state_shapes
from repro.models.lm import prelude_layers as _pre  # noqa: F401 (re-export safety)
from repro.parallel.sharding import dp_axes

__all__ = [
    "cache_shapes",
    "cache_specs",
    "init_cache",
    "make_serve_step",
    "make_prefill",
]


# ---------------------------------------------------------------------------
# Cache structure
# ---------------------------------------------------------------------------


def _layer_cache_shape(
    cfg: ModelConfig, kind: str, batch: int, max_seq: int
) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """{name: (shape, dtype)} for one (unstacked) layer."""
    dt = jnp.dtype(cfg.dtype)
    if kind == "ssm":
        sh = ssm_state_shapes(cfg, batch)
        return {"ssm": (sh["ssm"], jnp.float32), "conv": (sh["conv"], dt)}
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": ((batch, max_seq, m.kv_lora_rank), dt),
            "k_r": ((batch, max_seq, m.qk_rope_dim), dt),
        }
    hd = cfg.resolved_head_dim
    return {
        "k": ((batch, max_seq, cfg.n_kv_heads, hd), dt),
        "v": ((batch, max_seq, cfg.n_kv_heads, hd), dt),
    }


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the whole cache."""
    pre = prelude_layers(cfg)
    pattern = group_pattern(cfg)
    n_groups = (cfg.n_layers - pre) // cfg.block_group

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    out: Dict[str, Any] = {}
    for i in range(pre):
        kind = cfg.layer_kind(i)
        out[f"prelude_{i}"] = {
            k: sds(sh, dt) for k, (sh, dt) in _layer_cache_shape(cfg, kind, batch, max_seq).items()
        }
    blocks = {}
    for p_idx, (kind, _) in enumerate(pattern):
        blocks[f"pos_{p_idx}"] = {
            k: sds((n_groups,) + sh, dt)
            for k, (sh, dt) in _layer_cache_shape(cfg, kind, batch, max_seq).items()
        }
    out["blocks"] = blocks
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int) -> Any:
    """PartitionSpec tree: batch over data axes; seq (or heads) over model.

    Any non-divisible axis falls back to replication (e.g. ``long_500k``
    decodes a single sequence: batch cannot shard over data)."""
    dp_all = dp_axes(mesh)
    dp_size = 1
    for a in dp_all:
        dp_size *= mesh.shape[a]
    dp = dp_all if (batch % max(dp_size, 1) == 0) else None
    model = mesh.shape.get("model", 1)

    def spec_for(path_key: str, shape: Tuple[int, ...], stacked: bool) -> P:
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape
        if path_key in ("k", "v"):  # (b, S, kvh, hd): seq over model
            s_ok = body[1] % model == 0
            return P(*lead, dp, "model" if s_ok else None, None, None)
        if path_key in ("c_kv", "k_r"):  # (b, S, r)
            s_ok = body[1] % model == 0
            return P(*lead, dp, "model" if s_ok else None, None)
        if path_key == "ssm":  # (b, H, P, N): heads over model
            h_ok = body[1] % model == 0
            return P(*lead, dp, "model" if h_ok else None, None, None)
        if path_key == "conv":  # (b, w, conv_dim)
            return P(*lead, dp, None, None)
        raise KeyError(path_key)

    shapes = cache_shapes(cfg, batch, max_seq)

    def walk(tree, stacked):
        return {
            k: (
                walk(v, stacked)
                if isinstance(v, dict)
                else spec_for(k, tuple(v.shape), stacked)
            )
            for k, v in tree.items()
        }

    out = {}
    for k, v in shapes.items():
        out[k] = walk(v, stacked=(k == "blocks"))
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Any:
    """Concrete zero-filled cache (CPU tests / real serving)."""
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes(cfg, batch, max_seq)
    )


# ---------------------------------------------------------------------------
# Decode-attention cores
# ---------------------------------------------------------------------------


def _gqa_decode(p, cfg: ModelConfig, x, cache, position):
    """x: (b,1,d); cache k/v: (b,S,kvh,hd); position: (b,) int32."""
    b = x.shape[0]
    S = cache["k"].shape[1]
    q, k_new, v_new = attention_qkv(p, cfg, x, positions=position[:, None])
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, position].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[bidx, position].set(v_new[:, 0].astype(cache["v"].dtype))

    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)  # (b, kvh, g, hd) -- squeeze the seq dim
    # partial scores over the (possibly model-sharded) cache sequence
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k, preferred_element_type=jnp.float32
    ) * (hd**-0.5)
    mask = jnp.arange(S)[None, :] <= position[:, None]  # (b, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    # log-sum-exp combine: XLA lowers the sharded-S reductions to the
    # distributed max/sum (flash-decode) pattern
    a = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", a.astype(v.dtype), v)
    out = out.reshape(b, 1, h * hd)
    return dense(p["wo"], out), {"k": k, "v": v}


def _mla_decode(p, cfg: ModelConfig, x, cache, position):
    """Absorbed MLA decode: scores directly against the compressed latents."""
    m = cfg.mla
    b = x.shape[0]
    S = cache["c_kv"].shape[1]
    h = cfg.n_heads

    from repro.models.layers.attention import mla_latents

    c_new, kr_new = mla_latents(p, cfg, x, position[:, None])  # (b,1,r), (b,1,rope)
    bidx = jnp.arange(b)
    c_kv = cache["c_kv"].at[bidx, position].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_r = cache["k_r"].at[bidx, position].set(kr_new[:, 0].astype(cache["k_r"].dtype))

    q = dense(p["wq"], x).reshape(b, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    rot, inv = rope_frequencies(m.qk_rope_dim, 1.0, cfg.rope_theta)
    q_rope = apply_rope(q_rope[:, None], position[:, None], rot, inv)[:, 0]

    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk.astype(q.dtype))
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_lat, c_kv, preferred_element_type=jnp.float32)
        + jnp.einsum("bhp,bsp->bhs", q_rope, k_r, preferred_element_type=jnp.float32)
    ) * scale
    mask = jnp.arange(S)[None, :] <= position[:, None]
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    a = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", a.astype(c_kv.dtype), c_kv)
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    val = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(ctx.dtype))
    out = val.reshape(b, 1, h * m.v_head_dim)
    return dense(p["wo"], out), {"c_kv": c_kv, "k_r": k_r}


def _ffn_decode(p, cfg: ModelConfig, is_moe: bool, x):
    if is_moe:
        return moe_apply(p, cfg, x)
    return mlp_apply(p, x, cfg.act)


def _block_decode(p, cfg: ModelConfig, kind: str, is_moe: bool, x, cache, position):
    has_ffn = "ffn" in p
    if cfg.parallel_block:
        h = apply_norm(p["norm1"], x, cfg.norm)
        if kind == "attn":
            mix, cache = (
                _mla_decode(p["mixer"], cfg, h, cache, position)
                if cfg.mla is not None
                else _gqa_decode(p["mixer"], cfg, h, cache, position)
            )
        else:
            mix, cache = ssm_decode_step(p["mixer"], cfg, h, cache)
        out = x + mix
        if has_ffn:
            out = out + _ffn_decode(p["ffn"], cfg, is_moe, h)
        return out, cache
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == "attn":
        mix, cache = (
            _mla_decode(p["mixer"], cfg, h, cache, position)
            if cfg.mla is not None
            else _gqa_decode(p["mixer"], cfg, h, cache, position)
        )
    else:
        mix, cache = ssm_decode_step(p["mixer"], cfg, h, cache)
    x = x + mix
    if has_ffn:
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + _ffn_decode(p["ffn"], cfg, is_moe, h)
    return x, cache


# ---------------------------------------------------------------------------
# serve_step / prefill factories
# ---------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int):
    """Returns (serve_fn, in_shardings, out_shardings).

    ``serve_fn(params, cache, tokens, position) -> (next_tokens, logits_f32
    stats, cache)``: one decode step for the whole batch.
    """
    from repro.parallel.sharding import batch_spec, param_shardings
    from repro.train.step import abstract_params

    pattern = group_pattern(cfg)
    pre = prelude_layers(cfg)
    dtype = jnp.dtype(cfg.dtype)

    def serve_fn(params, cache, tokens, position):
        x = embed(params["embed"], tokens, dtype)  # (b, 1, d)
        if not cfg.use_rope:
            d = cfg.d_model
            inv = 1.0 / (10_000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
            ang = position[:, None].astype(jnp.float32) * inv
            pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
            x = x + pos_emb.astype(dtype)[:, None, :]

        new_cache: Dict[str, Any] = {}
        for i in range(pre):
            x, new_cache[f"prelude_{i}"] = _block_decode(
                params[f"prelude_{i}"],
                cfg,
                cfg.layer_kind(i),
                cfg.layer_is_moe(i),
                x,
                cache[f"prelude_{i}"],
                position,
            )

        def group_body(x, xs):
            gparams, gcache = xs
            outc = {}
            for p_idx, (kind, is_moe) in enumerate(pattern):
                x, outc[f"pos_{p_idx}"] = _block_decode(
                    gparams[f"pos_{p_idx}"],
                    cfg,
                    kind,
                    is_moe,
                    x,
                    gcache[f"pos_{p_idx}"],
                    position,
                )
            return x, outc

        x, blocks_cache = jax.lax.scan(
            group_body, x, (params["blocks"], cache["blocks"])
        )
        new_cache["blocks"] = blocks_cache

        x = apply_norm(params["final_norm"], x, cfg.norm)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = unembed(head, x[:, 0, :]).astype(jnp.float32)  # (b, vocab)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_cache

    params_sds = abstract_params(cfg, dtype)
    params_sh = param_shardings(params_sds, mesh, cfg=cfg)
    cspecs = cache_specs(cfg, mesh, batch, max_seq)
    cache_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cspecs, is_leaf=lambda x: isinstance(x, P)
    )
    dp_all = dp_axes(mesh)
    dp_size = 1
    for a in dp_all:
        dp_size *= mesh.shape[a]
    bspec = (dp_all,) if batch % max(dp_size, 1) == 0 else (None,)
    tok_sh = NamedSharding(mesh, P(*bspec, None))
    pos_sh = NamedSharding(mesh, P(*bspec))
    logits_sh = NamedSharding(
        mesh,
        P(*bspec, "model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0 else None),
    )
    in_sh = (params_sh, cache_sh, tok_sh, pos_sh)
    out_sh = (pos_sh, logits_sh, cache_sh)
    return serve_fn, in_sh, out_sh, params_sds


def make_prefill(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int):
    """Prefill: full forward that also produces the filled cache.

    ``prefill_fn(params, batch_inputs) -> (last_logits, cache)``.
    """
    from repro.models.blocks import block_apply
    from repro.models.layers.attention import mla_latents
    from repro.parallel.sharding import batch_spec, param_shardings
    from repro.train.step import abstract_params
    from repro.models.layers.ssm import ssm_apply  # noqa: F401

    pattern = group_pattern(cfg)
    pre = prelude_layers(cfg)
    dtype = jnp.dtype(cfg.dtype)
    dp = dp_axes(mesh)
    residual_sh = (
        NamedSharding(mesh, P(dp, "model", None))
        if mesh.shape.get("model", 1) > 1
        else None
    )

    def layer_with_cache(p, kind, is_moe, x, positions):
        """block_apply + cache extraction for one layer."""
        h_in = apply_norm(p["norm1"], x, cfg.norm)
        cache: Dict[str, jnp.ndarray] = {}
        if kind == "attn":
            if cfg.mla is not None:
                c_kv, k_r = mla_latents(p["mixer"], cfg, h_in, positions)
                cache = {"c_kv": c_kv.astype(dtype), "k_r": k_r.astype(dtype)}
            else:
                q, k, v = attention_qkv(p["mixer"], cfg, h_in, positions)
                cache = {"k": k.astype(dtype), "v": v.astype(dtype)}
        else:
            # SSD: run the chunked scan and keep the final state
            from repro.models.layers.ssm import (
                _causal_conv,  # type: ignore[attr-defined]
                _dims,
                _project,
                ssd_chunked,
            )

            s_cfg = cfg.ssm
            b, s, _ = h_in.shape
            d_inner, n_heads, conv_dim, g, n = _dims(cfg)
            z, xs, B, C, dt = _project(p["mixer"], cfg, h_in)
            conv_tail = jnp.concatenate([xs, B, C], axis=-1)[:, -(s_cfg.d_conv - 1) :, :]
            xs = _causal_conv(xs, p["mixer"]["conv_x"].astype(xs.dtype), p["mixer"]["conv_bx"])
            B = _causal_conv(B, p["mixer"]["conv_B"].astype(B.dtype), p["mixer"]["conv_bB"])
            C = _causal_conv(C, p["mixer"]["conv_C"].astype(C.dtype), p["mixer"]["conv_bC"])
            xs = xs.reshape(b, s, n_heads, s_cfg.head_dim)
            B = B.reshape(b, s, g, n)
            C = C.reshape(b, s, g, n)
            dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["mixer"]["dt_bias"])
            A = -jnp.exp(p["mixer"]["A_log"])
            _, final_state = ssd_chunked(xs, dtv, A, B, C, chunk=min(s_cfg.chunk, s))
            cache = {"ssm": final_state, "conv": conv_tail.astype(dtype)}
        # the actual layer output (recomputes the mixer -- clarity over
        # cleverness here; XLA CSEs the shared projections)
        x = block_apply(p, cfg, x, kind, is_moe, positions)
        return x, cache

    def prefill_fn(params, inputs):
        if cfg.frontend is not None:
            x = inputs["embeddings"].astype(dtype)
        else:
            x = embed(params["embed"], inputs["tokens"], dtype)
        b, s, _ = x.shape
        positions = jnp.arange(s)
        if not cfg.use_rope:
            d = cfg.d_model
            inv = 1.0 / (10_000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
            ang = positions[:, None].astype(jnp.float32) * inv
            pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
            x = x + pos_emb.astype(dtype)[None]

        def constrain(v):
            if residual_sh is not None and v.shape[1] % mesh.shape.get("model", 1) == 0:
                return jax.lax.with_sharding_constraint(v, residual_sh)
            return v

        x = constrain(x)
        cache: Dict[str, Any] = {}
        for i in range(pre):
            x, cache[f"prelude_{i}"] = layer_with_cache(
                params[f"prelude_{i}"], cfg.layer_kind(i), cfg.layer_is_moe(i), x, positions
            )
            x = constrain(x)

        def group_body(x, gparams):
            outc = {}
            for p_idx, (kind, is_moe) in enumerate(pattern):
                x, outc[f"pos_{p_idx}"] = layer_with_cache(
                    gparams[f"pos_{p_idx}"], kind, is_moe, x, positions
                )
            return constrain(x), outc

        x, blocks_cache = jax.lax.scan(group_body, x, params["blocks"])
        cache["blocks"] = blocks_cache

        x = apply_norm(params["final_norm"], x, cfg.norm)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        last_logits = unembed(head, x[:, -1, :]).astype(jnp.float32)
        return last_logits, cache

    params_sds = abstract_params(cfg, dtype)
    params_sh = param_shardings(params_sds, mesh, cfg=cfg)
    cspecs = cache_specs(cfg, mesh, batch, seq)
    cache_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cspecs, is_leaf=lambda x: isinstance(x, P)
    )
    in_sh = (params_sh, None)
    out_sh = (NamedSharding(mesh, batch_spec(mesh, 1)), cache_sh)
    return prefill_fn, in_sh, out_sh, params_sds
