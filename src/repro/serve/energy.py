"""Per-job idle-vs-spin energy split for the sweep service.

The paper's central energy contrast is *how losers wait*: hardware-assisted
disciplines clock-gate the cores that lost the race (cheap ``gated``
cycles), software spin-locks keep them clocked and hammering the TCDM
(expensive ``wait`` cycles plus interconnect traffic).  This helper projects
one job's :class:`~repro.core.scu.engine.ClusterStats` onto exactly that
axis so ``benchmarks/traffic.py`` can report **tail energy per
discipline** -- p99 spin energy of a ``tas`` mix vs an ``scu`` mix -- not
just averages.

The coefficients come from the calibrated cluster model
(:data:`repro.core.scu.energy.DEFAULT_ENERGY`); this module only groups its
terms, it does not introduce new ones, so ``idle_pj + spin_pj + compute_pj
+ baseline_pj == EnergyModel.energy_pj`` exactly (asserted in tests).
"""

from __future__ import annotations

import dataclasses

from repro.core.scu.energy import DEFAULT_ENERGY, Activity, EnergyModel
from repro.core.scu.engine import ClusterStats

__all__ = ["JobEnergy", "job_energy"]


@dataclasses.dataclass(frozen=True)
class JobEnergy:
    """One job's energy, grouped by how its cycles were spent (pJ).

    idle_pj
        Clock-gated loser cycles (``e_gate * gated``) -- what waiting costs
        under the SCU disciplines.
    spin_pj
        Clocked-but-held cycles plus TCDM traffic (``e_wait * wait +
        e_mem * tcdm``) -- what waiting costs when losers poll shared
        memory.  TCDM accesses of the payload itself land here too; for
        the synchronization microbenchmarks the traffic is overwhelmingly
        spin polls, which is the contrast we report.
    compute_pj
        Actual work: ``e_comp * comp + e_scu * scu``.
    baseline_pj
        Cluster-wide static + clock-tree floor: ``e_static * cycles``.
    """

    idle_pj: float
    spin_pj: float
    compute_pj: float
    baseline_pj: float

    @property
    def total_pj(self) -> float:
        return self.idle_pj + self.spin_pj + self.compute_pj + self.baseline_pj

    @property
    def wait_pj(self) -> float:
        """Everything spent *not* making progress (idle + spin)."""
        return self.idle_pj + self.spin_pj


def job_energy(
    stats: ClusterStats, model: EnergyModel = DEFAULT_ENERGY
) -> JobEnergy:
    """Split one finished job's stats into the idle/spin/compute/static axes.

    The four components are a regrouping of ``model.energy_pj`` -- they sum
    to it exactly, so fleet-level totals can be compared across disciplines
    without double counting.
    """
    act = Activity.from_stats(stats)
    return JobEnergy(
        idle_pj=model.e_gate * act.gated,
        spin_pj=model.e_wait * act.wait + model.e_mem * act.tcdm,
        compute_pj=model.e_comp * act.comp + model.e_scu * act.scu,
        baseline_pj=model.e_static * act.cycles,
    )
