"""Health-aware multi-fleet routing over correlated fault domains.

One level above :class:`repro.serve.fleet_service.FleetService`: a
:class:`FleetPool` manages N :class:`~repro.core.scu.engine.SlotFleet`\\ s
as independent **fault domains** -- the serving analogue of the voltage
islands / cluster groups that :mod:`repro.core.scu.faults` models with its
domain-scoped events (correlated droop, SCU blackout, domain-wide bank
blackout).  A fault that takes out one domain takes out every slot in it at
once, so recovery must be *topological*: re-route the work somewhere else
and stop feeding the sick domain, instead of retrying into the blast
radius.

Router
------
New jobs are placed onto a domain at submit time by a pluggable policy
(``placement``):

``least-loaded``
    the admissible domain with the smallest load (queued + in-flight
    jobs), ties broken by higher health score then lower domain id.
``round-robin``
    cycles through the admissible domains in index order.

Admissible means *healthy* domains when any exist, else *probation*
domains, else every domain (all quarantined -- jobs queue and wait out the
cooldown; a job already queued on a domain that is quarantined later also
waits, by design: placement is FIFO per domain and never reshuffles).
Every queue is per-domain FIFO, so rerouted retries join the tail of their
new domain and never jump fresh submissions there.

Health + circuit breaker
------------------------
Each domain carries a :class:`DomainHealth` record: a rolling window of
attempt outcomes plus running totals of watchdog trips, terminal failures
and wasted cycles.  An optional :class:`BreakerPolicy` drives a
deterministic, round-counted state machine per domain::

      healthy --(>= probation_after failures in window)--> probation
    probation --(any failure)--> quarantined        [cooldown_rounds]
    probation --(probe_successes consecutive successes)--> healthy
  quarantined --(cooldown elapsed)--> probation     [probe admissions]

``probation`` is probe mode: at most one job in flight, so a still-sick
domain burns one probe per window instead of a full fleet of jobs.
``quarantined`` admits nothing until the cooldown expires.  All
transitions happen at round boundaries from round-counted state -- no
wall-clock anywhere -- so a pool run is bit-reproducible.

Watchdog escalation
-------------------
The chain is slot -> domain -> router: a cluster-level watchdog first
force-releases parked waiters (slot-level recovery, invisible up here);
a hard trip surfaces as the member's ``DeadlockError`` whose
``"watchdog tripped"`` message carries the :class:`WaitForGraph` dump.
The pool records the trip against the domain's health (``fault_log``
entries carry ``"domain"`` blame), and the breaker escalates the domain to
quarantine -- the domain-level trip the ROADMAP's multi-cluster item
calls for.

Reroute vs retry
----------------
With ``RetryPolicy(reroute=True)`` a failed attempt is resubmitted to a
*different healthy* domain when one exists (counted in
:attr:`FleetPool.reroutes`); otherwise -- and always with
``reroute=False`` -- it retries in place on the same domain.  Backoff,
degradation (``degrade_after`` + ``fallback_factory``) and terminal
failure semantics are identical to :class:`FleetService`; the reroute
decision is made when the backoff expires, against the health state of
that round.

Live migration (reroute + :class:`CheckpointPolicy`)
----------------------------------------------------
With ``checkpoint=CheckpointPolicy(interval_rounds=k)`` every running
member is snapshotted each ``k`` rounds of its attempt (round boundary;
bit-exact, see :mod:`repro.core.scu.checkpoint`).  A failed attempt that
has a checkpoint **migrates** instead of restarting: the retry resumes
from the checkpoint -- on whatever domain the reroute logic picks -- with
the failed attempt's :class:`~repro.core.scu.faults.FaultPlan` stripped,
so the sick domain's remaining fault schedule does not follow the job to
its new home.  Wasted cycles per failure drop from the whole attempt to
the checkpoint -> failure tail (at most one interval plus the detection
lag).  Checkpoint-resumed admissions bypass the ``inject`` hook (the
chaos harness arms *fresh* attempts; a restore continues an old one).  A
checkpoint that backed one failed resume is dropped as poisoned -- it
captured already-corrupted state -- and the next retry rebuilds from
scratch.  Members running generator-backed programs are silently
non-checkpointable and keep restart-reroute semantics.  Migrations are
counted in :attr:`FleetPool.migrations` (a subset of ``reroutes`` when
the target differs from the failing domain).

Fault injection is tied to domains through the optional ``inject`` hook:
``inject(domain, config) -> config`` runs at admission for every attempt,
letting a chaos harness (``benchmarks/fault_domains.py``) arm
:class:`~repro.core.scu.faults.FaultPlan`\\ s on the configs a particular
domain executes -- which is exactly why rerouting escapes them.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core.scu.checkpoint import NotCheckpointable
from repro.core.scu.engine import FleetConfig, SlotFleet
from repro.serve.fleet_service import (
    CheckpointPolicy,
    QueueFull,
    RetryPolicy,
    SweepJob,
    _fresh_traces,
)

__all__ = ["DomainHealth", "BreakerPolicy", "FleetPool"]

HEALTHY, PROBATION, QUARANTINED = "healthy", "probation", "quarantined"


class DomainHealth:
    """Rolling health record for one fault domain.

    ``outcomes`` is a bounded window of recent attempt results (True =
    success); the running totals survive window eviction and feed the
    pool-level metrics.  ``score`` is the window success fraction (1.0
    while empty -- a fresh domain is presumed healthy)."""

    def __init__(self, window: int = 16):
        if window < 1:
            raise ValueError(f"health window must be >= 1, got {window}")
        self.window = window
        self.outcomes: Deque[bool] = deque(maxlen=window)
        self.watchdog_trips = 0
        self.terminal_failures = 0
        self.wasted_cycles = 0
        self.completed = 0
        self.failed_attempts = 0

    def record_success(self) -> None:
        self.outcomes.append(True)
        self.completed += 1

    def record_failure(self, wasted_cycles: int, watchdog: bool) -> None:
        self.outcomes.append(False)
        self.failed_attempts += 1
        self.wasted_cycles += wasted_cycles
        if watchdog:
            self.watchdog_trips += 1

    @property
    def score(self) -> float:
        if not self.outcomes:
            return 1.0
        return sum(self.outcomes) / len(self.outcomes)

    @property
    def window_failures(self) -> int:
        return len(self.outcomes) - sum(self.outcomes)


@dataclasses.dataclass(frozen=True)
class BreakerPolicy:
    """Deterministic circuit-breaker knobs for :class:`FleetPool`.

    ``probation_after`` window failures drop a healthy domain to
    probation; any failure on probation quarantines it for
    ``cooldown_rounds`` scheduler rounds, after which it re-enters
    probation (probe mode: one job in flight); ``probe_successes``
    consecutive successes restore it to healthy."""

    probation_after: int = 2
    cooldown_rounds: int = 8
    probe_successes: int = 2

    def __post_init__(self):
        if self.probation_after < 1:
            raise ValueError(
                f"probation_after must be >= 1, got {self.probation_after}"
            )
        if self.cooldown_rounds < 1:
            raise ValueError(
                f"cooldown_rounds must be >= 1, got {self.cooldown_rounds}"
            )
        if self.probe_successes < 1:
            raise ValueError(
                f"probe_successes must be >= 1, got {self.probe_successes}"
            )


class FleetPool:
    """N slot fleets as fault domains behind one health-aware router.

    Parameters
    ----------
    n_domains:
        Number of fault domains (independent :class:`SlotFleet`\\ s).
    n_slots, slot_cores, banking_factor:
        Per-domain fleet geometry (uniform across domains).
    queue_limit:
        Global bound over the sum of the per-domain queues; a full pool
        **rejects** (:class:`QueueFull`) exactly like
        :class:`FleetService`.  Retry requeues bypass the bound -- a
        retried job already owns its place in the system.
    placement:
        ``"least-loaded"`` (default) or ``"round-robin"``; see the module
        docstring.
    retry:
        Optional :class:`RetryPolicy`; ``reroute=True`` makes failed
        attempts prefer a different healthy domain.
    breaker:
        Optional :class:`BreakerPolicy`; ``None`` disables quarantine
        (every domain stays ``healthy`` forever, health is still scored).
    health_window:
        Rolling-outcome window per :class:`DomainHealth`.
    inject:
        Optional ``inject(domain, config) -> config`` hook applied at
        admission to every attempt (chaos harness entry point;
        checkpoint-restored admissions skip it).
    checkpoint:
        Optional :class:`~repro.serve.fleet_service.CheckpointPolicy`;
        enables periodic snapshots and live migration (see the module
        docstring).
    """

    PLACEMENTS = ("least-loaded", "round-robin")

    def __init__(
        self,
        n_domains: int,
        n_slots: int,
        slot_cores: int,
        banking_factor: int = 2,
        queue_limit: int = 64,
        placement: str = "least-loaded",
        retry: Optional[RetryPolicy] = None,
        breaker: Optional[BreakerPolicy] = None,
        health_window: int = 16,
        inject: Optional[Callable[[int, FleetConfig], FleetConfig]] = None,
        checkpoint: Optional[CheckpointPolicy] = None,
    ):
        if n_domains < 1:
            raise ValueError(f"n_domains must be >= 1, got {n_domains}")
        if placement not in self.PLACEMENTS:
            raise ValueError(
                f"placement must be one of {self.PLACEMENTS}, got {placement!r}"
            )
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self.n_domains = n_domains
        self.fleets = [
            SlotFleet(n_slots, slot_cores, banking_factor)
            for _ in range(n_domains)
        ]
        self.queues: List[Deque[SweepJob]] = [deque() for _ in range(n_domains)]
        self.health = [DomainHealth(health_window) for _ in range(n_domains)]
        self.states = [HEALTHY] * n_domains
        self.queue_limit = queue_limit
        self.placement = placement
        self.retry = retry
        self.breaker = breaker
        self.inject = inject
        self.checkpoint = checkpoint
        self.round = 0
        self.finished: List[SweepJob] = []
        self.reroutes = 0
        self.quarantines = 0
        self.migrations = 0  # checkpoint-resumed reroutes to a new domain
        self._cooldown_until = [0] * n_domains
        self._probe_streak = [0] * n_domains
        self._by_slot: List[Dict[int, SweepJob]] = [
            {} for _ in range(n_domains)
        ]
        self._backoff: List[Tuple[int, SweepJob]] = []
        self._rr = 0
        self._next_id = 0
        self.lane_rounds = 0
        self.busy_lane_rounds = 0

    # ------------------------------------------------------------------ api
    def submit(
        self,
        config: Optional[FleetConfig] = None,
        *,
        factory: Optional[Callable[[int], FleetConfig]] = None,
        fallback_factory: Optional[Callable[[int], FleetConfig]] = None,
    ) -> SweepJob:
        """Enqueue a job onto a routed domain; raises :class:`QueueFull`
        when the global queue bound is hit and ``ValueError`` on a config
        no fleet could admit.  Same config/factory contract as
        :meth:`FleetService.submit`."""
        if (config is None) == (factory is None):
            raise ValueError("submit: pass exactly one of config or factory")
        if config is None:
            config = _fresh_traces(factory(1))
        self.fleets[0].validate(config)
        if sum(len(q) for q in self.queues) >= self.queue_limit:
            raise QueueFull(
                f"pool queue full ({self.queue_limit} jobs waiting); "
                "retry after a step() or raise queue_limit"
            )
        job = SweepJob(
            self._next_id, config, submitted_round=self.round,
            factory=factory, fallback_factory=fallback_factory,
        )
        self._next_id += 1
        self._enqueue(job, self._place())
        return job

    def try_submit(self, config: FleetConfig) -> Optional[SweepJob]:
        """Non-raising :meth:`submit`: ``None`` instead of
        :class:`QueueFull` (invalid configs still raise ``ValueError``)."""
        try:
            return self.submit(config)
        except QueueFull:
            return None

    def step(self) -> List[SweepJob]:
        """One pool round: expire quarantine cooldowns, re-queue
        backoff-expired retries (rerouting them if asked), admit per
        domain, advance every occupied fleet, collect completions and
        update domain health/breaker state.  Returns the jobs that went
        terminal this round."""
        if self.checkpoint is not None:
            self._checkpoint_pass()
        self._expire_cooldowns()
        self._requeue_backoff()
        for d in range(self.n_domains):
            self._admit(d)
        done: List[SweepJob] = []
        busy_lanes = 0
        for d in range(self.n_domains):
            fleet = self.fleets[d]
            finished_cores = 0
            if fleet.occupied:
                for m in fleet.advance():
                    finished_cores += m.cluster.n_cores
                    done.extend(self._collect(d, m))
            busy_lanes += sum(
                j.config.cluster.n_cores for j in self._by_slot[d].values()
            ) + finished_cores
        self.lane_rounds += sum(
            f.n_slots * f.slot_cores for f in self.fleets
        )
        self.busy_lane_rounds += busy_lanes
        self.round += 1
        return done

    def run_until_drained(self, max_rounds: int = 10_000_000) -> List[SweepJob]:
        """Step until every queue, the backoff list and every fleet are
        empty; quarantined domains drain too (their cooldowns are
        round-counted, so progress is guaranteed)."""
        out: List[SweepJob] = []
        rounds = 0
        while (
            any(self.queues) or self._backoff
            or any(f.occupied for f in self.fleets)
        ):
            out.extend(self.step())
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError(
                    f"run_until_drained: not drained after {max_rounds} rounds"
                )
        return out

    # ---------------------------------------------------------------- router
    def _admissible(self, exclude: Optional[int] = None) -> List[int]:
        """Domains the router may place onto, best tier first: healthy,
        else probation, else everything (all quarantined)."""
        for tier in (HEALTHY, PROBATION):
            ds = [
                d for d in range(self.n_domains)
                if self.states[d] == tier and d != exclude
            ]
            if ds:
                return ds
        return [d for d in range(self.n_domains) if d != exclude] or [exclude]

    def _place(self, exclude: Optional[int] = None) -> int:
        """Pick a target domain by the placement policy."""
        candidates = self._admissible(exclude)
        if self.placement == "round-robin":
            d = candidates[self._rr % len(candidates)]
            self._rr += 1
            return d
        # least-loaded: fewest queued+in-flight jobs, ties to the higher
        # health score, then the lower domain id -- fully deterministic
        return min(
            candidates,
            key=lambda d: (
                len(self.queues[d]) + len(self._by_slot[d]),
                -self.health[d].score,
                d,
            ),
        )

    def _enqueue(self, job: SweepJob, domain: int) -> None:
        job.domain = domain
        job.state = "queued"
        self.queues[domain].append(job)

    # ----------------------------------------------------------- checkpoints
    def _checkpoint_pass(self) -> None:
        """Periodic snapshots at the round boundary, per domain."""
        iv = self.checkpoint.interval_rounds
        for d in range(self.n_domains):
            fleet = self.fleets[d]
            for slot, job in sorted(self._by_slot[d].items()):
                if job.checkpoint_disabled:
                    continue
                age = self.round - job.attempt_admitted_round
                if age <= 0 or age % iv != 0:
                    continue
                m = fleet.members[slot]
                if m.cluster.cycle >= m.max_cycles:
                    continue  # burned to its cap: timeout imminent
                try:
                    job.checkpoint = fleet.snapshot(slot)
                except NotCheckpointable:
                    job.checkpoint_disabled = True
                else:
                    job.checkpoint_round = self.round

    def suspend_all(self) -> List[SweepJob]:
        """Checkpoint and evict every running member across all domains
        (pool restart) -- the per-domain analogue of
        :meth:`FleetService.suspend_all`.  Suspended jobs requeue on their
        own domain with ``faults="carry"`` and resume bit-exactly on
        subsequent :meth:`step` calls; non-checkpointable members restart
        via their factory or go terminal."""
        out: List[SweepJob] = []
        for d in range(self.n_domains):
            fleet = self.fleets[d]
            for slot in sorted(self._by_slot[d]):
                job = self._by_slot[d][slot]
                try:
                    job.checkpoint = fleet.suspend(slot)
                except NotCheckpointable:
                    job.checkpoint_disabled = True
                    m = fleet.members[slot]
                    job.wasted_cycles += m.cluster.cycle
                    self.health[d].wasted_cycles += m.cluster.cycle
                    m.done = True
                    fleet.free(slot)
                    job.restore_pending = False
                    factory = job.factory
                    if job.degraded and job.fallback_factory is not None:
                        factory = job.fallback_factory
                    if factory is None:
                        job.error = (
                            "suspended: generator-backed program is not "
                            "checkpointable and the job has no factory to "
                            "rebuild from"
                        )
                        job.state = "failed"
                        job.slot = None
                        job.finished_round = self.round
                        self.health[d].terminal_failures += 1
                        self.finished.append(job)
                        continue
                    job.config = _fresh_traces(factory(job.attempts + 1))
                else:
                    job.checkpoint_round = self.round
                    job.restore_pending = True
                    job.resume_faults = "carry"
                job.slot = None
                self._enqueue(job, d)
                out.append(job)
            self._by_slot[d].clear()
        return out

    # ------------------------------------------------------------- admission
    def _admit(self, d: int) -> None:
        if self.states[d] == QUARANTINED:
            return
        fleet, queue = self.fleets[d], self.queues[d]
        while queue and fleet.free_slots:
            if self.states[d] == PROBATION and self._by_slot[d]:
                return  # probe mode: one job in flight
            job = queue.popleft()
            if job.restore_pending and job.checkpoint is not None:
                # live migration / pool-restart resume: the checkpoint IS
                # the job state; the inject hook (fresh-attempt chaos)
                # does not apply
                slot = fleet.restore(job.checkpoint, faults=job.resume_faults)
                job.restore_pending = False
                if job.resume_faults is None:
                    job.resumed_attempt = True
            else:
                cfg = job.config
                if self.inject is not None:
                    cfg = self.inject(d, cfg)
                    job.config = cfg
                slot = fleet.admit(cfg)
                job.resumed_attempt = False
            job.slot = slot
            job.state = "running"
            job.admitted_round = self.round
            job.attempt_admitted_round = self.round
            self._by_slot[d][slot] = job

    # ------------------------------------------------------------ completion
    def _collect(self, d: int, m) -> List[SweepJob]:
        """Fold one finished fleet member into job + domain state."""
        job = self._by_slot[d].pop(m.index)
        job.attempts += 1
        self.fleets[d].free(m.index)
        if m.error is not None:
            watchdog = m.error.startswith("watchdog tripped")
            fail_cycle = m.cluster.cycle
            job.fault_log.append({
                "attempt": job.attempts,
                "round": self.round,
                "cycles": fail_cycle,
                "degraded": job.degraded,
                "domain": d,
                "watchdog": watchdog,
                "error": m.error.splitlines()[0],
            })
            retried = self._maybe_retry(job)
            # a checkpoint-resume redoes only the checkpoint -> failure
            # tail; a restart redoes the whole attempt
            resume_from = (
                job.checkpoint.cycle
                if retried and job.restore_pending else 0
            )
            waste = fail_cycle - resume_from
            job.wasted_cycles += waste
            self.health[d].record_failure(waste, watchdog)
            self._breaker_failure(d)
            if retried:
                return []
            job.error = m.error
            job.state = "failed"
            self.health[d].terminal_failures += 1
        else:
            job.state = "done"
            self.health[d].record_success()
            self._breaker_success(d)
        job.finished_round = self.round
        job.stats = m.cluster.stats
        self.finished.append(job)
        return [job]

    # --------------------------------------------------------------- breaker
    def _breaker_failure(self, d: int) -> None:
        b = self.breaker
        if b is None:
            return
        state = self.states[d]
        if state == PROBATION:
            self.states[d] = QUARANTINED
            self._cooldown_until[d] = self.round + 1 + b.cooldown_rounds
            self._probe_streak[d] = 0
            self.quarantines += 1
        elif (
            state == HEALTHY
            and self.health[d].window_failures >= b.probation_after
        ):
            self.states[d] = PROBATION
            self._probe_streak[d] = 0

    def _breaker_success(self, d: int) -> None:
        if self.breaker is None or self.states[d] != PROBATION:
            return
        self._probe_streak[d] += 1
        if self._probe_streak[d] >= self.breaker.probe_successes:
            self.states[d] = HEALTHY
            self._probe_streak[d] = 0

    def _expire_cooldowns(self) -> None:
        for d in range(self.n_domains):
            if (
                self.states[d] == QUARANTINED
                and self.round >= self._cooldown_until[d]
            ):
                self.states[d] = PROBATION
                self._probe_streak[d] = 0

    # --------------------------------------------------------------- recovery
    def _requeue_backoff(self) -> None:
        still: List[Tuple[int, SweepJob]] = []
        for eligible, job in self._backoff:
            if eligible > self.round:
                still.append((eligible, job))
                continue
            target = job.domain
            r = self.retry
            if r is not None and r.reroute:
                healthy_elsewhere = [
                    d for d in range(self.n_domains)
                    if self.states[d] == HEALTHY and d != job.domain
                ]
                if healthy_elsewhere:
                    target = self._place(exclude=job.domain)
                    if target != job.domain:
                        self.reroutes += 1
                        if job.restore_pending and job.checkpoint is not None:
                            # checkpoint rides along: live migration
                            self.migrations += 1
            self._enqueue(job, target)
        self._backoff = still

    def _maybe_retry(self, job: SweepJob) -> bool:
        """Identical backoff/degrade schedule to :class:`FleetService`;
        the reroute decision is deferred to requeue time.  Prefers
        resuming from the job's last checkpoint (faults stripped -- live
        migration when the reroute picks a new domain); a checkpoint that
        already backed one failed resume is poisoned and dropped."""
        r = self.retry
        if r is None or job.attempts >= r.max_attempts:
            return False
        if job.resumed_attempt:
            job.checkpoint = None
            job.checkpoint_round = None
        if job.checkpoint is not None:
            job.restore_pending = True
            job.resume_faults = None  # the sick domain's plan stays behind
        else:
            job.restore_pending = False
            cfg = self._next_config(job)
            if cfg is None:
                return False
            try:
                self.fleets[0].validate(cfg)
            except ValueError:
                return False
            job.config = cfg
        job.slot = None
        job.state = "backoff"
        delay = r.backoff_rounds * (r.backoff_factor ** (job.attempts - 1))
        self._backoff.append((self.round + 1 + delay, job))
        return True

    def _next_config(self, job: SweepJob) -> Optional[FleetConfig]:
        nxt = job.attempts + 1
        r = self.retry
        if (
            r.degrade_after is not None
            and job.attempts >= r.degrade_after
            and job.fallback_factory is not None
        ):
            job.degraded = True
            return _fresh_traces(job.fallback_factory(nxt))
        if job.factory is not None:
            return _fresh_traces(job.factory(nxt))
        return None

    # --------------------------------------------------------------- metrics
    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues)

    @property
    def active(self) -> int:
        return sum(len(s) for s in self._by_slot)

    @property
    def idle_lane_fraction(self) -> float:
        if self.lane_rounds == 0:
            return 0.0
        return 1.0 - self.busy_lane_rounds / self.lane_rounds

    @property
    def watchdog_trips(self) -> int:
        return sum(h.watchdog_trips for h in self.health)

    @property
    def wasted_cycles(self) -> int:
        return sum(h.wasted_cycles for h in self.health)

    def domain_report(self) -> List[Dict]:
        """Deterministic per-domain health snapshot (benchmark surface)."""
        return [
            {
                "domain": d,
                "state": self.states[d],
                "score": self.health[d].score,
                "completed": self.health[d].completed,
                "failed_attempts": self.health[d].failed_attempts,
                "terminal_failures": self.health[d].terminal_failures,
                "watchdog_trips": self.health[d].watchdog_trips,
                "wasted_cycles": self.health[d].wasted_cycles,
            }
            for d in range(self.n_domains)
        ]
