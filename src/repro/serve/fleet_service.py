"""Continuous-batching sweep service over the slot-recycling fleet engine.

The serving analogue of :class:`repro.serve.batching.ContinuousBatcher`, one
level up: instead of token sequences in decode slots, the unit of work is a
whole cluster configuration (a :class:`~repro.core.scu.engine.FleetConfig`)
and the step is one scheduling round of a
:class:`~repro.core.scu.engine.SlotFleet` -- the batched array program over
every occupied slot.  Finished jobs free their lanes and queued jobs are
admitted at the next round, so the fleet stays warm across a stream of
heterogeneous sweep jobs instead of draining to idle between fixed batches.

Time axis and latency
---------------------
All latency accounting is in **scheduler rounds** (calls to :meth:`step`),
the machine-independent clock shared with :mod:`repro.serve.arrivals`.  A
job's latency spans submit to finish inclusive; its queue wait is the
submit-to-admission span.  Wall-clock enters only in the benchmark layer,
as same-run throughput ratios.

Backpressure (documented choice: **reject**)
--------------------------------------------
The queue is bounded; :meth:`submit` on a full queue raises
:class:`QueueFull` deterministically -- the caller decides whether to
retry, drop, or throttle (``try_submit`` is the non-raising variant).
Rejecting keeps the service loop non-blocking and the behaviour identical
on every machine, which blocking-with-timeout would not.

Correctness
-----------
Admission timing is invisible to co-resident jobs (see
:class:`~repro.core.scu.engine.SlotFleet`): every job's ``ClusterStats`` is
bit-exact against a sequential ``Cluster.run()`` of the same config, no
matter when it was admitted or what shared a step with it.  A job that
hits its ``max_cycles`` cap fails alone -- same message ``Cluster.run``
would raise, carried on ``SweepJob.error`` -- and its lanes are recycled.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.core.scu.engine import ClusterStats, FleetConfig, SlotFleet

__all__ = ["SweepJob", "QueueFull", "FleetService"]


class QueueFull(RuntimeError):
    """Raised by :meth:`FleetService.submit` when the bounded queue is full."""


@dataclasses.dataclass
class SweepJob:
    """One sweep job's lifecycle record (filled in by the service).

    ``stats`` is a materialized snapshot -- safe to read after the job's
    slot has been recycled.  ``error`` is ``None`` on success, otherwise
    the timeout message the sequential engine would have raised.
    """

    job_id: int
    config: FleetConfig
    submitted_round: int
    admitted_round: Optional[int] = None
    finished_round: Optional[int] = None
    slot: Optional[int] = None
    stats: Optional[ClusterStats] = None
    error: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.finished_round is not None

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def queue_rounds(self) -> Optional[int]:
        """Rounds spent waiting for a slot (0 = admitted immediately)."""
        if self.admitted_round is None:
            return None
        return self.admitted_round - self.submitted_round

    @property
    def latency_rounds(self) -> Optional[int]:
        """Submit-to-finish span, inclusive of the finishing round."""
        if self.finished_round is None:
            return None
        return self.finished_round - self.submitted_round + 1


class FleetService:
    """Bounded-queue sweep service over a warm :class:`SlotFleet`.

    Parameters
    ----------
    n_slots, slot_cores, banking_factor:
        Fleet geometry, passed through to :class:`SlotFleet` (jobs up to
        ``slot_cores`` cores fit; narrower jobs leave their slot's tail
        lanes idle, which the idle-lane accounting charges honestly).
    queue_limit:
        Bounded-queue depth; a full queue **rejects** (:class:`QueueFull`).
    admission:
        ``"continuous"`` (default) -- finished jobs free lanes mid-flight
        and queued jobs take them at the next round.  ``"drain"`` -- the
        fixed-batch baseline: new jobs are only admitted once *every* slot
        has drained, exactly the utilization loss continuous batching
        removes.  Both modes run the identical engine, so measured deltas
        are scheduling policy, not implementation.
    """

    ADMISSION_MODES = ("continuous", "drain")

    def __init__(
        self,
        n_slots: int,
        slot_cores: int,
        banking_factor: int = 2,
        queue_limit: int = 64,
        admission: str = "continuous",
    ):
        if admission not in self.ADMISSION_MODES:
            raise ValueError(
                f"admission must be one of {self.ADMISSION_MODES}, "
                f"got {admission!r}"
            )
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self.fleet = SlotFleet(n_slots, slot_cores, banking_factor)
        self.queue_limit = queue_limit
        self.admission = admission
        self.round = 0  # completed step() calls == current round index
        self.queue: Deque[SweepJob] = deque()
        self.finished: List[SweepJob] = []
        self._by_slot: Dict[int, SweepJob] = {}
        self._next_id = 0
        # lane-occupancy accounting (idle = not running a live job's core;
        # a narrow job's tail lanes count idle -- slot-width waste is real)
        self.lane_rounds = 0
        self.busy_lane_rounds = 0

    # ------------------------------------------------------------------ api
    def submit(self, config: FleetConfig) -> SweepJob:
        """Enqueue a job; raises :class:`QueueFull` on a full queue and
        ``ValueError`` on a config the fleet could never admit (so the
        queue only ever holds admissible jobs)."""
        self.fleet.validate(config)
        if len(self.queue) >= self.queue_limit:
            raise QueueFull(
                f"queue full ({self.queue_limit} jobs waiting); "
                "retry after a step() or raise queue_limit"
            )
        job = SweepJob(self._next_id, config, submitted_round=self.round)
        self._next_id += 1
        self.queue.append(job)
        return job

    def try_submit(self, config: FleetConfig) -> Optional[SweepJob]:
        """Non-raising :meth:`submit`: returns ``None`` instead of raising
        :class:`QueueFull` (invalid configs still raise ``ValueError``)."""
        try:
            return self.submit(config)
        except QueueFull:
            return None

    def step(self) -> List[SweepJob]:
        """One service round: admit from the queue, advance the fleet one
        scheduling round, collect completions.  Returns the jobs that
        finished this round (stats materialized, failures marked)."""
        self._admit()
        done: List[SweepJob] = []
        if self.fleet.occupied:
            for m in self.fleet.advance():
                job = self._by_slot.pop(m.index)
                job.finished_round = self.round
                job.stats = m.cluster.stats
                job.error = m.error
                self.fleet.free(m.index)
                self.finished.append(job)
                done.append(job)
        # occupancy snapshot of the round just executed (post-completion:
        # a lane freed this round was still busy during it)
        self.lane_rounds += self.fleet.n_slots * self.fleet.slot_cores
        self.busy_lane_rounds += sum(
            j.config.cluster.n_cores for j in self._by_slot.values()
        ) + sum(j.config.cluster.n_cores for j in done)
        self.round += 1
        return done

    def run_until_drained(self, max_rounds: int = 10_000_000) -> List[SweepJob]:
        """Step until the queue and every slot are empty; returns all jobs
        finished along the way.  ``max_rounds`` guards against a caller
        submitting faster than the fleet can drain (raises RuntimeError)."""
        out: List[SweepJob] = []
        rounds = 0
        while self.queue or self.fleet.occupied:
            out.extend(self.step())
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError(
                    f"run_until_drained: not drained after {max_rounds} rounds"
                )
        return out

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        if self.admission == "drain" and self.fleet.occupied:
            return  # baseline: wait for the whole fleet to empty
        while self.queue and self.fleet.free_slots:
            job = self.queue.popleft()
            slot = self.fleet.admit(job.config)
            job.slot = slot
            job.admitted_round = self.round
            self._by_slot[slot] = job

    # --------------------------------------------------------------- metrics
    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def active(self) -> int:
        return len(self._by_slot)

    @property
    def idle_lane_fraction(self) -> float:
        """Fraction of (lane, round) cells spent idle so far (0.0 before
        the first round).  The drain baseline's straggler tails and slot
        fragmentation both land here."""
        if self.lane_rounds == 0:
            return 0.0
        return 1.0 - self.busy_lane_rounds / self.lane_rounds
