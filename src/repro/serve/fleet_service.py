"""Continuous-batching sweep service over the slot-recycling fleet engine.

The serving analogue of :class:`repro.serve.batching.ContinuousBatcher`, one
level up: instead of token sequences in decode slots, the unit of work is a
whole cluster configuration (a :class:`~repro.core.scu.engine.FleetConfig`)
and the step is one scheduling round of a
:class:`~repro.core.scu.engine.SlotFleet` -- the batched array program over
every occupied slot.  Finished jobs free their lanes and queued jobs are
admitted at the next round, so the fleet stays warm across a stream of
heterogeneous sweep jobs instead of draining to idle between fixed batches.

Time axis and latency
---------------------
All latency accounting is in **scheduler rounds** (calls to :meth:`step`),
the machine-independent clock shared with :mod:`repro.serve.arrivals`.  A
job's latency spans submit to finish inclusive; its queue wait is the
submit-to-admission span.  Wall-clock enters only in the benchmark layer,
as same-run throughput ratios.

Backpressure (documented choice: **reject**)
--------------------------------------------
The queue is bounded; :meth:`submit` on a full queue raises
:class:`QueueFull` deterministically -- the caller decides whether to
retry, drop, or throttle (``try_submit`` is the non-raising variant).
Rejecting keeps the service loop non-blocking and the behaviour identical
on every machine, which blocking-with-timeout would not.

Correctness
-----------
Admission timing is invisible to co-resident jobs (see
:class:`~repro.core.scu.engine.SlotFleet`): every job's ``ClusterStats`` is
bit-exact against a sequential ``Cluster.run()`` of the same config, no
matter when it was admitted or what shared a step with it.  A job that
hits its ``max_cycles`` cap (or trips a watchdog) fails alone -- same
message ``Cluster.run`` would raise, carried on ``SweepJob.error`` -- and
its lanes are recycled.

Recovery (opt-in via :class:`RetryPolicy`)
------------------------------------------
Clusters are single-use, so a failed attempt cannot be re-run in place;
retryable jobs are submitted with a ``factory(attempt) -> FleetConfig``
callable that rebuilds a fresh config per attempt (attempt numbers start
at 1).  On failure the service logs the attempt in ``SweepJob.fault_log``
and re-queues the job after an exponential backoff in scheduler rounds
(``backoff_rounds * backoff_factor ** (attempts - 1)``); after
``degrade_after`` failed attempts it switches to ``fallback_factory`` when
provided (graceful degradation, e.g. the ``scu`` policy falling back to
``sw`` spin barriers -- marked on ``SweepJob.degraded``).  A job that
exhausts ``max_attempts`` (or has no way to rebuild a config) goes
**terminal**: ``state == "failed"``, ``error`` set, counted in
``finished`` -- so :meth:`run_until_drained` terminates instead of
spinning on permanently-failed work.

Checkpointing (opt-in via :class:`CheckpointPolicy`)
----------------------------------------------------
With ``checkpoint=CheckpointPolicy(interval_rounds=k)`` the service
snapshots every running member each ``k`` rounds of its attempt, at the
round boundary (a full-step boundary -- the only place the engine's
recovery contract allows).  The checkpoint is deterministic and bit-exact
(:mod:`repro.core.scu.checkpoint`); a member running generator-backed
programs is silently non-checkpointable and keeps the restart-only
behaviour -- never a wrong resume.  A failed attempt that has a checkpoint
retries by **resuming** from it (with the attempt-scoped
:class:`~repro.core.scu.faults.FaultPlan` stripped -- the transient-fault
model), so its wasted cycles shrink from the whole attempt to at most one
checkpoint interval.  If the resumed attempt fails again the checkpoint is
considered poisoned (it captured already-corrupted state, e.g. a core
whose wake was already lost) and dropped -- the next retry rebuilds from
scratch.  :meth:`suspend_all` checkpoints and evicts every running member
at once (service restart): the service object -- queue, backoff list and
checkpoints -- is the serialized in-flight state, and subsequent
:meth:`step` calls resume the whole sweep bit-exactly.

Priority admission + preemption (opt-in)
----------------------------------------
``admission_order="priority"`` replaces FIFO admission with a
deterministic priority pick: highest effective priority first, ties broken
by earlier submission then lower job id.  ``aging_rounds=k`` bumps a
waiting job's effective priority by one every ``k`` queued rounds, so
low-priority work cannot starve.  ``preempt=True`` (requires priority
mode and a checkpoint-capable job) lets a queued job with strictly higher
effective priority suspend the lowest-priority running member to a
checkpoint and take its lane; the victim re-enters the queue with its
checkpoint and resumes later (``faults="carry"`` -- preemption continues
the same attempt, losing zero cycles).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core.scu.checkpoint import NotCheckpointable
from repro.core.scu.engine import ClusterStats, FleetConfig, SlotFleet
from repro.core.scu.trace import TraceProgram

__all__ = [
    "SweepJob",
    "QueueFull",
    "RetryPolicy",
    "CheckpointPolicy",
    "FleetService",
]


def _fresh_traces(config: FleetConfig) -> FleetConfig:
    """Clone any single-use :class:`TraceProgram`s in a config.

    Trace programs are consumed on first call (mirroring ``FaultPlan``), but
    a retry ``factory(attempt)`` commonly rebuilds only the cluster and
    reuses the lowered tables -- lowering is the expensive part.  Cloning at
    admission-config construction keeps that pattern valid: every attempt
    gets fresh cursors over the same immutable row tables.
    """
    if not any(isinstance(p, TraceProgram) for p in config.programs):
        return config
    return dataclasses.replace(
        config,
        programs=[
            p.clone() if isinstance(p, TraceProgram) else p
            for p in config.programs
        ],
    )


class QueueFull(RuntimeError):
    """Raised by :meth:`FleetService.submit` when the bounded queue is full."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Failure-recovery knobs for :class:`FleetService`.

    ``max_attempts`` caps total attempts per job (1 = no retry);
    ``backoff_rounds`` / ``backoff_factor`` shape the exponential backoff
    delay (in scheduler rounds) before attempt ``k+1``:
    ``backoff_rounds * backoff_factor ** (k - 1)``.  ``degrade_after``
    (optional) switches the job to its ``fallback_factory`` once that many
    attempts have failed -- graceful degradation to a more robust (slower)
    configuration instead of repeating the failing one forever.
    ``reroute`` asks for the retry to land on a *different healthy fault
    domain* when one exists (falling back to in-place retry otherwise);
    it only has meaning under :class:`repro.serve.fleet_pool.FleetPool`
    (a single-fleet :class:`FleetService` has one domain and ignores it).
    """

    max_attempts: int = 3
    backoff_rounds: int = 1
    backoff_factor: int = 2
    degrade_after: Optional[int] = None
    reroute: bool = False

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff_rounds < 0:
            raise ValueError(f"backoff_rounds must be >= 0, got {self.backoff_rounds}")
        if self.backoff_factor < 1:
            raise ValueError(f"backoff_factor must be >= 1, got {self.backoff_factor}")
        if self.degrade_after is not None and self.degrade_after < 1:
            raise ValueError(f"degrade_after must be >= 1, got {self.degrade_after}")


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Periodic-checkpoint knob for :class:`FleetService` /
    :class:`repro.serve.fleet_pool.FleetPool`.

    Every running member is snapshotted each ``interval_rounds`` rounds of
    its current attempt (at the round boundary).  Smaller intervals bound
    the worst-case recovery loss tighter (a failed attempt resumes from
    its last checkpoint, so at most one interval of progress is redone) at
    the cost of more frequent captures."""

    interval_rounds: int = 8

    def __post_init__(self):
        if self.interval_rounds < 1:
            raise ValueError(
                f"interval_rounds must be >= 1, got {self.interval_rounds}"
            )


@dataclasses.dataclass
class SweepJob:
    """One sweep job's lifecycle record (filled in by the service).

    ``stats`` is a materialized snapshot -- safe to read after the job's
    slot has been recycled.  ``error`` is ``None`` on success, otherwise
    the timeout/deadlock message the sequential engine would have raised
    (terminal -- intermediate failures of retried attempts live in
    ``fault_log``).  ``state`` walks ``queued -> running`` and ends in
    ``done`` or ``failed``, with ``backoff -> queued -> running`` loops in
    between for retried attempts.  ``domain`` is the fault-domain (fleet)
    index the job last ran on -- always ``None`` under the single-fleet
    :class:`FleetService`, set by :class:`repro.serve.fleet_pool.FleetPool`.
    """

    job_id: int
    config: FleetConfig
    submitted_round: int
    admitted_round: Optional[int] = None
    finished_round: Optional[int] = None
    slot: Optional[int] = None
    domain: Optional[int] = None
    stats: Optional[ClusterStats] = None
    error: Optional[str] = None
    state: str = "queued"
    attempts: int = 0
    degraded: bool = False
    wasted_cycles: int = 0  # simulated cycles burnt by failed attempts
    fault_log: List[Dict] = dataclasses.field(default_factory=list)
    factory: Optional[Callable[[int], FleetConfig]] = dataclasses.field(
        default=None, repr=False
    )
    fallback_factory: Optional[Callable[[int], FleetConfig]] = dataclasses.field(
        default=None, repr=False
    )
    # -- checkpoint / priority state (see the module docstring) ------------
    priority: int = 0
    checkpoint: Optional[object] = dataclasses.field(default=None, repr=False)
    checkpoint_round: Optional[int] = None
    checkpoint_disabled: bool = False  # member is not checkpointable
    restore_pending: bool = False  # next admission restores the checkpoint
    resume_faults: object = "carry"  # forwarded to SlotFleet.restore
    resumed_attempt: bool = False  # current attempt began as a failure-resume
    preemptions: int = 0  # times this job was suspended by a higher priority
    attempt_admitted_round: Optional[int] = None  # checkpoint cadence anchor

    @property
    def done(self) -> bool:
        return self.finished_round is not None

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def queue_rounds(self) -> Optional[int]:
        """Rounds spent waiting for a slot (0 = admitted immediately)."""
        if self.admitted_round is None:
            return None
        return self.admitted_round - self.submitted_round

    @property
    def latency_rounds(self) -> Optional[int]:
        """Submit-to-finish span, inclusive of the finishing round."""
        if self.finished_round is None:
            return None
        return self.finished_round - self.submitted_round + 1


class FleetService:
    """Bounded-queue sweep service over a warm :class:`SlotFleet`.

    Parameters
    ----------
    n_slots, slot_cores, banking_factor:
        Fleet geometry, passed through to :class:`SlotFleet` (jobs up to
        ``slot_cores`` cores fit; narrower jobs leave their slot's tail
        lanes idle, which the idle-lane accounting charges honestly).
    queue_limit:
        Bounded-queue depth; a full queue **rejects** (:class:`QueueFull`).
    admission:
        ``"continuous"`` (default) -- finished jobs free lanes mid-flight
        and queued jobs take them at the next round.  ``"drain"`` -- the
        fixed-batch baseline: new jobs are only admitted once *every* slot
        has drained, exactly the utilization loss continuous batching
        removes.  Both modes run the identical engine, so measured deltas
        are scheduling policy, not implementation.
    retry:
        Optional :class:`RetryPolicy`; ``None`` (default) keeps the legacy
        fail-fast behaviour (first failure is terminal).  See the module
        docstring's Recovery section.
    admission_order:
        ``"fifo"`` (default) or ``"priority"``; see the module docstring's
        priority section.
    aging_rounds:
        Optional starvation guard for priority mode: +1 effective priority
        per ``aging_rounds`` rounds spent queued.
    preempt:
        Priority mode only: let a strictly-higher-priority queued job
        suspend the lowest-priority running member to a checkpoint and
        take its lane.
    checkpoint:
        Optional :class:`CheckpointPolicy`; enables periodic snapshots and
        resume-from-checkpoint retries.
    """

    ADMISSION_MODES = ("continuous", "drain")
    ADMISSION_ORDERS = ("fifo", "priority")

    def __init__(
        self,
        n_slots: int,
        slot_cores: int,
        banking_factor: int = 2,
        queue_limit: int = 64,
        admission: str = "continuous",
        retry: Optional[RetryPolicy] = None,
        admission_order: str = "fifo",
        aging_rounds: Optional[int] = None,
        preempt: bool = False,
        checkpoint: Optional[CheckpointPolicy] = None,
    ):
        if admission not in self.ADMISSION_MODES:
            raise ValueError(
                f"admission must be one of {self.ADMISSION_MODES}, "
                f"got {admission!r}"
            )
        if admission_order not in self.ADMISSION_ORDERS:
            raise ValueError(
                f"admission_order must be one of {self.ADMISSION_ORDERS}, "
                f"got {admission_order!r}"
            )
        if preempt and admission_order != "priority":
            raise ValueError("preempt=True requires admission_order='priority'")
        if aging_rounds is not None and aging_rounds < 1:
            raise ValueError(f"aging_rounds must be >= 1, got {aging_rounds}")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self.fleet = SlotFleet(n_slots, slot_cores, banking_factor)
        self.queue_limit = queue_limit
        self.admission = admission
        self.admission_order = admission_order
        self.aging_rounds = aging_rounds
        self.preempt = preempt
        self.checkpoint = checkpoint
        self.retry = retry
        self.preemptions = 0  # member suspensions forced by priority
        self.round = 0  # completed step() calls == current round index
        self.queue: Deque[SweepJob] = deque()
        self.finished: List[SweepJob] = []
        self._by_slot: Dict[int, SweepJob] = {}
        # (eligible_round, job) pairs waiting out a retry backoff; re-queued
        # at the head of the round they become eligible (bypassing
        # queue_limit: a retry never competes with fresh submissions for
        # queue space, it already owns its place in the system)
        self._backoff: List[Tuple[int, SweepJob]] = []
        self._next_id = 0
        # lane-occupancy accounting (idle = not running a live job's core;
        # a narrow job's tail lanes count idle -- slot-width waste is real)
        self.lane_rounds = 0
        self.busy_lane_rounds = 0

    # ------------------------------------------------------------------ api
    def submit(
        self,
        config: Optional[FleetConfig] = None,
        *,
        factory: Optional[Callable[[int], FleetConfig]] = None,
        fallback_factory: Optional[Callable[[int], FleetConfig]] = None,
        priority: int = 0,
    ) -> SweepJob:
        """Enqueue a job; raises :class:`QueueFull` on a full queue and
        ``ValueError`` on a config the fleet could never admit (so the
        queue only ever holds admissible jobs).

        Pass exactly one of ``config`` (single-shot, non-rebuildable) or
        ``factory`` (``factory(attempt)`` builds a fresh config per
        attempt; attempt numbers start at 1).  ``fallback_factory`` is the
        degraded rebuild used after ``RetryPolicy.degrade_after`` failed
        attempts.  ``priority`` (higher = sooner) only matters under
        ``admission_order="priority"``."""
        if (config is None) == (factory is None):
            raise ValueError("submit: pass exactly one of config or factory")
        if config is None:
            config = _fresh_traces(factory(1))
        self.fleet.validate(config)
        if len(self.queue) >= self.queue_limit:
            raise QueueFull(
                f"queue full ({self.queue_limit} jobs waiting); "
                "retry after a step() or raise queue_limit"
            )
        job = SweepJob(
            self._next_id, config, submitted_round=self.round,
            factory=factory, fallback_factory=fallback_factory,
            priority=priority,
        )
        self._next_id += 1
        self.queue.append(job)
        return job

    def try_submit(self, config: FleetConfig) -> Optional[SweepJob]:
        """Non-raising :meth:`submit`: returns ``None`` instead of raising
        :class:`QueueFull` (invalid configs still raise ``ValueError``)."""
        try:
            return self.submit(config)
        except QueueFull:
            return None

    def step(self) -> List[SweepJob]:
        """One service round: re-queue backoff-expired retries, admit from
        the queue, advance the fleet one scheduling round, collect
        completions.  Returns the jobs that went terminal this round
        (stats materialized, failures marked); retried attempts are not
        returned -- they surface when they finally succeed or exhaust."""
        if self.checkpoint is not None:
            self._checkpoint_pass()
        if self._backoff:
            still: List[Tuple[int, SweepJob]] = []
            for eligible, job in self._backoff:
                if eligible <= self.round:
                    job.state = "queued"
                    self.queue.append(job)
                else:
                    still.append((eligible, job))
            self._backoff = still
        self._admit()
        done: List[SweepJob] = []
        finished_cores = 0
        if self.fleet.occupied:
            for m in self.fleet.advance():
                finished_cores += m.cluster.n_cores
                job = self._by_slot.pop(m.index)
                job.attempts += 1
                self.fleet.free(m.index)
                if m.error is not None:
                    fail_cycle = m.cluster.cycle
                    job.fault_log.append({
                        "attempt": job.attempts,
                        "round": self.round,
                        "cycles": fail_cycle,
                        "degraded": job.degraded,
                        "error": m.error.splitlines()[0],
                    })
                    if self._maybe_retry(job):
                        # a resume redoes only checkpoint -> failure; a
                        # restart redoes the whole attempt
                        resume_from = (
                            job.checkpoint.cycle if job.restore_pending
                            else 0
                        )
                        job.wasted_cycles += fail_cycle - resume_from
                        continue
                    job.wasted_cycles += fail_cycle
                    job.error = m.error
                    job.state = "failed"
                else:
                    job.state = "done"
                job.finished_round = self.round
                job.stats = m.cluster.stats
                self.finished.append(job)
                done.append(job)
        # occupancy snapshot of the round just executed (post-completion:
        # a lane freed this round was still busy during it, whether the
        # job went terminal or back to the retry queue)
        self.lane_rounds += self.fleet.n_slots * self.fleet.slot_cores
        self.busy_lane_rounds += sum(
            j.config.cluster.n_cores for j in self._by_slot.values()
        ) + finished_cores
        self.round += 1
        return done

    def run_until_drained(self, max_rounds: int = 10_000_000) -> List[SweepJob]:
        """Step until the queue, the backoff list and every slot are empty;
        returns all jobs finished along the way (terminally-failed jobs
        included -- they drain instead of spinning the loop).
        ``max_rounds`` guards against a caller submitting faster than the
        fleet can drain (raises RuntimeError)."""
        out: List[SweepJob] = []
        rounds = 0
        while self.queue or self._backoff or self.fleet.occupied:
            out.extend(self.step())
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError(
                    f"run_until_drained: not drained after {max_rounds} rounds"
                )
        return out

    # --------------------------------------------------------------- recovery
    def _maybe_retry(self, job: SweepJob) -> bool:
        """Schedule another attempt for a failed job if policy allows;
        returns False when the failure must go terminal.  Prefers resuming
        from the job's last checkpoint (faults stripped); a checkpoint
        that already backed one failed resume is poisoned and dropped."""
        r = self.retry
        if r is None or job.attempts >= r.max_attempts:
            return False
        if job.resumed_attempt:
            job.checkpoint = None
            job.checkpoint_round = None
        if job.checkpoint is not None:
            job.restore_pending = True
            job.resume_faults = None  # transient-fault model: strip the plan
        else:
            job.restore_pending = False
            cfg = self._next_config(job)
            if cfg is None:
                return False
            try:
                self.fleet.validate(cfg)
            except ValueError:
                return False  # a factory built an inadmissible config
            job.config = cfg
        job.slot = None
        job.state = "backoff"
        delay = r.backoff_rounds * (r.backoff_factor ** (job.attempts - 1))
        self._backoff.append((self.round + 1 + delay, job))
        return True

    def _next_config(self, job: SweepJob) -> Optional[FleetConfig]:
        """Build the config for the job's next attempt (clusters are
        single-use), or ``None`` when the job cannot be rebuilt."""
        nxt = job.attempts + 1
        r = self.retry
        if (
            r.degrade_after is not None
            and job.attempts >= r.degrade_after
            and job.fallback_factory is not None
        ):
            job.degraded = True
            return _fresh_traces(job.fallback_factory(nxt))
        if job.factory is not None:
            return _fresh_traces(job.factory(nxt))
        return None

    # ----------------------------------------------------------- checkpoints
    def _checkpoint_pass(self) -> None:
        """Periodic snapshots at the round boundary (before this round's
        admissions and fleet advance -- a full-step boundary)."""
        iv = self.checkpoint.interval_rounds
        for slot, job in sorted(self._by_slot.items()):
            if job.checkpoint_disabled:
                continue
            age = self.round - job.attempt_admitted_round
            if age <= 0 or age % iv != 0:
                continue
            m = self.fleet.members[slot]
            if m.cluster.cycle >= m.max_cycles:
                continue  # burned to its cap: timeout imminent, state junk
            try:
                job.checkpoint = self.fleet.snapshot(slot)
            except NotCheckpointable:
                job.checkpoint_disabled = True  # restart-only from here on
            else:
                job.checkpoint_round = self.round

    def suspend_all(self) -> List[SweepJob]:
        """Checkpoint and evict every running member (service restart).

        After this call no member is in flight; each suspended job sits in
        the queue with its checkpoint and resumes (``faults="carry"`` --
        the same attempt continues bit-exactly) on subsequent
        :meth:`step` calls.  Non-checkpointable members fall back to a
        restart requeue via their factory, or go terminal when they cannot
        be rebuilt -- never a wrong resume.  Returns the suspended jobs."""
        out: List[SweepJob] = []
        for slot in sorted(self._by_slot):
            job = self._by_slot[slot]
            try:
                job.checkpoint = self.fleet.suspend(slot)
            except NotCheckpointable:
                job.checkpoint_disabled = True
                m = self.fleet.members[slot]
                job.wasted_cycles += m.cluster.cycle
                m.done = True
                self.fleet.free(slot)
                job.restore_pending = False
                cfg = self._rebuild_config(job)
                if cfg is None:
                    job.error = (
                        "suspended: generator-backed program is not "
                        "checkpointable and the job has no factory to "
                        "rebuild from"
                    )
                    job.state = "failed"
                    job.slot = None
                    job.finished_round = self.round
                    self.finished.append(job)
                    continue
                job.config = cfg
            else:
                job.checkpoint_round = self.round
                job.restore_pending = True
                job.resume_faults = "carry"
            job.slot = None
            job.state = "queued"
            self.queue.append(job)
            out.append(job)
        self._by_slot.clear()
        return out

    def _rebuild_config(self, job: SweepJob) -> Optional[FleetConfig]:
        """Restart rebuild for a suspended, non-checkpointable job."""
        factory = job.factory
        if job.degraded and job.fallback_factory is not None:
            factory = job.fallback_factory
        if factory is None:
            return None
        return _fresh_traces(factory(job.attempts + 1))

    # ------------------------------------------------------------- admission
    def _start(self, job: SweepJob) -> None:
        """Bind a queued job to a slot: fresh admit, or checkpoint restore."""
        if job.restore_pending and job.checkpoint is not None:
            slot = self.fleet.restore(job.checkpoint, faults=job.resume_faults)
            job.restore_pending = False
            # a failure-resume (stripped faults) marks the attempt so a
            # second failure poisons the checkpoint; a preemption resume
            # ("carry") continues the attempt unchanged
            if job.resume_faults is None:
                job.resumed_attempt = True
        else:
            slot = self.fleet.admit(job.config)
            job.resumed_attempt = False
        job.slot = slot
        job.state = "running"
        job.admitted_round = self.round
        job.attempt_admitted_round = self.round
        self._by_slot[slot] = job

    def _effective_priority(self, job: SweepJob) -> int:
        eff = job.priority
        if self.aging_rounds is not None:
            eff += (self.round - job.submitted_round) // self.aging_rounds
        return eff

    def _best_queued(self) -> int:
        """Queue index of the next job under priority order: highest
        effective priority, then earliest submission, then lowest id."""
        return min(
            range(len(self.queue)),
            key=lambda i: (
                -self._effective_priority(self.queue[i]),
                self.queue[i].submitted_round,
                self.queue[i].job_id,
            ),
        )

    def _preempt_victim(self, eff: int) -> Optional[SweepJob]:
        """Lowest-effective-priority running member strictly below ``eff``
        (ties to the youngest submission then highest id -- the inverse of
        admission order), skipping non-checkpointable members."""
        victims = sorted(
            (
                j for j in self._by_slot.values()
                if not j.checkpoint_disabled
                and self._effective_priority(j) < eff
            ),
            key=lambda j: (
                self._effective_priority(j),
                -j.submitted_round,
                -j.job_id,
            ),
        )
        for victim in victims:
            try:
                ckpt = self.fleet.suspend(victim.slot)
            except NotCheckpointable:
                victim.checkpoint_disabled = True
                continue
            victim.checkpoint = ckpt
            victim.checkpoint_round = self.round
            victim.restore_pending = True
            victim.resume_faults = "carry"  # same attempt, zero lost cycles
            self._by_slot.pop(victim.slot)
            victim.slot = None
            victim.state = "queued"
            victim.preemptions += 1
            self.preemptions += 1
            self.queue.append(victim)
            return victim
        return None

    def _admit(self) -> None:
        if self.admission == "drain" and self.fleet.occupied:
            return  # baseline: wait for the whole fleet to empty
        if self.admission_order == "priority":
            self._admit_priority()
            return
        while self.queue and self.fleet.free_slots:
            job = self.queue.popleft()
            self._start(job)

    def _admit_priority(self) -> None:
        while self.queue:
            idx = self._best_queued()
            job = self.queue[idx]
            if self.fleet.free_slots:
                del self.queue[idx]
                self._start(job)
                continue
            if not self.preempt:
                return
            victim = self._preempt_victim(self._effective_priority(job))
            if victim is None:
                return
            # the victim appended itself to the queue tail; the candidate's
            # index is unchanged
            del self.queue[idx]
            self._start(job)

    # --------------------------------------------------------------- metrics
    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def active(self) -> int:
        return len(self._by_slot)

    @property
    def idle_lane_fraction(self) -> float:
        """Fraction of (lane, round) cells spent idle so far (0.0 before
        the first round).  The drain baseline's straggler tails and slot
        fragmentation both land here."""
        if self.lane_rounds == 0:
            return 0.0
        return 1.0 - self.busy_lane_rounds / self.lane_rounds
