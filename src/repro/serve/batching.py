"""Continuous batching scheduler (serving substrate).

A slot-based scheduler in the vLLM style, sized for the static-shape decode
step: the decode batch is a fixed-capacity slot array; finished sequences
free their slot and queued requests are admitted at the next step.  The
jitted ``serve_step`` sees a constant (batch, max_seq) shape -- admission
only mutates host-side bookkeeping plus the tokens/positions fed in, so no
recompilation ever happens mid-serving.

Straggler/fault behaviour: a request exceeding ``max_new_tokens`` or
``deadline_steps`` is force-finished (the serving analogue of the step
watchdog in ``train/loop.py``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    deadline_steps: Optional[int] = None
    # filled by the scheduler
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    age: int = 0

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        if self.deadline_steps is not None and self.age >= self.deadline_steps:
            return True
        return False


class ContinuousBatcher:
    """Fixed-slot continuous batching around a single-token decode step."""

    def __init__(self, batch_slots: int, max_seq: int, pad_token: int = 0):
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self.pad_token = pad_token
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.finished: Dict[int, Request] = {}
        # per-slot decode state (host mirrors of what the model consumes)
        self.positions = np.zeros((batch_slots,), np.int32)
        self.next_tokens = np.full((batch_slots,), pad_token, np.int32)

    # ------------------------------------------------------------------ api
    def submit(self, req: Request) -> None:
        assert len(req.prompt) < self.max_seq, "prompt exceeds cache"
        self.queue.append(req)

    def admit(self) -> List[int]:
        """Fill free slots from the queue; returns newly admitted slot ids.

        The caller is responsible for prefilling the admitted prompts into
        the cache slots (``prefill`` per slot, or token-by-token feed)."""
        admitted = []
        for i in range(self.batch_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                req.slot = i
                self.slots[i] = req
                self.positions[i] = len(req.prompt)
                self.next_tokens[i] = req.prompt[-1] if req.prompt else self.pad_token
                admitted.append(i)
        return admitted

    def step_inputs(self):
        """(tokens (B,1), positions (B,)) for the jitted decode step."""
        return self.next_tokens.reshape(-1, 1).copy(), self.positions.copy()

    def observe(self, sampled: np.ndarray) -> List[Request]:
        """Record one decode step's outputs; returns finished requests."""
        done: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(sampled[i])
            req.generated.append(tok)
            req.age += 1
            self.next_tokens[i] = tok
            self.positions[i] += 1
            if req.done or self.positions[i] >= self.max_seq - 1:
                self.finished[req.rid] = req
                done.append(req)
                self.slots[i] = None
                self.positions[i] = 0
                self.next_tokens[i] = self.pad_token
        return done

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def drain_done(self) -> bool:
        return self.active == 0 and not self.queue
