"""repro: SCU-paper reproduction -- cycle-accurate Tier 1 + TPU-pod Tier 2."""

__version__ = "1.0.0"
