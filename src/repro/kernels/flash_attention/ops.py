"""Jit-ready wrapper: Pallas flash attention on TPU, flash-vjp ref elsewhere.

``flash_attention(q, k, v)`` takes the models' (b, s, h, d) layout, runs the
Pallas kernel when a TPU backend is present (or ``interpret=True`` is
forced), and otherwise falls back to the numerically identical pure-JAX
flash core (which also provides the backward pass -- the Pallas backward
kernel is future work; on TPU the forward kernel is wrapped in
``jax.custom_vjp`` with the flash-recompute backward from
:mod:`repro.models.layers.flash_core`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.flash_core import flash_attention_core
from .kernel import flash_attention_fwd

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def flash_attention(
    q: jnp.ndarray,  # (b, s, h, d)
    k: jnp.ndarray,  # (b, s, kvh, d)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    use_pallas = interpret if interpret is not None else _on_tpu()
    if use_pallas:
        out = flash_attention_fwd(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=causal,
            block_q=block_q,
            block_k=block_k,
            interpret=bool(interpret),
        )
        return out.transpose(0, 2, 1, 3)
    g = h // kvh
    out = flash_attention_core(
        q.reshape(b, sq, kvh, g, d), k, v, causal, block_q, block_k, 0
    )
    return out.reshape(b, sq, h, d)
