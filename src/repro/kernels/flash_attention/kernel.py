"""Pallas TPU flash-attention kernel (forward).

TPU-native adaptation of the flash algorithm (DESIGN.md Sec. 6.2): the
(q-block, kv-block) score tile lives entirely in VMEM, streamed block by
block from HBM, with f32 running max / denominator / accumulator scratch
persisted across the innermost (sequential) kv grid axis.  Tile shapes are
MXU-aligned (multiples of 128 on the lane axis; the q/kv block sizes are
sublane multiples).

Grid: ``(batch, q_heads, num_q_blocks, num_kv_blocks)`` -- the kv axis is
innermost, so the output block and the scratch accumulators are revisited
across kv steps ("arbitrary effects" only at the final step).  GQA is
handled in the index map: q head ``h`` reads kv head ``h // group``.

Causal masking skips fully-masked kv blocks via ``pl.when`` (the block is
still visited by the grid but performs no MXU work).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

__all__ = ["flash_attention_fwd"]

_NEG = -1e30


def _kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, d)
    o_ref,  # (1, 1, bq, d)
    m_scr,  # VMEM (bq,) f32
    l_scr,  # VMEM (bq,) f32
    acc_scr,  # VMEM (bq, d) f32
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    if causal:
        # causal block skip: kv blocks strictly above the diagonal do no work
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (b, h, sq, d)
    k: jnp.ndarray,  # (b, kvh, sk, d)
    v: jnp.ndarray,  # (b, kvh, sk, d)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Head-major flash attention.  Returns (b, h, sq, d)."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    scale = d**-0.5

    kernel = functools.partial(
        _kernel,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
    )

    grid = (b, h, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, qi, kj: (bi, hi // group, kj, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, qi, kj: (bi, hi // group, kj, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, hi, qi, kj: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
