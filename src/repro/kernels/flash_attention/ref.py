"""Pure-jnp oracle for the flash-attention kernel (head-major layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jnp.ndarray,  # (b, h, sq, d)
    k: jnp.ndarray,  # (b, kvh, sk, d)
    v: jnp.ndarray,  # (b, kvh, sk, d)
    causal: bool = True,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, d)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * (d**-0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(b, h, sq, d)
