"""Pure-jnp oracle for the SSD scan kernel (single B/C group)."""

from __future__ import annotations


from repro.models.layers.ssm import ssd_chunked

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(x, dt, A, B, C, chunk: int = 256):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B, C: (b,s,n) single group."""
    y, _ = ssd_chunked(x, dt, A, B[:, :, None, :], C[:, :, None, :], chunk=chunk)
    return y
