"""Jit wrapper for the SSD scan: Pallas on TPU, chunked-jnp elsewhere."""

from __future__ import annotations

import jax

from repro.models.layers.ssm import ssd_chunked
from .kernel import ssd_scan_fwd

__all__ = ["ssd_scan"]


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, interpret: bool | None = None):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B, C: (b,s,n)."""
    use_pallas = interpret if interpret is not None else (
        jax.default_backend() == "tpu"
    )
    if use_pallas:
        return ssd_scan_fwd(x, dt, A, B, C, chunk=chunk, interpret=bool(interpret))
    y, _ = ssd_chunked(x, dt, A, B[:, :, None, :], C[:, :, None, :], chunk=chunk)
    return y
