"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (forward).

One grid step processes one (batch, head, chunk) tile entirely in VMEM:

  * the within-chunk decay kernel ``L = exp(segsum(dt*A))`` (Q x Q, f32),
  * the "diagonal" contribution  ``(C B^T * L) (dt*x)``  (MXU matmuls),
  * the chunk state  ``B^T (decay * dt*x)``  -> (P, N) f32 scratch carried
    across the innermost (sequential) chunk axis -- the inter-chunk
    recurrence runs inside the kernel via the revisited scratch,
  * the "off-diagonal" contribution ``C state_prev`` with in-chunk decay.

The head-state scratch (P x N f32, e.g. 64x128 = 32 KiB) stays resident in
VMEM for the whole sequence -- the TPU-native counterpart of the SSD
algorithm's "states never leave SRAM between chunks" property on GPUs.

Grid: (batch, heads, num_chunks), chunk axis innermost/sequential.
Single-group (g=1) B/C layout, matching the mamba2-1.3b config.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

__all__ = ["ssd_scan_fwd"]


def _kernel(
    x_ref,  # (1, Q, 1, P)   dt-unweighted input tile
    dt_ref,  # (1, Q, 1)
    a_ref,  # (1,)           A (negative) for this head
    b_ref,  # (1, Q, N)
    c_ref,  # (1, Q, N)
    y_ref,  # (1, Q, 1, P)
    state_scr,  # VMEM (P, N) f32
    *,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0].astype(jnp.float32)  # scalar
    B = b_ref[0].astype(jnp.float32)  # (Q, N)
    C = c_ref[0].astype(jnp.float32)  # (Q, N)

    xb = x * dt[:, None]  # dt-weighted input
    dA = dt * A  # (Q,)
    dA_cum = jnp.cumsum(dA)  # (Q,)

    # within-chunk decay kernel: L[i, j] = exp(sum_{j<k<=i} dA_k), j <= i
    diff = dA_cum[:, None] - dA_cum[None, :] + dA[None, :] * 0.0
    Q = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    # diagonal: (C B^T * L) @ xb
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    y_diag = jax.lax.dot_general(
        scores * L, xb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # off-diagonal: C @ state_prev^T with in-chunk decay
    state_prev = state_scr[...]  # (P, N)
    y_off = jax.lax.dot_general(
        C, state_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(dA_cum)[:, None]  # (Q, P)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state = decay_chunk * state_prev + B^T (decay_states * xb)
    decay_states = jnp.exp(dA_cum[-1] - dA_cum)  # (Q,)
    new_contrib = jax.lax.dot_general(
        xb * decay_states[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    state_scr[...] = state_prev * jnp.exp(dA_cum[-1]) + new_contrib


def ssd_scan_fwd(
    x: jnp.ndarray,  # (b, s, h, p)
    dt: jnp.ndarray,  # (b, s, h)  positive
    A: jnp.ndarray,  # (h,) negative
    B: jnp.ndarray,  # (b, s, n)  (single group)
    C: jnp.ndarray,  # (b, s, n)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_kernel, num_chunks=nc)
    grid = (b, h, nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, B, C)
