"""SCU barrier / notifier as a Pallas TPU kernel -- the paper's mechanism.

The SCU's event lines have a direct TPU hardware analogue: DMA semaphores.
A core that executes ``elw`` stalls until the event arrives with zero busy
cycles; a TPU core that waits on a DMA semaphore blocks in the DMA hardware
the same way -- no spin loop, no host round-trip (DESIGN.md Sec. 6.2).

``scu_barrier_kernel`` implements the paper's *barrier extension* across
the devices of one mesh axis as a dissemination barrier:

  round r in 0..log2(n)-1:
      partner = (my_id XOR 2^r)
      remote-copy my arrival word to partner's slot   (signal = event line)
      wait on the receive semaphore                    (elw = restful wait)

After ``log2(n)`` rounds every device has observed every other device's
arrival -- the same all-see-all semantics the SCU barrier status register
provides, in log(n) hops instead of a shared register (adapting the
single-cycle-shared-L1 assumption to the ICI topology).

``scu_notifier_kernel`` is the *notifier extension*: a one-way remote copy
of a 32-bit payload word to a target device + semaphore signal (the paper's
mutex message-passing channel uses the same path).

Validation: the TPU interpret mode cannot execute cross-device DMAs on the
CPU backend, so tests validate (a) the single-device self-copy semantics in
interpret mode, and (b) the numerically identical collective fallback in
``ops.py`` on 8 host devices.  The kernel itself is the TPU target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

__all__ = ["scu_barrier_kernel", "scu_notifier_kernel", "scu_self_signal_kernel"]


def _barrier_body(arrive_ref, out_ref, comm_buf, send_sem, recv_sem, *, axis: str):
    """Dissemination barrier over mesh axis ``axis`` (inside shard_map)."""
    my_id = jax.lax.axis_index(axis)
    n = jax.lax.axis_size(axis)
    n_rounds = max(1, int(n).bit_length() - 1) if isinstance(n, int) else 1
    # n is static inside shard_map
    n_static = int(n)
    rounds = max(0, n_static.bit_length() - 1)

    comm_buf[0] = arrive_ref[0]

    for r in range(rounds):
        partner = jax.lax.rem(
            my_id + (1 << r), jnp.int32(n_static)
        )  # dissemination: signal (i + 2^r) mod n
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[0:1],
            dst_ref=comm_buf.at[1:2],
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=(partner,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()  # restful wait on the DMA semaphores (the elw analogue)
        # accumulate the partner's arrival word into ours
        comm_buf[0] = comm_buf[0] + comm_buf[1]

    out_ref[0] = comm_buf[0]


def scu_barrier_kernel(arrivals: jnp.ndarray, *, axis: str, interpret: bool = False):
    """All devices along ``axis`` synchronize; returns the summed arrival
    words (== n when everyone arrived).  Must run inside shard_map."""
    return pl.pallas_call(
        functools.partial(_barrier_body, axis=axis),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(arrivals.shape, arrivals.dtype),
        scratch_shapes=[
            pltpu.VMEM((2,), arrivals.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
        compiler_params=CompilerParams(has_side_effects=True),
    )(arrivals)


def _notifier_body(payload_ref, out_ref, send_sem, recv_sem, *, target, axis):
    """One-way payload word to ``target`` along ``axis`` + event signal."""
    rdma = pltpu.make_async_remote_copy(
        src_ref=payload_ref,
        dst_ref=out_ref,
        send_sem=send_sem,
        recv_sem=recv_sem,
        device_id=(jnp.int32(target),),
        device_id_type=pltpu.DeviceIdType.LOGICAL,
    )
    rdma.start()
    rdma.wait()


def scu_notifier_kernel(
    payload: jnp.ndarray, *, target: int, axis: str, interpret: bool = False
):
    """Send a 32-bit message word to ``target`` (the mutex message channel)."""
    return pl.pallas_call(
        functools.partial(_notifier_body, target=target, axis=axis),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(payload.shape, payload.dtype),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        interpret=interpret,
        compiler_params=CompilerParams(has_side_effects=True),
    )(payload)


def _self_signal_body(x_ref, o_ref, buf, sem):
    """Single-device event semantics: signal + restful wait + consume --
    the elw state machine on one core (interpret-testable on CPU)."""
    cp = pltpu.make_async_copy(x_ref, buf, sem)
    cp.start()
    cp.wait()  # blocks until the DMA event fires (event-buffer semantics)
    o_ref[...] = buf[...] + 1


def scu_self_signal_kernel(x: jnp.ndarray, *, interpret: bool = True):
    """Local DMA signal/wait roundtrip (the base-unit FSM on one core)."""
    return pl.pallas_call(
        _self_signal_body,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[
            pltpu.VMEM(x.shape, x.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(x)
