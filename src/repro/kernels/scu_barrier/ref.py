"""Oracle for the SCU barrier/notifier ops: plain psum semantics."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["barrier_ref", "self_signal_ref"]


def barrier_ref(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    return jax.lax.psum(arrive, axis)


def self_signal_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Oracle of the single-core signal/wait/consume roundtrip."""
    return x + 1
