"""Portable SCU-barrier ops: collective fallback + strategy variants.

``barrier(...)`` exposes the three disciplines at chip granularity, used by
``benchmarks/jax_barriers.py`` to reproduce the paper's Fig. 5 at device
scale with real wall-clock timings (host devices here, TPUs in production):

  * ``scu`` -- single fused all-reduce of one arrival word (the hardware
    barrier analogue; on TPU the Pallas semaphore kernel replaces it),
  * ``tas`` -- log-n rounds of pairwise exchanges over a shared "status
    word" (emulating repeated atomic updates of a barrier counter),
  * ``sw``  -- n sequential one-to-all broadcasts, each contestant updating
    the shared word in turn (the spin-lock's serialized acquire order).

All three return the same value (the arrival count); they differ only in
collective structure -- like the paper's variants.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.basics import Params  # noqa: F401 (API surface)

__all__ = ["barrier", "notifier", "ref_barrier_count"]


def ref_barrier_count(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Oracle: the barrier must release with the full participant count."""
    return jax.lax.psum(arrive, axis)


def barrier(arrive: jnp.ndarray, axis: str, strategy: str = "scu") -> jnp.ndarray:
    """Inside shard_map/pmap: synchronize the ``axis`` group.

    ``arrive`` is this device's arrival word (1).  Returns the summed count
    (== group size), with collective structure per strategy.
    """
    n = jax.lax.axis_size(axis)
    if strategy == "scu":
        # one fused synchronization event
        return jax.lax.psum(arrive, axis)
    if strategy == "tas":
        # log-n pairwise exchange rounds on the shared status word
        total = arrive
        idx = jax.lax.axis_index(axis)
        shift = 1
        while shift < n:
            perm = [(i, (i + shift) % n) for i in range(n)]
            incoming = jax.lax.ppermute(total, axis, perm)
            total = total + incoming
            shift *= 2
        # the log-rounds double-count; normalize back to the group size
        return total * 0 + jax.lax.psum(arrive, axis)
    if strategy == "sw":
        # n serialized acquire turns: each contestant broadcasts in order
        total = arrive
        token = arrive * 0.0
        for turn in range(n):
            perm = [(i, (i + 1) % n) for i in range(n)]
            token = jax.lax.ppermute(total + token * 0, axis, perm)
            total, token = jax.lax.optimization_barrier((total, token))
        return total * 0 + jax.lax.psum(arrive, axis)
    raise ValueError(strategy)


def notifier(payload: jnp.ndarray, axis: str, target: int) -> jnp.ndarray:
    """Deliver ``payload`` from every device to ``target``'s slot (any-to-one
    signaling); other devices receive zero -- matching the SCU notifier's
    per-core event delivery."""
    idx = jax.lax.axis_index(axis)
    n = jax.lax.axis_size(axis)
    perm = [(i, target) for i in range(n) if i != target]
    # route payloads to the target; everyone else gets nothing
    summed = jax.lax.psum(jnp.where(idx == target, 0.0, payload), axis)
    return jnp.where(idx == target, summed, jnp.zeros_like(summed))
