"""Portable SCU-barrier ops: collective fallback + policy dispatch.

``barrier(...)`` exposes the synchronization disciplines at chip
granularity, used by ``benchmarks/jax_barriers.py`` to reproduce the
paper's Fig. 5 at device scale with real wall-clock timings (host devices
here, TPUs in production).

The per-discipline collective bodies live on the ``repro.sync`` policy
objects (``repro/sync/policies.py`` and ``repro/sync/tree.py``); dispatch
through ``repro.sync.get_policy(name).chip_barrier`` -- :func:`barrier`
remains only as a deprecated alias.  Every discipline returns
the same value -- the arrival count, derived from the values it actually
exchanged -- and differs only in collective structure, like the paper's
variants (``ref_barrier_count`` is the test oracle for that equivalence).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.basics import Params  # noqa: F401 (API surface)

__all__ = ["barrier", "notifier", "ref_barrier_count"]


def ref_barrier_count(arrive: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Oracle: the barrier must release with the full participant count."""
    return jax.lax.psum(arrive, axis)


def barrier(arrive: jnp.ndarray, axis: str, strategy: str = "scu") -> jnp.ndarray:
    """DEPRECATED alias: call ``get_policy(strategy).chip_barrier`` directly.

    Kept as a one-line warning wrapper for external callers; every in-repo
    call site dispatches through the :mod:`repro.sync` registry.
    """
    import warnings

    from repro.sync import get_policy

    warnings.warn(
        "repro.kernels.scu_barrier.ops.barrier is deprecated; use "
        "repro.sync.get_policy(strategy).chip_barrier(arrive, axis)",
        DeprecationWarning,
        stacklevel=2,
    )
    return get_policy(strategy).chip_barrier(arrive, axis)


def notifier(payload: jnp.ndarray, axis: str, target: int) -> jnp.ndarray:
    """Deliver ``payload`` from every device to ``target``'s slot (any-to-one
    signaling); other devices receive zero -- matching the SCU notifier's
    per-core event delivery."""
    idx = jax.lax.axis_index(axis)
    # route payloads to the target; everyone else gets nothing
    summed = jax.lax.psum(jnp.where(idx == target, 0.0, payload), axis)
    return jnp.where(idx == target, summed, jnp.zeros_like(summed))
