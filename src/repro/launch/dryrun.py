"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Run as:   PYTHONPATH=src python -m repro.launch.dryrun --all
          PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-1.3b \
              --shape train_4k --mesh multi

For every cell this lowers the appropriate step function (train_step /
prefill / serve_step) against ShapeDtypeStruct inputs (no allocation),
compiles it for the production mesh, and records:

  * ``memory_analysis`` (per-device argument/output/temp bytes -- proves fit),
  * ``cost_analysis`` (per-device HLO FLOPs and bytes accessed),
  * per-collective byte counts parsed from the optimized HLO
    (all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute, with replica-group-aware wire-byte estimates),

into ``artifacts/dryrun/<mesh>/<arch>__<shape>[__tag].json`` for the
roofline analysis (benchmarks/roofline.py, EXPERIMENTS.md §Roofline).
"""

# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first initialization):
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import (  # noqa: E402
    SHAPES,
    input_specs,
    shape_applicable,
    sync_policy_choices,
)
from repro.configs.registry import get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_num_chips  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-collective (count, result bytes, estimated wire bytes per device)."""
    stats = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        lhs, rhs = stripped.split(" = ", 1)
        kind = None
        for c in _COLLECTIVES:
            # match op name at the start of the rhs expression, e.g.
            # "bf16[8]{0} all-reduce(", including -start/-done variants
            if re.match(rf"[^a-z]*{c}(-start)?\(", rhs.split(")")[0] + ")") or re.search(
                rf"\b{c}(-start)?\(", rhs.split("(")[0] + "("
            ):
                kind = c
                break
        if kind is None:
            continue
        # result shapes live between '=' and the op name
        result_seg = rhs.split(kind)[0]
        rb = _shape_bytes(result_seg)
        if rb == 0:
            continue
        m = _GROUPS_RE.search(rhs)
        g = int(m.group(2)) if m else 2  # group size; conservative default
        if kind == "all-reduce":
            wire = 2.0 * rb * (g - 1) / g
        elif kind == "all-gather":
            wire = rb * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = rb * (g - 1)  # operand = result * g
        elif kind == "all-to-all":
            wire = rb * (g - 1) / g
        else:  # collective-permute
            wire = float(rb)
        s = stats[kind]
        s["count"] += 1
        s["result_bytes"] += rb
        s["wire_bytes"] += wire
    return stats


def apply_variant(cfg, variant: str):
    """§Perf hillclimb variants: (cfg transform, train-config overrides)."""
    import dataclasses

    tkw = {}
    if not variant:
        return cfg, tkw
    for v in variant.split("+"):
        if v.startswith("ssdchunk"):
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, chunk=int(v[len("ssdchunk"):]))
            )
        elif v == "moehints":
            cfg = dataclasses.replace(cfg, moe_shard_hints=True)
        elif v == "nosp":
            tkw["sequence_parallel"] = False
        elif v.startswith("accum"):
            tkw["grad_accum"] = int(v[len("accum"):])
        elif v:
            raise ValueError(f"unknown variant {v!r}")
    return cfg, tkw


def build_cell(arch: str, shape_name: str, mesh, *, sync_strategy: str = "scu",
               remat_policy: str = "full", variant: str = "", compression: str = "none"):
    """Returns (fn, jit_kwargs, args) ready to lower."""
    cfg, tkw = apply_variant(get_config(arch), variant)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        from repro.train.optimizer import OptConfig
        from repro.train.step import TrainConfig, make_train_step

        # activation-memory knob for the very large archs
        n = cfg.n_params()
        accum = 8 if n > 90e9 else (4 if n > 20e9 else 1)
        tcfg = TrainConfig(
            sync_strategy=sync_strategy, remat_policy=remat_policy,
            grad_accum=tkw.get("grad_accum", accum),
            sequence_parallel=tkw.get("sequence_parallel", True),
            opt=OptConfig(compression=compression),
        )
        step_fn, (in_sh, batch_sh_fn), out_sh, params_sds = make_train_step(
            cfg, tcfg, mesh
        )

        # abstract optimizer state
        opt_sds = {
            "master": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_sds
            ),
            "m": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_sds
            ),
            "v": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_sds
            ),
        }
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        batch_sh = batch_sh_fn(specs)
        jit_kwargs = dict(
            in_shardings=(in_sh[0], in_sh[1], in_sh[2], batch_sh),
            out_shardings=(out_sh[0], out_sh[1], out_sh[2], None),
            donate_argnums=(0, 1),  # params + optimizer state alias in/out
        )
        args = (params_sds, opt_sds, step_sds, specs)
        return step_fn, jit_kwargs, args

    if shape.kind == "prefill":
        from repro.serve.decode import make_prefill

        prefill_fn, in_sh, out_sh, params_sds = make_prefill(
            cfg, mesh, shape.global_batch, shape.seq_len
        )
        from repro.parallel.sharding import batch_spec
        from jax.sharding import NamedSharding

        batch_sh = {
            k: NamedSharding(mesh, batch_spec(mesh, v.ndim - 1))
            for k, v in specs.items()
        }
        jit_kwargs = dict(in_shardings=(in_sh[0], batch_sh), out_shardings=out_sh)
        return prefill_fn, jit_kwargs, (params_sds, specs)

    # decode
    from repro.serve.decode import cache_shapes, make_serve_step

    serve_fn, in_sh, out_sh, params_sds = make_serve_step(
        cfg, mesh, shape.global_batch, shape.seq_len
    )
    cache_sds = cache_shapes(cfg, shape.global_batch, shape.seq_len)
    args = (params_sds, cache_sds, specs["tokens"], specs["position"])
    jit_kwargs = dict(in_shardings=in_sh, out_shardings=out_sh)
    return serve_fn, jit_kwargs, args


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             sync_strategy: str = "scu", remat_policy: str = "full",
             tag: str = "", save_hlo: bool = False, variant: str = "",
             compression: str = "none") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "sync_strategy": sync_strategy,
        "remat_policy": remat_policy,
        "applicable": ok,
    }
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / mesh_kind / f"{arch}__{shape_name}{suffix}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    if not ok:
        rec["skip_reason"] = why
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[skip] {arch} x {shape_name} ({mesh_kind}): {why}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        fn, jit_kwargs, args = build_cell(
            arch, shape_name, mesh, sync_strategy=sync_strategy,
            remat_policy=remat_policy, variant=variant, compression=compression,
        )
        with mesh:
            lowered = jax.jit(fn, **jit_kwargs).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            coll = parse_collectives(hlo)
            from repro.launch.hlo_analysis import analyze_hlo

            hs = analyze_hlo(hlo)

        rec.update(
            status="ok",
            chips=mesh_num_chips(mesh),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            cost={
                "flops_per_device": ca.get("flops"),
                "bytes_accessed_per_device": ca.get("bytes accessed"),
                "transcendentals": ca.get("transcendentals"),
            },
            collectives=coll,
            hlo_analysis={
                "dot_flops_per_device": hs.dot_flops,
                "bytes_accessed_per_device": hs.bytes_accessed,
                "transcendental_elems": hs.transcendental_elems,
                "collectives": hs.collectives,
                "wire_bytes_per_device": hs.total_wire_bytes,
                "collective_count": hs.total_collective_count,
                "while_trip_counts": hs.while_trip_counts,
            },
            model={
                "n_params": cfg.n_params(),
                "n_active_params": cfg.n_active_params(),
                "seq_len": shape.seq_len,
                "global_batch": shape.global_batch,
                "kind": shape.kind,
            },
        )
        if save_hlo:
            (out_path.with_suffix(".hlo.txt")).write_text(hlo)
        print(
            f"[ok]   {arch} x {shape_name} ({mesh_kind}/{sync_strategy}): "
            f"compile {t_compile:.1f}s, "
            f"flops/dev {ca.get('flops', 0):.3e}, "
            f"temp {ma.temp_size_in_bytes/2**30:.2f} GiB"
        )
    except Exception as e:  # noqa: BLE001 - record and continue
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {arch} x {shape_name} ({mesh_kind}): {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all archs x shapes")
    ap.add_argument("--sync", default="scu", choices=list(sync_policy_choices()))
    ap.add_argument("--remat", default="full")
    ap.add_argument("--tag", default="")
    ap.add_argument("--variant", default="", help="e.g. ssdchunk128, moehints")
    ap.add_argument("--compression", default="none", choices=["none", "int8"])
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(
                    arch, shape, mesh_kind, out_dir,
                    sync_strategy=args.sync, remat_policy=args.remat,
                    tag=args.tag, save_hlo=args.save_hlo,
                    variant=args.variant, compression=args.compression,
                )
                if rec.get("status") == "error":
                    n_fail += 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
