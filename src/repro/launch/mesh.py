"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state -- the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.

Meshes:
  * single-pod:  (data=16, model=16)            -- 256 chips (one v5e pod)
  * multi-pod:   (pod=2, data=16, model=16)     -- 512 chips (2 pods)

The "model" axis carries TP/EP/SP; "data" (x "pod") carries DP/ZeRO.  The
"pod" axis is the slow (DCN-ish) outer domain -- the hierarchical analogue
of the paper's single-cluster focus (DESIGN.md Sec. 6.3).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.compat import make_axis_mesh

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_num_chips"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_axis_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: Optional[int] = None) -> Mesh:
    """Small mesh over whatever host devices exist (tests / examples)."""
    n = len(jax.devices())
    want = data * model * (pod or 1)
    assert n >= want, f"need {want} devices, have {n}"
    if pod:
        return make_axis_mesh((pod, data, model), ("pod", "data", "model"))
    return make_axis_mesh((data, model), ("data", "model"))


def mesh_num_chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
