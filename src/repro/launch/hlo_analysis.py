"""Trip-count-aware analysis of optimized HLO text.

``jax.stages.Compiled.cost_analysis()`` counts every while-loop body ONCE --
with scan-over-layers that understates FLOPs, bytes, and collective traffic
by the layer count.  This module parses the optimized HLO, builds a symbol
table per computation (operand shapes are not inline in the modern HLO
dialect), builds the computation call graph (while bodies weighted by
``known_trip_count``, fusions, calls, conditionals) and accumulates:

  * ``dot_flops``          -- 2 * prod(result) * prod(contracting dims),
                              weighted by the execution multiplier;
  * ``bytes_accessed``     -- sum of (operand + result) bytes of top-level
                              instructions per computation (fusion-boundary
                              buffers ~ HBM traffic on TPU), weighted;
  * per-collective counts / result bytes / estimated wire bytes (ring-model
    per-device estimates using replica group sizes), weighted.

This is the data source for the roofline terms in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloSummary", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)')
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"  # result name
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+"  # result shape(s)
    r"([\w\-]+)\("  # op name
)
_HEADER_PARAM = re.compile(r"([\w\.\-]+)\s*:\s*([a-z0-9]+\[[\d,]*\])")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_CALLEE_BODY = re.compile(r"body=%?([\w\.\-]+)")
_CALLEE_CTRL = [
    re.compile(r"condition=%?([\w\.\-]+)"),
    re.compile(r"true_computation=%?([\w\.\-]+)"),
    re.compile(r"false_computation=%?([\w\.\-]+)"),
]
_CALLEE_FUSED = [
    re.compile(r"calls=%?([\w\.\-]+)"),
    re.compile(r"to_apply=%?([\w\.\-]+)"),
]
_CALLEE_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_COLL_CANON = {
    "all-reduce": "all-reduce", "all-reduce-start": "all-reduce",
    "all-gather": "all-gather", "all-gather-start": "all-gather",
    "reduce-scatter": "reduce-scatter",
    "all-to-all": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-permute-start": "collective-permute",
}

# ops with no real memory traffic of their own
_FREE_OPS = {
    "get-tuple-element", "bitcast", "tuple", "parameter", "constant", "iota",
    "reshape", "after-all", "opt-barrier", "partition-id", "replica-id",
}


def _shapes_in(seg: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(shapes: List[Tuple[str, Tuple[int, ...]]]) -> float:
    return float(sum(_DTYPE_BYTES[dt] * math.prod(s or (1,)) for dt, s in shapes))


@dataclasses.dataclass
class HloSummary:
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    transcendental_elems: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(c["wire_bytes"] for c in self.collectives.values())

    @property
    def total_collective_count(self) -> float:
        return sum(c["count"] for c in self.collectives.values())


@dataclasses.dataclass
class _Comp:
    name: str
    is_entry: bool
    header: str
    lines: List[str] = dataclasses.field(default_factory=list)
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = dataclasses.field(
        default_factory=dict
    )


def _parse(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry: Optional[str] = None
    current: Optional[_Comp] = None
    depth = 0
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if current is None:
            if stripped.endswith("{") and "->" in stripped:
                is_entry = stripped.startswith("ENTRY")
                name_m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
                if not name_m:
                    continue
                current = _Comp(name_m.group(1), is_entry, stripped)
                comps[current.name] = current
                if is_entry:
                    entry = current.name
                # header params populate the symbol table
                for pname, pshape in _HEADER_PARAM.findall(stripped):
                    current.symbols[pname] = _shapes_in(pshape)
                depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            current = None
            continue
        if " = " in stripped:
            current.lines.append(stripped)
            m = _INSTR_RE.match(stripped)
            if m:
                current.symbols[m.group(1)] = _shapes_in(m.group(2))
    return comps, entry


def _callees(line: str) -> List[Tuple[str, str]]:
    """(callee, kind) where kind in {body, branch, fused}."""
    out = []
    for name in _CALLEE_BODY.findall(line):
        out.append((name, "body"))
    for rx in _CALLEE_CTRL:
        for name in rx.findall(line):
            out.append((name, "branch"))
    for rx in _CALLEE_FUSED:
        for name in rx.findall(line):
            out.append((name, "fused"))
    for grp in _CALLEE_BRANCHES.findall(line):
        for name in grp.split(","):
            name = name.strip().lstrip("%")
            if name:
                out.append((name, "branch"))
    return out


def analyze_hlo(text: str) -> HloSummary:
    comps, entry = _parse(text)
    summary = HloSummary(
        collectives={
            c: {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0}
            for c in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute")
        }
    )
    if entry is None:
        return summary

    # --- execution multiplier per computation (fixed point over call graph) --
    # "control" computations (entry, while bodies/conds, conditional branches)
    # own their instructions' memory traffic; fusion/reduce bodies (reached
    # via calls=/to_apply=) only contribute dot FLOPs -- their internal ops
    # live in registers/VMEM, the fusion *call site* accounts the HBM bytes.
    mult: Dict[str, float] = {entry: 1.0}
    control: Dict[str, bool] = {entry: True}
    for _ in range(64):
        changed = False
        for comp in comps.values():
            w = mult.get(comp.name)
            if not w:
                continue
            for line in comp.lines:
                trip = 1.0
                tm = _TRIP_RE.search(line)
                if tm and " while(" in line:
                    trip = float(tm.group(1))
                for callee, kind in _callees(line):
                    if callee not in comps:
                        continue
                    weight = trip if kind == "body" else 1.0
                    new = w * weight
                    if mult.get(callee, 0.0) < new:
                        mult[callee] = new
                        changed = True
                    is_ctrl = control.get(comp.name, False) and kind in (
                        "body", "branch",
                    )
                    if is_ctrl and not control.get(callee, False):
                        control[callee] = True
                        changed = True
        if not changed:
            break

    # --- effective boundary bytes of fusion computations ---------------------
    # A fusion's real HBM traffic is its *boundary*: params read + root
    # written -- except params that are only dynamic-sliced inside (read the
    # slice, not the buffer) and dynamic-update-slice roots (write the update
    # region; the buffer aliases in place).
    fusion_bytes: Dict[str, float] = {}
    for comp in comps.values():
        header_params = dict(_HEADER_PARAM.findall(comp.header))
        in_bytes = 0.0
        # usage analysis per param
        for pname, pshape in header_params.items():
            full = _bytes_of(_shapes_in(pshape))
            refs = [ln for ln in comp.lines if re.search(rf"%{re.escape(pname)}\b", ln.split(" = ", 1)[-1])]
            if refs and all(
                _INSTR_RE.match(r) and _INSTR_RE.match(r).group(3) == "dynamic-slice"
                and _OPERAND_RE.findall(r.split("(", 1)[1])[:1] == [pname]
                for r in refs
            ):
                in_bytes += sum(
                    _bytes_of(_shapes_in(_INSTR_RE.match(r).group(2))) for r in refs
                )
            else:
                in_bytes += full
        out_bytes = 0.0
        for ln in comp.lines:
            if not ln.startswith("ROOT"):
                continue
            m = _INSTR_RE.match(ln)
            if not m:
                break
            _rn, rseg, rop = m.groups()
            if rop == "dynamic-update-slice":
                onames = _OPERAND_RE.findall(ln.split("(", 1)[1])
                upd = comp.symbols.get(onames[1], []) if len(onames) > 1 else []
                out_bytes += _bytes_of(upd)
            else:
                out_bytes += _bytes_of(_shapes_in(rseg))
            break
        fusion_bytes[comp.name] = in_bytes + out_bytes

    # --- accumulate per instruction ------------------------------------------
    for comp in comps.values():
        w = mult.get(comp.name, 0.0)
        if w == 0.0:
            continue
        is_control = control.get(comp.name, False)
        for line in comp.lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            _res_name, result_seg, op = m.groups()
            result_shapes = _shapes_in(result_seg)
            rb = _bytes_of(result_shapes)

            if op == "while":
                tm = _TRIP_RE.search(line)
                if tm:
                    summary.while_trip_counts.append(int(tm.group(1)))
                continue  # body costs attributed via multipliers

            # operand shapes via the computation symbol table
            args_seg = line.split("(", 1)[1].split(")", 1)[0] if "(" in line else ""
            operand_names = _OPERAND_RE.findall(args_seg)
            opshapes: List[Tuple[str, Tuple[int, ...]]] = []
            for on in operand_names:
                opshapes.extend(comp.symbols.get(on, []))

            if op in ("dot", "convolution"):
                if result_shapes:
                    res_elems = math.prod(result_shapes[0][1] or (1,))
                    cprod = 1
                    cm = _DOT_CONTRACT.search(line)
                    lhs_shapes = comp.symbols.get(operand_names[0], []) if operand_names else []
                    lhs = lhs_shapes[0][1] if lhs_shapes else ()
                    if cm is not None and lhs:
                        cdims = [int(d) for d in cm.group(1).split(",") if d]
                        cprod = math.prod([lhs[d] for d in cdims if d < len(lhs)] or [1])
                    summary.dot_flops += w * 2.0 * res_elems * cprod

            canon = _COLL_CANON.get(op)
            if canon is not None:
                g = 2.0
                gi = _GROUPS_IOTA.search(line)
                if gi:
                    g = float(gi.group(2))
                else:
                    gl = _GROUPS_LIST.search(line)
                    if gl:
                        g = float(len([x for x in gl.group(1).split(",") if x.strip()]))
                if canon == "all-reduce":
                    wire = 2.0 * rb * (g - 1) / g
                elif canon == "all-gather":
                    wire = rb * (g - 1) / g
                elif canon == "reduce-scatter":
                    wire = rb * (g - 1)
                elif canon == "all-to-all":
                    wire = rb * (g - 1) / g
                else:
                    wire = float(rb)
                c = summary.collectives[canon]
                c["count"] += w
                c["result_bytes"] += w * rb
                c["wire_bytes"] += w * wire
                summary.bytes_accessed += w * 2 * rb
                continue

            if op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                      "logistic", "sine", "cosine") and result_shapes:
                summary.transcendental_elems += w * math.prod(
                    result_shapes[0][1] or (1,)
                )

            if op in _FREE_OPS or not is_control:
                continue
            if op == "fusion":
                callee = next(
                    (c for c, k in _callees(line) if k == "fused" and c in fusion_bytes),
                    None,
                )
                summary.bytes_accessed += w * (
                    fusion_bytes[callee] if callee else rb + _bytes_of(opshapes)
                )
            elif op == "dynamic-slice":
                # reads only the slice (plus writes it): NOT the full buffer
                summary.bytes_accessed += w * 2 * rb
            elif op == "dynamic-update-slice":
                # reads + writes the updated region only (result aliases the
                # buffer); the update operand is the second argument
                upd = (
                    _bytes_of(comp.symbols.get(operand_names[1], []))
                    if len(operand_names) > 1
                    else rb
                )
                summary.bytes_accessed += w * 2 * upd
            else:
                summary.bytes_accessed += w * (rb + _bytes_of(opshapes))
    return summary
