"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --steps 200 --batch 8 --seq 64 --smoke --mesh host

``--mesh host`` uses whatever host devices exist (tests/examples);
``--mesh single|multi`` builds the production mesh (requires the 512-device
environment of the dry-run).  Checkpointing/resume via ``--ckpt-dir``.
"""

from __future__ import annotations

import argparse


def main() -> None:
    from repro.configs.base import sync_policy_choices

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sync", default="scu", choices=list(sync_policy_choices()))
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--mesh", default="host")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    from repro.configs.registry import get_config, get_smoke_config
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.train.data import SyntheticLM
    from repro.train.loop import TrainerConfig, train
    from repro.train.optimizer import OptConfig
    from repro.train.step import TrainConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh == "host":
        import jax

        n = len(jax.devices())
        model = 2 if n >= 4 else 1
        mesh = make_host_mesh(data=n // model, model=model)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=10),
        sync_strategy=args.sync,
        remat_policy=args.remat,
        grad_accum=args.grad_accum,
    )
    trainer = TrainerConfig(
        steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        log_every=10,
    )
    train(cfg, tcfg, trainer, mesh, lambda i: data.batch(i, batch_size=args.batch))


if __name__ == "__main__":
    main()
