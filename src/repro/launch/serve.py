"""Serving launcher: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config, get_smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.lm import init_lm
    from repro.serve.decode import init_cache, make_prefill, make_serve_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n = len(jax.devices())
    model = 2 if n >= 4 else 1
    mesh = make_host_mesh(data=n // model, model=model)
    max_seq = args.prompt_len + args.gen

    with mesh:
        params = jax.jit(lambda k: init_lm(k, cfg, jnp.bfloat16))(jax.random.PRNGKey(0))
        prefill_fn, _, _, _ = make_prefill(cfg, mesh, args.batch, args.prompt_len)
        serve_fn, _, _, _ = make_serve_step(cfg, mesh, args.batch, max_seq)
        serve_fn = jax.jit(serve_fn)

        key = jax.random.PRNGKey(1)
        tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
        inputs = (
            {"embeddings": jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)}
            if cfg.frontend
            else {"tokens": tokens}
        )
        t0 = time.time()
        logits, _small_cache = jax.jit(prefill_fn)(params, inputs)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        print(f"[serve] prefill({args.batch}x{args.prompt_len}) {time.time()-t0:.2f}s")

        # decode against a max_seq cache (prefill cache re-staged into it)
        cache = init_cache(cfg, args.batch, max_seq)
        position = jnp.full((args.batch,), args.prompt_len, jnp.int32)
        out = [next_tok]
        t0 = time.time()
        for i in range(args.gen):
            next_tok, _logits, cache = serve_fn(params, cache, next_tok[:, None], position + i)
            out.append(next_tok)
        jax.block_until_ready(next_tok)
        dt = time.time() - t0
        print(
            f"[serve] decoded {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
            f"({args.gen*args.batch/dt:.1f} tok/s)"
        )
        print("[serve] sample continuation:", [int(t[0]) for t in out][:10])


if __name__ == "__main__":
    main()
