"""Architecture registry: the ten assigned configs + reduced smoke variants.

Every entry lists the exact published configuration from the assignment
(``[source]`` per config docstring) and a ``smoke`` reduction of the same
family for CPU tests (small widths/depths/experts/vocab, same structural
features so the code paths are identical).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from .base import MLAConfig, ModelConfig, MoEConfig, SSMConfig

__all__ = ["ARCHS", "get_config", "get_smoke_config", "list_archs"]


def _mamba2_1p3b() -> ModelConfig:
    # [ssm] 48L d_model=2048 (attn-free) vocab=50280, ssm_state=128 -- SSD
    # [arXiv:2405.21060]
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=1,  # no attention; SSD heads derive from ssm config
        n_kv_heads=1,
        d_ff=0,  # mamba2 blocks are norm + mixer only (no FFN), per assignment
        vocab_size=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
        tie_embeddings=True,
        use_rope=False,
    )


def _jamba_52b() -> ModelConfig:
    # [hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
    # MoE 16e top-2 -- Mamba+attn 1:7 interleave [arXiv:2403.19887]
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every_k=2),
        attn_every=8,
        block_group=8,
        use_rope=False,  # jamba uses no positional embeddings (Mamba provides order)
    )


def _musicgen_medium() -> ModelConfig:
    # [audio] 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 -- decoder-only
    # over EnCodec tokens [arXiv:2306.05284]; frontend stubbed (embeddings in).
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        act="gelu",
        norm="layernorm",
        use_rope=False,  # sinusoidal positions
        frontend="audio",
    )


def _deepseek_v2_lite() -> ModelConfig:
    # [moe] 27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, MoE 64e top-6,
    # MLA kv_lora=512, 2 shared experts, first layer dense [arXiv:2405.04434]
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=192,  # qk_nope 128 + rope 64
        d_ff=10944,  # dense FFN width of the first (non-MoE) layer
        vocab_size=102400,
        mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(
            n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2, first_k_dense=1
        ),
    )


def _qwen3_moe_30b() -> ModelConfig:
    # [moe] 48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768 vocab=151936,
    # MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B]
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    )


def _command_r_plus() -> ModelConfig:
    # [dense] 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000 --
    # parallel block, no bias [hf:CohereForAI/c4ai-command-r-plus]
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        parallel_block=True,
        norm="layernorm",
        tie_embeddings=True,
        rope_theta=75_000_000.0,
    )


def _phi4_mini() -> ModelConfig:
    # [dense] 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 --
    # RoPE (partial 0.75) SwiGLU GQA [arXiv:2412.08905]
    return ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        rope_fraction=0.75,
        tie_embeddings=True,
    )


def _stablelm_3b() -> ModelConfig:
    # [dense] 32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304 --
    # LayerNorm, partial rotary 0.25 [hf:stabilityai/stablelm-3b-4e1t]
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
        norm="layernorm",
        rope_fraction=0.25,
    )


def _codeqwen_7b() -> ModelConfig:
    # [dense] 32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416 --
    # qwen1.5 arch: QKV bias [hf:Qwen/CodeQwen1.5-7B]
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )


def _llava_next_34b() -> ModelConfig:
    # [vlm] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 --
    # anyres tiling; vision frontend stubbed (patch embeddings in)
    # [hf:llava-hf/llava-v1.6-34b-hf backbone]
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        frontend="vision",
        rope_theta=5_000_000.0,
    )


ARCHS: Dict[str, Callable[[], ModelConfig]] = {
    "mamba2-1.3b": _mamba2_1p3b,
    "jamba-v0.1-52b": _jamba_52b,
    "musicgen-medium": _musicgen_medium,
    "deepseek-v2-lite-16b": _deepseek_v2_lite,
    "qwen3-moe-30b-a3b": _qwen3_moe_30b,
    "command-r-plus-104b": _command_r_plus,
    "phi4-mini-3.8b": _phi4_mini,
    "stablelm-3b": _stablelm_3b,
    "codeqwen1.5-7b": _codeqwen_7b,
    "llava-next-34b": _llava_next_34b,
}


def list_archs():
    return sorted(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return ARCHS[name]()


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: tiny widths, few layers/experts, small vocab."""
    full = get_config(name)
    kw = dict(
        name=full.name + "-smoke",
        n_layers=4 if full.block_group == 1 else full.block_group,
        d_model=64,
        d_ff=0 if full.d_ff == 0 else 128,
        vocab_size=128,
    )
    if full.family == "ssm":
        kw.update(n_heads=1, n_kv_heads=1)
    else:
        # keep the GQA ratio when possible
        ratio = max(1, full.n_heads // full.n_kv_heads)
        kw.update(n_heads=4, n_kv_heads=max(1, 4 // ratio), head_dim=16)
    if full.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            full.ssm, d_state=16, head_dim=16, expand=2, n_groups=1
        )
    if full.moe is not None:
        kw["moe"] = dataclasses.replace(
            full.moe,
            n_experts=8,
            top_k=2,
            d_ff_expert=64,
            first_k_dense=min(full.moe.first_k_dense, 1),
        )
        if full.moe.first_k_dense > 0:
            kw["n_layers"] = kw["n_layers"] + 1
    if full.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        kw["head_dim"] = 24
    return dataclasses.replace(full, **kw)
