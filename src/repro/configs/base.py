"""Model / run configuration system.

One :class:`ModelConfig` dataclass covers all ten assigned architecture
families (dense / GQA / MLA / MoE / SSM / hybrid / audio / vlm backbones).
Architecture files in this package (``src/repro/configs/<id>.py``) expose
``CONFIG`` with the exact published numbers and ``smoke()`` with a reduced
same-family variant for CPU tests.

Input shapes (assigned): ``train_4k``, ``prefill_32k``, ``decode_32k``,
``long_500k`` -- see :data:`SHAPES` and :func:`input_specs`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "input_specs",
    "sync_policy_choices",
    "validate_sync_policy",
]


def sync_policy_choices() -> Tuple[str, ...]:
    """Registered ``repro.sync`` policy names -- the valid values for every
    sync-policy config field / CLI flag (launchers build argparse choices
    from this, so new registered disciplines appear everywhere at once)."""
    from repro.sync import available_policies  # deferred: keep configs light

    return available_policies()


def validate_sync_policy(name: str) -> str:
    """Canonicalize a sync-policy config value against the registry.

    Returns the canonical (lowercase) registered name; raises ``KeyError``
    naming the available policies for anything unknown.
    """
    from repro.sync import canonical_name  # deferred: keep configs light

    return canonical_name(name)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int  # routed experts
    top_k: int
    d_ff_expert: int  # per-expert FFN hidden size
    n_shared: int = 0  # shared (always-on) experts, deepseek-style
    first_k_dense: int = 0  # leading dense layers (deepseek-v2: 1)
    every_k: int = 1  # MoE layer every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # renormalize top-k weights


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- normalization / residual topology ----------------------------------
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    parallel_block: bool = False  # command-r: attn and MLP in parallel
    act: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False  # qwen3-style per-head RMSNorm on q/k
    tie_embeddings: bool = False
    # --- positions -----------------------------------------------------------
    use_rope: bool = True
    rope_fraction: float = 1.0  # partial rotary (phi-4: 0.75, stablelm: 0.25)
    rope_theta: float = 10_000.0
    # --- mixture / attention variants / ssm ----------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1  # hybrid (jamba): attention layer every k-th, SSM else
    # --- modality frontend (stub: precomputed embeddings) ---------------------
    frontend: Optional[str] = None  # audio | vision
    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"  # activation / weight compute dtype
    # --- scan over layers -----------------------------------------------------
    scan_layers: bool = True
    block_group: int = 1  # layers per scan step (jamba: 8)
    # --- perf variants (§Perf hillclimb levers) --------------------------------
    moe_shard_hints: bool = False  # constrain MoE dispatch to EP sharding


    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, layer_idx: int) -> str:
        """'attn' or 'ssm' mixer for layer ``layer_idx``."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            # jamba: one attention layer per group of ``attn_every`` layers
            # (placed in the middle of the group, as in the released model)
            return "attn" if layer_idx % self.attn_every == self.attn_every // 2 else "ssm"
        return "attn"

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        if layer_idx < self.moe.first_k_dense:
            return False
        return (layer_idx - self.moe.first_k_dense) % self.moe.every_k == 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            if self.layer_kind(i) == "attn":
                if self.mla is not None:
                    m = self.mla
                    total += d * h * (m.qk_nope_dim + m.qk_rope_dim)  # W_q
                    total += d * (m.kv_lora_rank + m.qk_rope_dim)  # W_dkv + W_kr
                    total += m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
                    total += h * m.v_head_dim * d  # W_o
                else:
                    total += d * (h + 2 * kv) * hd + h * hd * d
            else:
                s = self.ssm
                d_in = s.expand * d
                conv_dim = d_in + 2 * s.n_groups * s.d_state
                n_h = d_in // s.head_dim
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state + n_h)  # in_proj
                total += conv_dim * s.d_conv + d_in * d + 2 * n_h  # conv, out, A/D
            if self.layer_is_moe(i):
                m = self.moe
                total += m.n_experts * 3 * d * m.d_ff_expert
                total += m.n_shared * 3 * d * m.d_ff_expert
                total += d * m.n_experts  # router
            else:
                mult = 3 if self.act == "swiglu" else 2
                total += mult * d * self.d_ff
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        total = self.n_params()
        # subtract the inactive routed experts' weights
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        inactive = (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return total - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable cell (assignment rules)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is a full-attention arch (skip per assignment)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the step function.

    ``train``/``prefill``: token ids + labels (or stub embeddings for
    audio/vlm frontends).  ``decode``: one new token per sequence plus the
    current position; the KV/SSM cache is part of the step *state*, built by
    ``serve.decode.init_cache_specs``.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend is not None:
            # modality stub: precomputed frame/patch embeddings
            return {
                "embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    # decode: one token step against a cache of length s
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "position": jax.ShapeDtypeStruct((b,), i32),
    }
