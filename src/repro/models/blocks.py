"""Decoder blocks: sequential / parallel-residual / hybrid, dense or MoE FFN.

A *block* is one transformer layer: mixer (attention / MLA / SSD) + FFN
(dense MLP or MoE), pre-norm residual.  ``command-r``-style architectures use
a parallel residual (one input norm, attn and MLP both read it).

Blocks are grouped for ``lax.scan``: :func:`group_pattern` returns the
periodic (kind, is_moe) pattern of one scan group so heterogeneous stacks
(Jamba's 1:7 SSM:attention interleave with MoE every other layer) scan over
*groups* with a fixed internal structure.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers.attention import attention_apply, init_attention, init_mla, mla_apply
from .layers.basics import apply_norm, init_mlp, init_norm, mlp_apply
from .layers.moe import init_moe, moe_apply
from .layers.ssm import init_ssm, ssm_apply

Params = Dict[str, jnp.ndarray]

__all__ = ["group_pattern", "init_block", "block_apply", "prelude_layers"]


def prelude_layers(cfg: ModelConfig) -> int:
    """Leading layers that do not fit the periodic scan pattern."""
    return cfg.moe.first_k_dense if cfg.moe is not None else 0


def group_pattern(cfg: ModelConfig) -> List[Tuple[str, bool]]:
    """(mixer kind, is_moe) for each position of one scan group."""
    pre = prelude_layers(cfg)
    return [
        (cfg.layer_kind(pre + p), cfg.layer_is_moe(pre + p))
        for p in range(cfg.block_group)
    ]


def init_block(
    key: jax.Array, cfg: ModelConfig, layer_idx: int, dtype=jnp.float32
) -> Params:
    """Parameters of one layer (mixer + FFN + norms)."""
    kind = cfg.layer_kind(layer_idx)
    is_moe = cfg.layer_is_moe(layer_idx)
    k_mix, k_ffn = jax.random.split(key)
    p: Params = {"norm1": init_norm(cfg.norm, cfg.d_model)}
    if kind == "attn":
        p["mixer"] = (
            init_mla(k_mix, cfg, dtype) if cfg.mla is not None else init_attention(k_mix, cfg, dtype)
        )
    else:
        p["mixer"] = init_ssm(k_mix, cfg, dtype)
    if is_moe:
        p["ffn"] = init_moe(k_ffn, cfg, dtype)
    elif cfg.d_ff > 0:
        p["ffn"] = init_mlp(k_ffn, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    if not cfg.parallel_block and "ffn" in p:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
    return p


def _mixer(
    p: Params,
    cfg: ModelConfig,
    kind: str,
    x: jnp.ndarray,
    positions: Optional[jnp.ndarray],
) -> jnp.ndarray:
    if kind == "attn":
        if cfg.mla is not None:
            return mla_apply(p, cfg, x, positions)
        return attention_apply(p, cfg, x, positions)
    return ssm_apply(p, cfg, x)


def _ffn(p: Params, cfg: ModelConfig, is_moe: bool, x: jnp.ndarray) -> jnp.ndarray:
    if is_moe:
        return moe_apply(p, cfg, x)
    return mlp_apply(p, x, cfg.act)


def block_apply(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    kind: str,
    is_moe: bool,
    positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One layer, full-sequence path (training / prefill)."""
    has_ffn = "ffn" in p
    if cfg.parallel_block:
        h = apply_norm(p["norm1"], x, cfg.norm)
        out = x + _mixer(p["mixer"], cfg, kind, h, positions)
        if has_ffn:
            out = out + _ffn(p["ffn"], cfg, is_moe, h)
        return out
    h = apply_norm(p["norm1"], x, cfg.norm)
    x = x + _mixer(p["mixer"], cfg, kind, h, positions)
    if has_ffn:
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + _ffn(p["ffn"], cfg, is_moe, h)
    return x
