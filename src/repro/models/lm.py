"""Causal LM assembly: embeddings -> (prelude + scanned groups) -> norm -> head.

Layers are stacked and iterated with ``jax.lax.scan`` so the lowered HLO is
O(1) in depth -- essential for compiling 48-64-layer models for 512 devices.
Heterogeneous stacks (Jamba) scan over *groups* with a fixed internal
pattern (see :func:`repro.models.blocks.group_pattern`).

Remat: the scan body is wrapped in ``jax.checkpoint`` with a configurable
policy ("none" | "dots" | "full") -- "dots" saves matmul outputs and
recomputes the rest, the standard memory/compute trade for long sequences.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .blocks import block_apply, group_pattern, init_block, prelude_layers
from .layers.basics import apply_norm, embed, init_embedding, init_norm, unembed

Params = Dict[str, jnp.ndarray]

__all__ = ["init_lm", "lm_forward", "lm_logits", "lm_loss", "REMAT_POLICIES"]

REMAT_POLICIES = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "full": jax.checkpoint_policies.nothing_saveable,
}


def residual_spec_div(spec) -> int:
    """Mesh divisor implied by a sequence-sharded residual spec (for the
    divisibility guard); NamedShardings carry their mesh."""
    try:
        mesh = spec.mesh  # NamedSharding
        axis = spec.spec[1]
    except AttributeError:
        return 1
    if axis is None:
        return 1
    names = axis if isinstance(axis, tuple) else (axis,)
    d = 1
    for n in names:
        d *= mesh.shape[n]
    return d


def _tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    pre = prelude_layers(cfg)
    body = cfg.n_layers - pre
    assert body % cfg.block_group == 0, (cfg.n_layers, pre, cfg.block_group)
    n_groups = body // cfg.block_group

    k_embed, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)

    params: Params = {
        "embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(k_head, cfg.vocab_size, cfg.d_model, dtype)

    for i in range(pre):
        params[f"prelude_{i}"] = init_block(layer_keys[i], cfg, i, dtype)

    groups = []
    for g in range(n_groups):
        group = {}
        for p_idx in range(cfg.block_group):
            li = pre + g * cfg.block_group + p_idx
            group[f"pos_{p_idx}"] = init_block(layer_keys[li], cfg, li, dtype)
        groups.append(group)
    params["blocks"] = _tree_stack(groups)
    return params


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,
    embeddings: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
    remat_policy: str = "dots",
    residual_spec=None,
    embed_grad_spec=None,
) -> jnp.ndarray:
    """Returns final hidden states (b, s, d_model) in compute dtype."""
    dtype = jnp.dtype(cfg.dtype)
    if embeddings is None:
        x = embed(params["embed"], tokens, dtype, grad_sharding=embed_grad_spec)
    else:
        x = embeddings.astype(dtype)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    if not cfg.use_rope:
        # learned-position-free archs (musicgen backbone): sinusoidal adds
        d = cfg.d_model
        inv = 1.0 / (10_000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
        ang = positions[:, None].astype(jnp.float32) * inv
        pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pos_emb.astype(dtype)[None]

    # sequence-parallel residual stream: the scan carry (the only activation
    # saved per layer group under full remat) is sharded over the model axis
    # along the sequence -- required for the 100B-class archs to fit HBM
    def constrain(x):
        if residual_spec is not None and s % residual_spec_div(residual_spec) == 0:
            return jax.lax.with_sharding_constraint(x, residual_spec)
        return x

    pattern = group_pattern(cfg)
    pre = prelude_layers(cfg)
    x = constrain(x)
    for i in range(pre):
        x = block_apply(
            params[f"prelude_{i}"], cfg, x, cfg.layer_kind(i), cfg.layer_is_moe(i), positions
        )
        x = constrain(x)

    def group_body(x, group_params):
        for p_idx, (kind, is_moe) in enumerate(pattern):
            x = block_apply(
                group_params[f"pos_{p_idx}"], cfg, x, kind, is_moe, positions
            )
        return constrain(x), None

    policy = REMAT_POLICIES.get(remat_policy)
    if remat_policy != "none":
        group_body = jax.checkpoint(group_body, policy=policy)

    x, _ = jax.lax.scan(group_body, x, params["blocks"])
    return apply_norm(params["final_norm"], x, cfg.norm)


def lm_logits(params: Params, cfg: ModelConfig, hidden: jnp.ndarray) -> jnp.ndarray:
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(head, hidden)


def lm_loss(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    remat_policy: str = "dots",
    residual_spec=None,
    embed_grad_spec=None,
    logits_spec=None,
) -> jnp.ndarray:
    """Mean next-token cross-entropy.  ``batch``: tokens/embeddings + labels.

    The gold-logit extraction is a masked sum (not take_along_axis): with the
    vocabulary sharded over "model", a cross-vocab gather would force XLA to
    replicate the (tokens, vocab) logits -- tens of GiB for 256k vocabs.  The
    masked sum keeps every op elementwise/reduce over the sharded axis.
    """
    hidden = lm_forward(
        params,
        cfg,
        tokens=batch.get("tokens"),
        embeddings=batch.get("embeddings"),
        remat_policy=remat_policy,
        residual_spec=residual_spec,
        embed_grad_spec=embed_grad_spec,
    )
    labels = batch["labels"]
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]

    def chunk_loss(h_chunk, l_chunk):
        """Summed CE of one sequence chunk -- the full-sequence logits (a
        multi-GiB f32 buffer for 256k vocabs) never materialize."""
        logits = unembed(head, h_chunk).astype(jnp.float32)
        if logits_spec is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_spec)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        vocab_iota = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1
        )
        gold = jnp.sum(
            jnp.where(vocab_iota == l_chunk[..., None], logits, 0.0), axis=-1
        )
        return jnp.sum(logz - gold)

    b, s, _ = hidden.shape
    n_chunks = max(1, s // 2048)
    if s % n_chunks == 0 and n_chunks > 1:
        hc = hidden.reshape(b, n_chunks, s // n_chunks, -1).swapaxes(0, 1)
        lc = labels.reshape(b, n_chunks, s // n_chunks).swapaxes(0, 1)

        def body(acc, xs):
            h, l = xs
            return acc + chunk_loss(h, l), None

        total, _ = jax.lax.scan(
            jax.checkpoint(body), jnp.zeros((), jnp.float32), (hc, lc)
        )
    else:
        total = chunk_loss(hidden, labels)
    return total / (b * s)
