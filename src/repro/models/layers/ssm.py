"""Mamba-2 (SSD -- state-space duality) mixer layer.

Implements the chunked "dual" form of the SSD recurrence (Dao & Gu, 2024,
arXiv:2405.21060 Listing 1): within-chunk attention-like matmuls + an
inter-chunk recurrence over compressed states -- matmul-dominated and
MXU-friendly.  The pure-jnp implementation here is also the oracle for the
``repro/kernels/ssd_scan`` Pallas kernel.

Layer structure follows mamba2 with the input projection *split by
component* (z | x | B | C | dt) so tensor parallelism can shard the
d_inner-sized components (z, x -- and with them the SSD heads) over the
``model`` axis while the small B/C/dt projections stay replicated.  This is
a column partition of the fused in_proj -- mathematically identical.

A single-token recurrent step for decoding is provided
(:func:`ssm_decode_step`).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from .basics import init_dense, dense, rmsnorm

Params = Dict[str, jnp.ndarray]

__all__ = [
    "init_ssm",
    "ssm_apply",
    "ssd_chunked",
    "ssd_recurrent",
    "ssm_decode_step",
    "ssm_state_shapes",
]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim, s.n_groups, s.d_state


def init_ssm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim, g, n = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        # input projections, split by component for clean TP sharding
        "in_z": init_dense(ks[0], d, d_inner, dtype=dtype),
        "in_x": init_dense(ks[1], d, d_inner, dtype=dtype),
        "in_B": init_dense(ks[2], d, g * n, dtype=dtype),
        "in_C": init_dense(ks[3], d, g * n, dtype=dtype),
        "in_dt": init_dense(ks[4], d, n_heads, dtype=dtype),
        # causal depthwise conv per component (x | B | C)
        "conv_x": jax.random.normal(ks[5], (s.d_conv, d_inner), dtype) * 0.2,
        "conv_B": jax.random.normal(ks[6], (s.d_conv, g * n), dtype) * 0.2,
        "conv_C": jax.random.normal(ks[7], (s.d_conv, g * n), dtype) * 0.2,
        "conv_bx": jnp.zeros((d_inner,), dtype),
        "conv_bB": jnp.zeros((g * n,), dtype),
        "conv_bC": jnp.zeros((g * n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init_dense(ks[4], d_inner, d, scale=d_inner**-0.5, dtype=dtype),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (j <= i)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (b, s, h, p)
    dt: jnp.ndarray,  # (b, s, h)  (positive, post-softplus)
    A: jnp.ndarray,  # (h,)       (negative)
    B: jnp.ndarray,  # (b, s, g, n)
    C: jnp.ndarray,  # (b, s, g, n)
    chunk: int,
    initial_state: jnp.ndarray | None = None,  # (b, h, p, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD ("matmul" dual form).  Returns (y (b,s,h,p), final_state)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk
    rep = h // g  # heads per B/C group

    f32 = jnp.float32
    xb = (x * dt[..., None]).reshape(b, nc, chunk, h, p)  # dt-weighted input
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b, nc, Q, h, n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = (dt.astype(f32) * A.astype(f32)).reshape(b, nc, chunk, h)  # (b,nc,Q,h)
    dA = jnp.moveaxis(dA, -1, 2)  # (b, nc, h, Q)
    dA_cum = jnp.cumsum(dA, axis=-1)  # within-chunk cumulative

    # ---- diagonal (within-chunk) part: attention-like with decay kernel ----
    L = jnp.exp(_segsum(dA))  # (b, nc, h, Q, Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch.astype(f32), Bh.astype(f32))
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp", scores, L, xb.astype(f32))

    # ---- chunk states: decay-weighted B^T x over each chunk -----------------
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (b, nc, h, Q)
    states = jnp.einsum(
        "bckhn,bchk,bckhp->bchpn", Bh.astype(f32), decay_states, xb.astype(f32)
    )  # (b, nc, h, p, n)

    # ---- inter-chunk recurrence over compressed states ---------------------
    chunk_decay = jnp.exp(dA_cum[..., -1])  # (b, nc, h)
    s0 = (
        jnp.zeros((b, h, p, n), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def step(carry, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    final, prev_states = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b, nc, h, p, n)

    # ---- off-diagonal contribution: C @ carried state with in-chunk decay --
    state_decay = jnp.exp(dA_cum)  # (b, nc, h, Q)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bchq->bcqhp", Ch.astype(f32), prev_states, state_decay
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_recurrent(
    x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
    initial_state: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token reference recurrence (oracle for tests + decode)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    f32 = jnp.float32
    Bh = jnp.repeat(B, rep, axis=2).astype(f32)
    Ch = jnp.repeat(C, rep, axis=2).astype(f32)
    st = (
        jnp.zeros((b, h, p, n), f32) if initial_state is None else initial_state.astype(f32)
    )

    def step(st, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        dec = jnp.exp(dtt.astype(f32) * A.astype(f32))  # (b,h)
        st = st * dec[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt.astype(f32) * dtt[..., None].astype(f32), Bt
        )
        y = jnp.einsum("bhpn,bhn->bhp", st, Ct)
        return st, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    st, ys = jax.lax.scan(step, st, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), st


# ---------------------------------------------------------------------------
# Full mixer layer
# ---------------------------------------------------------------------------


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: (b, s, c); w: (d_conv, c)."""
    bsz, s, c = x.shape
    d_conv = w.shape[0]
    pad = jnp.zeros((bsz, d_conv - 1, c), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + s, :] * w[i][None, None, :] for i in range(d_conv))
    return jax.nn.silu(out + b.astype(x.dtype))


def _project(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    """Shared projection + conv path for full-seq and decode."""
    z = dense(p["in_z"], x)
    xs = dense(p["in_x"], x)
    B = dense(p["in_B"], x)
    C = dense(p["in_C"], x)
    dt = dense(p["in_dt"], x)
    return z, xs, B, C, dt


def ssm_apply(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence Mamba-2 mixer.  x: (b, s, d_model)."""
    s_cfg: SSMConfig = cfg.ssm
    b, s, _ = x.shape
    d_inner, n_heads, conv_dim, g, n = _dims(cfg)

    z, xs, B, C, dt = _project(p, cfg, x)
    xs = _causal_conv(xs, p["conv_x"].astype(xs.dtype), p["conv_bx"])
    B = _causal_conv(B, p["conv_B"].astype(B.dtype), p["conv_bB"])
    C = _causal_conv(C, p["conv_C"].astype(C.dtype), p["conv_bC"])

    xs = xs.reshape(b, s, n_heads, s_cfg.head_dim)
    B = B.reshape(b, s, g, n)
    C = C.reshape(b, s, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b, s, h)
    A = -jnp.exp(p["A_log"])  # (h,) negative

    y, _ = ssd_chunked(xs, dtv, A, B, C, chunk=min(s_cfg.chunk, s))
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    # gated RMSNorm (mamba2)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return dense(p["out_proj"], y)


# ---------------------------------------------------------------------------
# Decode (single-token recurrent step)
# ---------------------------------------------------------------------------


def ssm_state_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    s: SSMConfig = cfg.ssm
    d_inner, n_heads, conv_dim, g, n = _dims(cfg)
    return {
        "ssm": (batch, n_heads, s.head_dim, n),
        "conv": (batch, s.d_conv - 1, conv_dim),
    }


def ssm_decode_step(
    p: Params, cfg: ModelConfig, x: jnp.ndarray, state: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token step.  x: (b, 1, d); state: {'ssm': (b,h,p,n), 'conv': ...}."""
    s_cfg: SSMConfig = cfg.ssm
    b = x.shape[0]
    d_inner, n_heads, conv_dim, g, n = _dims(cfg)

    z, xs, B, C, dt = _project(p, cfg, x)
    xc = jnp.concatenate([xs, B, C], axis=-1)  # conv channel layout (x|B|C)
    hist = jnp.concatenate([state["conv"].astype(xc.dtype), xc], axis=1)
    w = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1
    ).astype(xc.dtype)
    bias = jnp.concatenate([p["conv_bx"], p["conv_bB"], p["conv_bC"]])
    conv = jnp.einsum("btc,tc->bc", hist, w)[:, None, :] + bias.astype(xc.dtype)
    conv = jax.nn.silu(conv)
    new_conv_state = hist[:, 1:, :]

    xs, B, C = (
        conv[..., :d_inner],
        conv[..., d_inner : d_inner + g * n],
        conv[..., d_inner + g * n :],
    )
    xs = xs.reshape(b, 1, n_heads, s_cfg.head_dim)
    B = B.reshape(b, 1, g, n)
    C = C.reshape(b, 1, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, new_ssm = ssd_recurrent(xs, dtv, A, B, C, initial_state=state["ssm"])
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return dense(p["out_proj"], y), {"ssm": new_ssm, "conv": new_conv_state}
