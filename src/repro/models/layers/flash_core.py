"""Flash attention core with custom VJP (pure JAX, O(S) memory).

Generic over GQA grouping and distinct qk/v head dims:

    q: (b, sq, kvh, g, dqk)    k: (b, sk, kvh, dqk)    v: (b, sk, kvh, dv)
    out: (b, sq, kvh, g, dv)

GQA: ``g = n_heads / n_kv_heads``;  MLA: ``kvh = n_heads, g = 1, dv != dqk``.

The forward is an online-softmax over KV blocks; the backward follows the
FlashAttention-2 recomputation scheme (only ``out`` and the log-sum-exp are
saved; score blocks are recomputed per (q-block, kv-block) pair).  This is
the numerical oracle for the ``repro/kernels/flash_attention`` Pallas
kernel, and the memory-safe attention used by training and prefill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_core"]

_NEG = -1e30


def _blocks(x, n, axis=1):
    """(b, s, ...) -> (n, b, s/n, ...) block-major for lax.scan."""
    shape = x.shape
    new = shape[:axis] + (n, shape[axis] // n) + shape[axis + 1 :]
    return jnp.moveaxis(x.reshape(new), axis, 0)


def _unblocks(x, axis=1):
    """(n, b, blk, ...) -> (b, n*blk, ...)."""
    x = jnp.moveaxis(x, 0, axis)
    shape = x.shape
    return x.reshape(shape[:axis] + (shape[axis] * shape[axis + 1],) + shape[axis + 2 :])


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention_core(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    out, _ = _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset)
    return out


def _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    b, sq, kvh, g, dqk = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = dqk**-0.5
    out_dtype = q.dtype

    qb = _blocks(q, nq)  # (nq, b, qc, kvh, g, dqk)
    kb = _blocks(k, nk)  # (nk, b, kc, kvh, dqk)
    vb = _blocks(v, nk)
    qpos = (jnp.arange(sq) + q_offset).reshape(nq, q_chunk)
    kpos = jnp.arange(sk).reshape(nk, kv_chunk)

    def q_step(_, qi):
        qblk, qp = qi

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kp = ki
            s = (
                jnp.einsum(
                    "bqhgd,bkhd->bhgqk", qblk, kblk,
                    preferred_element_type=jnp.float32,
                )
                * scale
            )
            if causal:
                s = jnp.where((kp[None, :] <= qp[:, None])[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kpos))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(out_dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (b, kvh, g, qc)
        return None, (o, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qb, qpos))
    # outs: (nq, b, kvh, g, qc, dv) -> (b, sq, kvh, g, dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, kvh, g, dv)
    return out, lses  # lses: (nq, b, kvh, g, qc)


def _fwd(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    out, lse = _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset)
    return out, (q, k, v, out, lse)


def _bwd(causal, q_chunk, kv_chunk, q_offset, res, dout):
    q, k, v, out, lse = res
    b, sq, kvh, g, dqk = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = dqk**-0.5
    f32 = jnp.float32

    qb = _blocks(q, nq)
    kb = _blocks(k, nk)
    vb = _blocks(v, nk)
    dob = _blocks(dout, nq)  # (nq, b, qc, kvh, g, dv)
    # delta_i = rowsum(dout * out), blocked to (nq, b, kvh, g, qc)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dout.astype(f32), out.astype(f32))
    deltab = delta.reshape(b, kvh, g, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    qpos = (jnp.arange(sq) + q_offset).reshape(nq, q_chunk)
    kpos = jnp.arange(sk).reshape(nk, kv_chunk)
    # lse comes blocked from fwd: (nq, b, kvh, g, qc)

    def recompute_p(qblk, kblk, qp, kp):
        s = (
            jnp.einsum(
                "bqhgd,bkhd->bhgqk", qblk, kblk, preferred_element_type=f32
            )
            * scale
        )
        if causal:
            s = jnp.where((kp[None, :] <= qp[:, None])[None, None, None], s, _NEG)
        return s

    # ---- dq: loop over q blocks, inner loop over kv blocks ------------------
    def dq_step(_, qi):
        qblk, doblk, lse_i, dlt_i, qp = qi

        def inner(dq_acc, ki):
            kblk, vblk, kp = ki
            s = recompute_p(qblk, kblk, qp, kp)
            p = jnp.exp(s - lse_i[..., None])  # (b,h,g,qc,kc)
            dp = jnp.einsum(
                "bqhgd,bkhd->bhgqk", doblk, vblk, preferred_element_type=f32
            )
            ds = p * (dp - dlt_i[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum(
                "bhgqk,bkhd->bqhgd", ds.astype(kblk.dtype), kblk
            ).astype(f32)
            return dq_acc, None

        dq0 = jnp.zeros((b, q_chunk, kvh, g, dqk), f32)
        dq_i, _ = jax.lax.scan(jax.checkpoint(inner), dq0, (kb, vb, kpos))
        return None, dq_i

    _, dqb = jax.lax.scan(dq_step, None, (qb, dob, lse, deltab, qpos))
    dq = _unblocks(dqb).astype(q.dtype)

    # ---- dk, dv: loop over kv blocks, inner loop over q blocks --------------
    def dkv_step(_, ki):
        kblk, vblk, kp = ki

        def inner(carry, qi):
            dk_acc, dv_acc = carry
            qblk, doblk, lse_i, dlt_i, qp = qi
            s = recompute_p(qblk, kblk, qp, kp)
            p = jnp.exp(s - lse_i[..., None])
            dv_acc = dv_acc + jnp.einsum(
                "bhgqk,bqhgd->bkhd", p.astype(f32), doblk.astype(f32)
            )
            dp = jnp.einsum(
                "bqhgd,bkhd->bhgqk", doblk, vblk, preferred_element_type=f32
            )
            ds = p * (dp - dlt_i[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum(
                "bhgqk,bqhgd->bkhd", ds, qblk.astype(f32)
            )
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros((b, kv_chunk, kvh, dqk), f32)
        dv0 = jnp.zeros((b, kv_chunk, kvh, dv), f32)
        (dk_j, dv_j), _ = jax.lax.scan(
            jax.checkpoint(inner), (dk0, dv0), (qb, dob, lse, deltab, qpos)
        )
        return None, (dk_j, dv_j)

    _, (dkb, dvb) = jax.lax.scan(dkv_step, None, (kb, vb, kpos))
    dk = _unblocks(dkb).astype(k.dtype)
    dv = _unblocks(dvb).astype(v.dtype)
    return dq, dk, dv


flash_attention_core.defvjp(_fwd, _bwd)
