"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Design (TPU-/pjit-friendly, static shapes):

  1. router logits -> top-k experts per token (+ optional renormalization);
  2. the (tokens x k) assignments are *sorted by expert id* and scattered
     into a dense ``(E, C, D)`` buffer (capacity ``C`` per expert; overflow
     tokens are dropped, standard capacity-factor semantics);
  3. expert FFNs run as grouped einsums over the ``E`` axis -- this is the
     axis expert parallelism shards (``experts`` logical axis -> ``model``);
  4. results are gathered back and combined with routing weights.

The dispatch/return movement is what becomes the all-to-all under expert
parallelism; the SyncEngine's `scu` strategy overlaps it with the shared
expert / attention compute (see DESIGN.md).

Shared experts (DeepSeek-style) run densely over all tokens.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from .basics import init_mlp, mlp_apply

Params = Dict[str, jnp.ndarray]

__all__ = ["init_moe", "moe_apply", "router_topk", "dispatch_indices"]


def init_moe(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    kr, ke, ks = jax.random.split(key, 3)
    # experts as stacked weights (E, d, ff): grouped-einsum friendly
    def expert_stack(key, d_in, d_out):
        return jax.random.normal(key, (m.n_experts, d_in, d_out), dtype) * (d_in**-0.5)

    k1, k2, k3 = jax.random.split(ke, 3)
    p: Params = {
        "router": jax.random.normal(kr, (d, m.n_experts), jnp.float32) * (d**-0.5),
        "gate": expert_stack(k1, d, m.d_ff_expert),
        "up": expert_stack(k2, d, m.d_ff_expert),
        "down": expert_stack(k3, m.d_ff_expert, d),
    }
    if m.n_shared > 0:
        p["shared"] = init_mlp(ks, d, m.d_ff_expert * m.n_shared, "swiglu", dtype)
    return p


def router_topk(
    logits: jnp.ndarray, m: MoEConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(T, E) logits -> (T, K) weights (float32) and (T, K) expert ids."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)
    if m.router_norm_topk:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx


def dispatch_indices(
    idx: jnp.ndarray, n_experts: int, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort-based dispatch bookkeeping.

    idx: (T, K) expert assignment.  Returns
      ``dest``    (T*K,) flat destination slot in the (E*C [+1 drop]) buffer,
      ``token``   (T*K,) source token of each sorted slot,
      ``slot_w``  (T*K,) position of this slot in the (T, K) weight matrix.
    """
    T, K = idx.shape
    flat_expert = idx.reshape(-1)  # (T*K,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    token = order // K
    # rank of each slot within its expert group
    starts = jnp.searchsorted(sorted_expert, jnp.arange(n_experts))  # (E,)
    rank = jnp.arange(T * K) - starts[sorted_expert]
    keep = rank < capacity
    dest = jnp.where(keep, sorted_expert * capacity + rank, n_experts * capacity)
    return dest, token, order


def moe_apply(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    T = b * s
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]  # (T, E)
    weights, idx = router_topk(logits, m)  # (T, K)

    capacity = int(T * m.top_k / m.n_experts * m.capacity_factor)
    capacity = max(8, min(capacity, T))
    dest, token, order = dispatch_indices(idx, m.n_experts, capacity)

    # scatter tokens into the expert buffers (dropped slots land in the
    # scratch row E*C which is sliced away)
    buf = jnp.zeros((m.n_experts * capacity + 1, d), x.dtype)
    buf = buf.at[dest].set(xf[token])
    h = buf[: m.n_experts * capacity].reshape(m.n_experts, capacity, d)
    if cfg.moe_shard_hints:
        # §Perf: pin the dispatch buffer to expert-parallel sharding so the
        # token movement lowers to an all-to-all instead of all-gathers
        from jax.sharding import PartitionSpec as _P

        h = jax.lax.with_sharding_constraint(h, _P("model", None, None))

    # grouped expert FFN (SwiGLU): the E axis is the EP sharding axis
    dt = x.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", h, p["up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", g * u, p["down"].astype(dt))  # (E, C, D)
    if cfg.moe_shard_hints:
        from jax.sharding import PartitionSpec as _P

        y = jax.lax.with_sharding_constraint(y, _P("model", None, None))

    # gather back + weighted combine
    y_flat = jnp.concatenate([y.reshape(-1, d), jnp.zeros((1, d), y.dtype)], axis=0)
    slot_out = y_flat[dest]  # (T*K, D), dropped slots contribute 0
    w_sorted = weights.reshape(-1)[order].astype(y.dtype)  # (T*K,)
    out = jnp.zeros((T, d), y.dtype).at[token].add(slot_out * w_sorted[:, None])

    if m.n_shared > 0:
        out = out + mlp_apply(p["shared"], xf, "swiglu")
    return out.reshape(b, s, d)
