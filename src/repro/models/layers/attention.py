"""Attention layers: GQA/MHA, MLA (DeepSeek-V2), chunked flash-style core.

The attention core (:func:`chunked_attention`) is a memory-efficient
online-softmax implementation in pure JAX (lax.scan over query and KV
blocks), used for training and prefill.  It is also the numerical oracle for
the Pallas ``flash_attention`` kernel (``repro/kernels/flash_attention``).

Decode (single-token) paths are in :mod:`repro.serve.decode`, including the
sequence-sharded distributed decode with log-sum-exp combination.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from .basics import apply_rope, dense, init_dense, init_norm, rmsnorm, rope_frequencies
from .flash_core import flash_attention_core

Params = Dict[str, jnp.ndarray]

__all__ = [
    "init_attention",
    "attention_apply",
    "init_mla",
    "mla_apply",
    "chunked_attention",
    "naive_attention",
]


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------


def naive_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Reference O(S^2)-memory attention.  q: (b, sq, h, d); k/v: (b, sk, kvh, d)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * (d**-0.5)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, sq, h, d)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Flash attention with O(S) memory and flash-recompute backward.

    q: (b, sq, h, d); k, v: (b, sk, kvh, d) with h % kvh == 0 (GQA).
    Returns (b, sq, h, d) in q.dtype.  Delegates to the custom-VJP core in
    :mod:`repro.models.layers.flash_core`.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    out = flash_attention_core(
        qg, k, v, causal, min(q_chunk, sq), min(kv_chunk, k.shape[1]), q_offset
    )
    return out.reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_dense(ks[1], d, kvh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_dense(ks[2], d, kvh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_dense(ks[3], h * hd, d, scale=(h * hd) ** -0.5, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm("rmsnorm", hd)
        p["k_norm"] = init_norm("rmsnorm", hd)
    return p


def attention_qkv(
    p: Params, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Projections + RoPE; shared by train/prefill/decode paths."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(b, s, h, hd)
    k = dense(p["wk"], x).reshape(b, s, kvh, hd)
    v = dense(p["wv"], x).reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"]["scale"])
        k = rmsnorm(k, p["k_norm"]["scale"])
    if cfg.use_rope:
        rot_dim, inv_freq = rope_frequencies(hd, cfg.rope_fraction, cfg.rope_theta)
        q = apply_rope(q, positions, rot_dim, inv_freq)
        k = apply_rope(k, positions, rot_dim, inv_freq)
    return q, k, v


def attention_apply(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Full-sequence causal attention (training / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = attention_qkv(p, cfg, x, positions)
    # TP alignment: when the kv-head count does not divide the model axis
    # (production TP=16) but the q-head count does, expand K/V to full heads
    # so the (kv_heads, group) factorization never crosses shard boundaries
    # (avoids XLA "involuntary full rematerialization" resharding).
    g = cfg.n_heads // cfg.n_kv_heads
    if g > 1 and cfg.n_kv_heads % 16 != 0 and cfg.n_heads % 16 == 0:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if s <= 2048:
        o = naive_attention(q, k, v, causal=True)
    else:
        o = chunked_attention(q, k, v, causal=True, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return dense(p["wo"], o.reshape(b, s, -1))


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        # queries (v2-lite: no q compression)
        "wq": init_dense(ks[0], d, h * qk_dim, dtype=dtype),
        # compressed KV path
        "w_dkv": init_dense(ks[1], d, m.kv_lora_rank, dtype=dtype),
        "kv_norm": init_norm("rmsnorm", m.kv_lora_rank),
        "w_kr": init_dense(ks[2], d, m.qk_rope_dim, dtype=dtype),  # shared rope key
        "w_uk": init_dense(ks[3], m.kv_lora_rank, h * m.qk_nope_dim, dtype=dtype),
        "w_uv": init_dense(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype=dtype),
        "wo": init_dense(ks[5], h * m.v_head_dim, d, scale=(h * m.v_head_dim) ** -0.5, dtype=dtype),
    }


def mla_latents(
    p: Params, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compressed KV latents (c_kv, k_rope) -- this is what the KV cache
    stores (the MLA memory saving: kv_lora + rope_dim per token)."""
    m: MLAConfig = cfg.mla
    c_kv = rmsnorm(dense(p["w_dkv"], x), p["kv_norm"]["scale"])  # (b, s, r)
    k_r = dense(p["w_kr"], x)[:, :, None, :]  # (b, s, 1, rope_dim)
    rot, inv = rope_frequencies(m.qk_rope_dim, 1.0, cfg.rope_theta)
    k_r = apply_rope(k_r, positions, rot, inv)
    return c_kv, k_r[:, :, 0, :]


def mla_apply(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Training/prefill MLA: decompress K/V and run the shared core."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)
    q = dense(p["wq"], x).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    rot, inv = rope_frequencies(m.qk_rope_dim, 1.0, cfg.rope_theta)
    q_rope = apply_rope(q_rope, positions, rot, inv)

    c_kv, k_r = mla_latents(p, cfg, x, positions)  # (b,s,r), (b,s,rope)
    k_nope = dense(p["w_uk"], c_kv).reshape(b, s, h, m.qk_nope_dim)
    v = dense(p["w_uv"], c_kv).reshape(b, s, h, m.v_head_dim)

    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_r[:, :, None, :], (b, s, h, m.qk_rope_dim))],
        axis=-1,
    )
    # the MLA core handles distinct qk/v head dims
    if s <= 2048:
        o = _mla_core(qq, kk, v)
    else:
        o = _mla_core_chunked(qq, kk, v, q_chunk, kv_chunk)
    return dense(p["wo"], o.reshape(b, s, -1))


def _mla_core(q, k, v):
    """MHA core with distinct qk/v dims.  q,k: (b,s,h,dqk), v: (b,s,h,dv)."""
    d = q.shape[-1]
    s = q.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (d**-0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _mla_core_chunked(q, k, v, q_chunk, kv_chunk):
    """Flash core for distinct qk/v head dims (kvh == h, g == 1)."""
    b, sq, h, dqk = q.shape
    dv = v.shape[-1]
    out = flash_attention_core(
        q.reshape(b, sq, h, 1, dqk),
        k,
        v,
        True,
        min(q_chunk, sq),
        min(kv_chunk, sq),
        0,
    )
    return out.reshape(b, sq, h, dv)
