"""Norms, activations, rotary embeddings, embeddings, MLP.

All layers are pure functions over explicit parameter pytrees (dicts of
``jnp.ndarray``):  ``init_*`` builds params, ``apply`` semantics are
documented per function.  Sharding is attached separately via the logical
axis specs in :mod:`repro.parallel.sharding` (every init here returns params
whose tree structure matches the spec tree).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rmsnorm",
    "layernorm",
    "init_norm",
    "apply_norm",
    "rope_frequencies",
    "apply_rope",
    "init_dense",
    "dense",
    "init_mlp",
    "mlp_apply",
    "init_embedding",
    "embed",
    "unembed",
]

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def init_norm(kind: str, dim: int) -> Params:
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def layernorm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (((x - mu) * jax.lax.rsqrt(var + eps)) * scale + bias).astype(dt)


def apply_norm(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# Rotary position embeddings (with partial-rotary support)
# ---------------------------------------------------------------------------


def rope_frequencies(
    head_dim: int, fraction: float, theta: float
) -> Tuple[int, jnp.ndarray]:
    """Returns (rot_dim, inv_freq[rot_dim//2]) for partial rotary."""
    rot_dim = int(head_dim * fraction) // 2 * 2
    if rot_dim == 0:
        return 0, jnp.zeros((0,), jnp.float32)
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    )
    return rot_dim, inv_freq


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    rot_dim: int,
    inv_freq: jnp.ndarray,
) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    if rot_dim == 0:
        return x
    dt = x.dtype
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., s, rd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., s, 1, rd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(dt), xp], axis=-1)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------


def init_dense(
    key: jax.Array,
    d_in: int,
    d_out: int,
    *,
    bias: bool = False,
    scale: Optional[float] = None,
    dtype=jnp.float32,
) -> Params:
    scale = scale if scale is not None else d_in**-0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_mlp(key: jax.Array, d_model: int, d_ff: int, act: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": init_dense(k1, d_model, d_ff, dtype=dtype),
        "down": init_dense(k2, d_ff, d_model, dtype=dtype),
    }
    if act == "swiglu":
        p["gate"] = init_dense(k3, d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    else:
        h = jax.nn.gelu(dense(p["up"], x))
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _embed_lookup(table, tokens, grad_sharding, table_spec):
    return jnp.take(table, tokens, axis=0)


def _embed_lookup_fwd(table, tokens, grad_sharding, table_spec):
    return jnp.take(table, tokens, axis=0), tokens


def _embed_lookup_bwd(grad_sharding, table_spec, tokens, dout):
    shape, dtype_name = table_spec
    # the table gradient is a scatter-add over the vocab axis; constraining
    # its sharding keeps the (vocab, d_model) f32 buffer sharded instead of
    # replicated (a ~12 GiB difference for 256k-vocab archs)
    dtable = jnp.zeros(shape, jnp.float32).at[tokens].add(dout.astype(jnp.float32))
    if grad_sharding is not None:
        dtable = jax.lax.with_sharding_constraint(dtable, grad_sharding)
    return (dtable.astype(dtype_name), None)


_embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def embed(
    p: Params, tokens: jnp.ndarray, dtype=jnp.bfloat16, grad_sharding=None
) -> jnp.ndarray:
    table = p["table"]
    spec = (tuple(table.shape), jnp.dtype(table.dtype).name)
    return _embed_lookup(table, tokens, grad_sharding, spec).astype(dtype)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Project to vocabulary logits (used for tied or dedicated lm_head)."""
    return x @ p["table"].astype(x.dtype).T
