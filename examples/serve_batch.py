"""Batched serving example: prefill + greedy decode on a reduced config.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serve_batch.py
"""

import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "phi4-mini-3.8b",
     "--smoke", "--batch", "4", "--prompt-len", "32", "--gen", "16"],
    check=True,
)
