"""Quickstart: build a small model, run a forward pass, take one train step.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config, list_archs
from repro.launch.mesh import make_host_mesh
from repro.models.lm import init_lm, lm_forward
from repro.train.data import SyntheticLM
from repro.train.loop import TrainerConfig, train
from repro.train.optimizer import OptConfig
from repro.train.step import TrainConfig

print("available architectures:", ", ".join(list_archs()))

cfg = get_smoke_config("qwen3-moe-30b-a3b")  # MoE family, reduced size
print(f"\nusing {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
      f"experts={cfg.moe.n_experts} top-{cfg.moe.top_k}")

params = init_lm(jax.random.PRNGKey(0), cfg)
tokens = jnp.zeros((2, 32), jnp.int32)
hidden = lm_forward(params, cfg, tokens=tokens)
print("forward:", hidden.shape, hidden.dtype)

mesh = make_host_mesh(data=2, model=2)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
tcfg = TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=5), remat_policy="none")
_, _, hist = train(
    cfg, tcfg, TrainerConfig(steps=10, log_every=2, ckpt_every=10**9),
    mesh, lambda i: data.batch(i, batch_size=8),
)
print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over 10 steps")
