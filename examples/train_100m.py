"""End-to-end driver: train a ~100M-param dense model for a few hundred steps
on the synthetic corpus with checkpointing, then resume once (restart drill).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import dataclasses

import jax

from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh
from repro.train.data import SyntheticLM
from repro.train.loop import TrainerConfig, train
from repro.train.optimizer import OptConfig
from repro.train.step import TrainConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
args = ap.parse_args()

# ~100M params: a narrow stablelm-family variant
cfg = dataclasses.replace(
    get_config("stablelm-3b"),
    name="stablelm-100m",
    n_layers=6,
    d_model=640,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1792,
    vocab_size=50304,
)
print(f"{cfg.name}: {cfg.n_params()/1e6:.0f}M params")

n = len(jax.devices())
mesh = make_host_mesh(data=max(1, n // 2), model=min(2, n))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128, seed=0)
tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20), remat_policy="none")

half = args.steps // 2
print(f"== phase 1: steps 0..{half} (with checkpoints) ==")
train(cfg, tcfg, TrainerConfig(steps=half, ckpt_every=50, ckpt_dir=args.ckpt,
                               log_every=20),
      mesh, lambda i: data.batch(i, batch_size=16))

print(f"== phase 2: resume from checkpoint -> step {args.steps} ==")
_, _, hist = train(cfg, tcfg,
                   TrainerConfig(steps=args.steps, ckpt_every=100,
                                 ckpt_dir=args.ckpt, log_every=20),
                   mesh, lambda i: data.batch(i, batch_size=16))
print(f"final loss: {hist[-1]['loss']:.4f}")
