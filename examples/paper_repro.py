"""Reproduce the paper's headline numbers on the Tier-1 simulator.

    PYTHONPATH=src python examples/paper_repro.py
"""

from benchmarks import fig5_overhead, table1_primitives

table1_primitives.run()
fig5_overhead.run()
