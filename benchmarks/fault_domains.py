"""Fault-domain chaos sweep: domain fault rate x routing-policy matrix.

A fixed, deterministic stream of 8-core SCU barrier jobs is served by a
:class:`repro.serve.fleet_pool.FleetPool` of three single-slot fleets --
three *fault domains*.  Domain 0 is sick: a seeded inject hook arms a
lost-barrier-wake :class:`repro.core.scu.faults.FaultPlan` on a fraction
of the configs admitted there (the *domain fault rate*), so any attempt
that lands in the blast radius deadlocks and burns its whole cycle
budget.  The other domains stay clean.  Three routing policies run the
identical arrival schedule:

* ``inplace``    -- ``RetryPolicy(reroute=False)``: a failed attempt
  retries on the *same* domain.  The fault is pinned to the domain, so
  every retry lands back in the blast radius and the job is lost;
* ``reroute``    -- ``reroute=True``: the retry is resubmitted to a
  different healthy domain first, escaping the fault.  Every job
  completes, but the victim domain keeps receiving *fresh* placements
  (it looks least loaded precisely because its jobs keep failing), each
  one a full wasted attempt;
* ``quarantine`` -- ``reroute=True`` plus a :class:`BreakerPolicy`:
  after the health window trips, the domain is demoted
  (healthy -> probation -> quarantined) and the router stops feeding it,
  cutting wasted cycles while still completing 100% of the stream.

Reported per (rate, policy) cell: failure rate, total attempts, wasted
cycles, reroutes, quarantines, scheduler rounds and recovery latency.
Everything is counted in cycles or rounds of a seeded deterministic
simulation, so the numbers are bit-exact across machines and hard-gated
by ``scripts/bench_compare.py``; the artifact is identical under
``--fast`` and full runs.

    PYTHONPATH=src python -m benchmarks.fault_domains [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import random
from typing import Dict

from repro.core.scu.faults import FaultEvent, FaultPlan
from repro.core.scu.programs import prep_barrier_bench
from repro.serve.fleet_pool import BreakerPolicy, FleetPool
from repro.serve.fleet_service import RetryPolicy

# pool geometry: three single-slot fault domains, so placement decisions
# are legible and every domain-0 admission is a countable wasted attempt
N_DOMAINS = 3
N_SLOTS = 1
SLOT_CORES = 8
ITERS = 4
SFR = 20
# cycle budget per attempt: a deadlocked attempt burns exactly this much
MAX_CYCLES = 4000

# arrival schedule: an initial burst (so the sick domain holds a queued
# job that becomes its probation probe), then a staggered tail (so the
# breaker's routing decisions have fresh arrivals to protect)
BURST_JOBS = 6
TAIL_JOBS = 3
TAIL_GAP_ROUNDS = 40
N_JOBS = BURST_JOBS + TAIL_JOBS

VICTIM_DOMAIN = 0
# the barrier event line (EV.BARRIER); losing it on one core deadlocks
# the whole barrier
_BARRIER_LINE_MASK = 1 << 8

FAULT_RATES = (0.0, 1.0)
POLICIES = ("inplace", "reroute", "quarantine")

_SEED = 0xD0A1A


def _fault_plan(victim_core: int) -> FaultPlan:
    """Lose the barrier wake on one core early in the attempt (plans are
    single-use, so build a fresh one per admission)."""
    return FaultPlan([
        FaultEvent("lost_wake", cycle=10, core=victim_core,
                   lines=_BARRIER_LINE_MASK)
    ])


def _inject(rate: float):
    """Domain-scoped chaos: admissions to the victim domain are armed
    with a deadlocking plan at ``rate``.  The rng is seeded and drawn in
    admission order (which is deterministic), so the sweep is bit-exact."""
    rng = random.Random(_SEED)

    def inject(domain: int, config):
        if domain == VICTIM_DOMAIN and rng.random() < rate:
            config.cluster.faults = _fault_plan(rng.randrange(SLOT_CORES))
        return config
    return inject


def _factory(attempt: int):
    fb = prep_barrier_bench("scu", SLOT_CORES, sfr=SFR, iters=ITERS)
    fb.config.max_cycles = MAX_CYCLES
    return fb.config


def _run_cell(rate: float, policy: str) -> Dict:
    retry = RetryPolicy(max_attempts=2, backoff_rounds=0,
                        reroute=(policy != "inplace"))
    breaker = None
    if policy == "quarantine":
        breaker = BreakerPolicy(probation_after=1, cooldown_rounds=200,
                                probe_successes=1)

    pool = FleetPool(
        n_domains=N_DOMAINS, n_slots=N_SLOTS, slot_cores=SLOT_CORES,
        queue_limit=N_JOBS, retry=retry, breaker=breaker,
        inject=_inject(rate),
    )

    jobs = [pool.submit(factory=_factory) for _ in range(BURST_JOBS)]
    for _ in range(TAIL_JOBS):
        for _ in range(TAIL_GAP_ROUNDS):
            pool.step()
        jobs.append(pool.submit(factory=_factory))
    pool.run_until_drained(max_rounds=500_000)

    failed = [j for j in jobs if j.state == "failed"]
    done = [j for j in jobs if j.state == "done"]
    assert len(failed) + len(done) == N_JOBS
    lat = [j.latency_rounds for j in jobs]
    return {
        "failure_rate": len(failed) / N_JOBS,
        "failed_jobs": len(failed),
        "completed_jobs": len(done),
        "total_attempts": sum(j.attempts for j in jobs),
        "reroutes": pool.reroutes,
        "quarantines": pool.quarantines,
        "wasted_cycles": pool.wasted_cycles,
        "rounds": pool.round,
        "mean_latency_rounds": sum(lat) / N_JOBS,
        "watchdog_trips": pool.watchdog_trips,
    }


def run(verbose: bool = True) -> Dict:
    cells: Dict[str, Dict[str, Dict]] = {}
    for rate in FAULT_RATES:
        key = f"rate{rate:g}"
        cells[key] = {policy: _run_cell(rate, policy) for policy in POLICIES}

    # the headline claims, asserted (not just reported): at a domain
    # fault rate where in-place retry loses jobs, rerouting completes
    # 100% of the stream, and quarantine does so with strictly fewer
    # wasted cycles than reroute alone
    faulty = cells[f"rate{FAULT_RATES[-1]:g}"]
    assert faulty["inplace"]["failed_jobs"] > 0, (
        "domain fault rate too low to matter"
    )
    for policy in ("reroute", "quarantine"):
        assert faulty[policy]["failure_rate"] == 0.0, (
            f"{policy} lost jobs: {faulty[policy]}"
        )
    assert faulty["quarantine"]["quarantines"] >= 1
    assert (faulty["quarantine"]["wasted_cycles"]
            < faulty["reroute"]["wasted_cycles"]), (
        "quarantine must stop feeding the victim domain"
    )
    # and clean traffic is untouched by the routing machinery
    clean = cells[f"rate{FAULT_RATES[0]:g}"]
    for c in clean.values():
        assert c["failure_rate"] == 0.0
        assert c["reroutes"] == 0 and c["quarantines"] == 0
        assert c["total_attempts"] == N_JOBS

    result = {
        "pool": {"n_domains": N_DOMAINS, "n_slots": N_SLOTS,
                 "slot_cores": SLOT_CORES, "victim_domain": VICTIM_DOMAIN},
        "n_jobs": N_JOBS,
        "max_cycles": MAX_CYCLES,
        "fault_rates": list(FAULT_RATES),
        "cells": cells,
    }

    if verbose:
        print(f"\n== Fault-domain chaos sweep ({N_JOBS} jobs, "
              f"{N_DOMAINS} domains x {N_SLOTS}x{SLOT_CORES} lanes, "
              f"domain {VICTIM_DOMAIN} sick) ==")
        print(f"{'rate':>5s} {'policy':10s} {'fail%':>6s} {'attempts':>8s} "
              f"{'wasted cyc':>10s} {'reroute':>7s} {'quar':>4s} "
              f"{'rounds':>7s} {'mean lat':>8s}")
        for rate in FAULT_RATES:
            for policy in POLICIES:
                c = cells[f"rate{rate:g}"][policy]
                print(
                    f"{rate:5.2f} {policy:10s} {c['failure_rate']:6.0%} "
                    f"{c['total_attempts']:8d} {c['wasted_cycles']:10d} "
                    f"{c['reroutes']:7d} {c['quarantines']:4d} "
                    f"{c['rounds']:7d} {c['mean_latency_rounds']:8.1f}"
                )
        f = faulty
        print(
            f"\nat a fully sick domain: in-place retry loses "
            f"{f['inplace']['failed_jobs']}/{N_JOBS} jobs; reroute and "
            f"reroute+quarantine complete {N_JOBS}/{N_JOBS} "
            f"(wasted cycles {f['inplace']['wasted_cycles']} -> "
            f"{f['reroute']['wasted_cycles']} -> "
            f"{f['quarantine']['wasted_cycles']})"
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", help="write results as JSON")
    args = ap.parse_args()
    result = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
