"""Pipelined producer-consumer chain microbenchmark (stages x SFR x depth).

The vertical-slice benchmark for the ``fifo`` discipline (paper Sec. 4.3:
the SCU event FIFO exists for fine-grain producer-consumer chains that pure
barriers serve poorly).  ``iters`` items stream through ``n_cores`` pipeline
stages; every registered ``repro.sync`` policy runs the same chain -- the
``fifo`` policy natively (credit-bounded per-link event queues, clock-gated
pops), every other policy through the barrier-synchronous emulation where
the whole cluster meets at a global barrier each pipeline tick.

Three read-outs:

  * the per-item cost vs SFR per policy (Table-1-style rows),
  * the ``fifo`` credit-depth sweep (how much in-flight buffering the chain
    needs before stages fully overlap -- the tunable-depth knob),
  * the pipelined variant of a Table-2 app skeleton (mfcc: audio frames
    through per-core stages), where per-stage imbalance makes the global
    barrier pay the cluster-wide maximum each tick while the FIFO chain only
    couples neighbors.

The (policy x SFR) sweep and the depth sweep dispatch through the fleet
engine as one batched ``simulate_fleet`` call (bit-exact per config).

    PYTHONPATH=src python -m benchmarks.chain_pipeline
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.core.scu.apps import APPS, PIPELINED_APPS, run_app_pipelined
from repro.core.scu.energy import DEFAULT_ENERGY, Activity
from repro.core.scu.programs import make_fleet, prep_chain_bench
from repro.sync import available_policies

SFRS = (50, 200, 800)
DEPTHS = (1, 2, 4, 8, 16)


def _energy_nj_per_item(r) -> float:
    return DEFAULT_ENERGY.energy_nj(Activity.per_iter(r.stats, r.iters))


def run(
    n_cores: int = 8,
    iters: int = 32,
    depth: int = 8,
    sfrs: Optional[Sequence[int]] = None,
    verbose: bool = True,
) -> Dict:
    """Chain sweep over every policy + the fifo depth sweep + pipelined app."""
    sfrs = list(sfrs) if sfrs is not None else list(SFRS)
    policies = available_policies()
    # the (policy x SFR) sweep plus the fifo depth sweep as ONE batched
    # fleet call (bit-exact per config vs sequential Cluster.run())
    grid = [(policy, sfr) for policy in policies for sfr in sfrs]
    results = make_fleet(
        [
            prep_chain_bench(policy, n_cores, sfr=sfr, iters=iters, depth=depth)
            for policy, sfr in grid
        ]
        + [
            prep_chain_bench("fifo", n_cores, sfr=sfrs[0], iters=iters, depth=d)
            for d in DEPTHS
        ]
    )
    rows: List[Dict] = []
    for (policy, sfr), r in zip(grid, results):
        rows.append({
            "policy": policy,
            "n_cores": n_cores,
            "sfr": sfr,
            "depth": depth,
            "cycles_per_item": r.cycles_per_iter,
            "overhead_cycles": r.prim_cycles,
            "energy_nj_per_item": _energy_nj_per_item(r),
            "gated_per_item": r.gated_core_cycles_per_iter,
        })

    depth_rows: List[Dict] = []
    for d, r in zip(DEPTHS, results[len(grid):]):
        depth_rows.append({
            "depth": d,
            "sfr": sfrs[0],
            "cycles_per_item": r.cycles_per_iter,
        })

    app_rows: List[Dict] = []
    for name in PIPELINED_APPS:
        per_policy = {
            p: run_app_pipelined(APPS[name], p, n_cores=n_cores, depth=depth)
            for p in policies
        }
        app_rows.append({
            "app": name,
            "cycles": {p: r.cycles for p, r in per_policy.items()},
            "energy_uj": {p: round(r.energy_uj, 2) for p, r in per_policy.items()},
        })

    results = {
        "n_cores": n_cores,
        "iters": iters,
        "depth": depth,
        "rows": rows,
        "depth_sweep": depth_rows,
        "apps": app_rows,
    }

    if verbose:
        print(f"\n== Pipelined chain: {n_cores} stages, {iters} items ==")
        print(f"{'policy':7s}" + "".join(f"  sfr={s:<6d}" for s in sfrs)
              + "(cycles/item; ideal = sfr)")
        for policy in policies:
            vals = [r for r in rows if r["policy"] == policy]
            print(f"{policy:7s}" + "".join(
                f"  {v['cycles_per_item']:8.1f}" for v in vals))
        print(f"\nfifo credit-depth sweep (sfr={sfrs[0]}):")
        print("  " + "  ".join(
            f"d={d['depth']}: {d['cycles_per_item']:.1f}" for d in depth_rows))
        for a in app_rows:
            fifo_c = a["cycles"]["fifo"]
            best_bar = min(c for p, c in a["cycles"].items() if p != "fifo")
            print(
                f"\npipelined {a['app']}: fifo {fifo_c} cycles vs best "
                f"barrier-sync {best_bar} ({best_bar / fifo_c - 1:+.1%})"
            )
    return results


# Policies measured on the very large (128/256-stage) chains: the barrier-
# synchronous emulation pays a full cluster barrier per pipeline tick, which
# for the central-counter disciplines is O(n^2) cycles per tick -- exactly
# the pathology the FIFO chain removes.  We keep the hardware barrier and
# the log-depth tree as baselines for contrast and drop the unbounded ones.
SCALING_LARGE_POLICIES = ("scu", "tree4", "fifo")
SCALING_LARGE_FROM = 128


def run_scaling(
    core_counts=(16, 32, 64, 128, 256),
    iters: int = 8,
    sfr: int = 200,
    depth: int = 8,
    verbose: bool = True,
) -> List[Dict]:
    """The chain on MemPool-scale clusters: deeper pipelines, same per-stage
    SFR.  The FIFO chain's per-item cost stays put as stages are added (only
    neighbors couple); the barrier-synchronous emulation pays the growing
    global barrier every tick."""
    rows: List[Dict] = []
    t0 = time.perf_counter()
    for n in core_counts:
        policies = (
            [p for p in available_policies() if p in SCALING_LARGE_POLICIES]
            if n >= SCALING_LARGE_FROM
            else available_policies()
        )
        # one fleet per core count (see table1_primitives.run_scaling)
        results = make_fleet([
            prep_chain_bench(policy, n, sfr=sfr, iters=iters, depth=depth)
            for policy in policies
        ])
        for policy, r in zip(policies, results):
            rows.append({
                "policy": policy,
                "n_cores": n,
                "sfr": sfr,
                "depth": depth,
                "cycles_per_item": r.cycles_per_iter,
            })
    if verbose:
        counts = "/".join(str(n) for n in core_counts)
        print(f"\n== Chain (scaling): cycles/item @ {counts} stages, sfr={sfr} ==")
        print("policy  " + "".join(f"{n:>10d}" for n in core_counts))
        for policy in available_policies():
            vals = [
                f"{r['cycles_per_item']:10.1f}" if r is not None else f"{'-':>10s}"
                for r in (
                    next((x for x in rows
                          if x["policy"] == policy and x["n_cores"] == n), None)
                    for n in core_counts
                )
            ]
            print(f"{policy:8s}" + "".join(vals))
        print(f"[chain scaling] {time.perf_counter() - t0:.1f}s wall")
    return rows


if __name__ == "__main__":
    run()
    run_scaling()
