"""Paper Fig. 5: relative overhead vs synchronization-free-region size.

Sweeps the SFR (compute cycles between barriers) and reports cycle and
energy overhead per variant, plus the minimum SFR that keeps overhead at or
below 10% -- the paper's headline: SCU 42 cycles vs TAS 1622 / SW 1771
(energy, 8 cores), a >41x reduction.

Every registered ``repro.sync`` policy is swept (the paper's triad plus
extensions such as the log-depth ``tree`` barrier).  Two grids are provided:
the paper-matching ``SFRS`` and the ~2x finer ``SFRS_DENSE`` that the
event-driven engine makes affordable (pass ``sfrs=SFRS_DENSE`` or
``dense=True``); :func:`run_scaling` repeats the sweep on 16..256-core
clusters, where the minimum viable SFR of the software disciplines grows
with the core count while the SCU's stays put.

Each sweep's (policy x SFR) grid dispatches through the fleet engine: one
batched ``simulate_fleet`` call per core count instead of hundreds of
sequential ``Cluster.run()`` calls (bit-exact per config).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scu.energy import DEFAULT_ENERGY, Activity
from repro.core.scu.programs import make_fleet, prep_barrier_bench
from repro.sync import available_policies

PAPER_MIN_SFR_ENERGY_8 = {"scu": 42.0, "tas": 1622.0, "sw": 1771.0}

SFRS = [8, 16, 32, 42, 64, 100, 160, 250, 400, 640, 1000, 1600, 2500, 4000]
# ~2x denser log-spaced grid: sharper min-SFR interpolation, same range
SFRS_DENSE = [
    8, 12, 16, 24, 32, 42, 56, 64, 80, 100, 128, 160, 200, 250, 320, 400,
    500, 640, 800, 1000, 1300, 1600, 2000, 2500, 3200, 4000,
]


def _overheads_of(r, n: int, sfr: int) -> Tuple[float, float]:
    cyc_overhead = (r.cycles_per_iter - sfr) / sfr
    act = Activity.per_iter(r.stats, r.iters)
    e_total = DEFAULT_ENERGY.energy_pj(act)
    e_ideal = sfr * DEFAULT_ENERGY.nop_power_per_cycle_pj(n)
    return cyc_overhead, (e_total - e_ideal) / e_ideal


def min_sfr_at(threshold: float, curve: List[Tuple[int, float]]) -> float:
    """Smallest SFR with overhead <= threshold (log-linear interpolation)."""
    prev = None
    for sfr, ov in curve:
        if ov <= threshold:
            if prev is None:
                return float(sfr)
            sfr0, ov0 = prev
            # linear interpolate in 1/sfr space (overhead ~ cost/sfr)
            frac = (ov0 - threshold) / max(ov0 - ov, 1e-12)
            return sfr0 + frac * (sfr - sfr0)
        prev = (sfr, ov)
    return float("inf")


def run(
    n_cores: int = 8,
    iters: int = 16,
    verbose: bool = True,
    sfrs: Optional[Sequence[int]] = None,
    dense: bool = False,
) -> Dict:
    sfrs = list(sfrs) if sfrs is not None else (SFRS_DENSE if dense else SFRS)
    variants = available_policies()
    # the whole (policy x SFR) grid as one batched fleet call: this is the
    # sweep that previously ran hundreds of sequential 8-core Cluster.run()
    # calls below the vectorization threshold
    results = iter(make_fleet([
        prep_barrier_bench(variant, n_cores, sfr=sfr, iters=iters)
        for variant in variants
        for sfr in sfrs
    ]))
    curves = {}
    for variant in variants:
        cyc_curve, en_curve = [], []
        for sfr in sfrs:
            c, e = _overheads_of(next(results), n_cores, sfr)
            cyc_curve.append((sfr, c))
            en_curve.append((sfr, e))
        curves[variant] = {"cycles": cyc_curve, "energy": en_curve}

    result = {}
    for variant, cc in curves.items():
        result[variant] = {
            "min_sfr_cycles_10pct": min_sfr_at(0.10, cc["cycles"]),
            "min_sfr_energy_10pct": min_sfr_at(0.10, cc["energy"]),
            "paper_min_sfr_energy": PAPER_MIN_SFR_ENERGY_8.get(variant),
            "curves": cc,
        }

    if verbose:
        print(f"\n== Fig. 5: overhead vs SFR size ({n_cores} cores) ==")
        hdr = "SFR:       " + "".join(f"{s:>8d}" for s in sfrs)
        print(hdr)
        for variant in variants:
            row = curves[variant]["energy"]
            print(
                f"{variant:5s} E-ovh " + "".join(f"{ov*100:7.1f}%" for _, ov in row)
            )
        print("\nminimum SFR @ 10% energy overhead (measured vs paper):")
        for variant in variants:
            m = result[variant]["min_sfr_energy_10pct"]
            p = result[variant]["paper_min_sfr_energy"]
            ps = f"(paper {p:7.1f})" if p is not None else "(paper    -  )"
            print(f"  {variant:5s}: {m:8.1f} cycles   {ps}")
        ratio = (
            result["sw"]["min_sfr_energy_10pct"]
            / max(result["scu"]["min_sfr_energy_10pct"], 1e-9)
        )
        print(f"  SW/SCU reduction: {ratio:.1f}x (paper: ~41x)")
    return result


# SFR grid for the multi-core sweep: spin-heavy small-SFR points get very
# expensive at 64 cores, so the scaling sweep samples the decades sparsely;
# the top end stretches past the 8-core grid because the software
# disciplines' minimum viable SFR grows with the core count.
SFRS_SCALE = [64, 160, 400, 1000, 2500, 6400, 16000]


def run_scaling(
    core_counts=(16, 32, 64, 128, 256),
    iters: int = 8,
    sfrs: Optional[Sequence[int]] = None,
    verbose: bool = True,
) -> Dict[int, Dict]:
    """The Fig. 5 sweep on 16..256-core clusters (every policy).

    Reports how the minimum SFR for <=10% energy overhead scales with the
    core count: the software disciplines need ever-larger synchronization-
    free regions, the SCU's stays flat -- the paper's argument, extended to
    MemPool-scale clusters.  The 128/256-core points average fewer
    iterations (the contended software rows grow superlinearly in cycles
    per iteration; the averages converge just as fast).
    """
    sfrs = list(sfrs) if sfrs is not None else SFRS_SCALE
    results: Dict[int, Dict] = {}
    for n in core_counts:
        it = iters if n < 128 else max(2, iters // 4)
        results[n] = run(n_cores=n, iters=it, verbose=False, sfrs=sfrs)
    if verbose:
        variants = available_policies()
        counts = "/".join(str(n) for n in core_counts)
        print(f"\n== Fig. 5 (scaling): min SFR @ 10% energy overhead, {counts} cores ==")
        print("policy " + "".join(f"{n:>10d}" for n in core_counts))
        for v in variants:
            vals = []
            for n in core_counts:
                m = results[n][v]["min_sfr_energy_10pct"]
                vals.append(f"{m:10.0f}" if m != float("inf") else f"{'>max':>10s}")
            print(f"{v:6s}" + "".join(vals))
    return results


if __name__ == "__main__":
    run()
    run_scaling()
