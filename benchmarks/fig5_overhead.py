"""Paper Fig. 5: relative overhead vs synchronization-free-region size.

Sweeps the SFR (compute cycles between barriers) and reports cycle and
energy overhead per variant, plus the minimum SFR that keeps overhead at or
below 10% -- the paper's headline: SCU 42 cycles vs TAS 1622 / SW 1771
(energy, 8 cores), a >41x reduction.

Every registered ``repro.sync`` policy is swept (the paper's triad plus
extensions such as the log-depth ``tree`` barrier).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.scu.energy import DEFAULT_ENERGY, Activity
from repro.core.scu.programs import run_barrier_bench
from repro.sync import available_policies

PAPER_MIN_SFR_ENERGY_8 = {"scu": 42.0, "tas": 1622.0, "sw": 1771.0}

SFRS = [8, 16, 32, 42, 64, 100, 160, 250, 400, 640, 1000, 1600, 2500, 4000]


def _overheads(variant: str, n: int, sfr: int, iters: int) -> Tuple[float, float]:
    r = run_barrier_bench(variant, n, sfr=sfr, iters=iters)
    cyc_overhead = (r.cycles_per_iter - sfr) / sfr
    st, it = r.stats, r.iters
    act = Activity(
        comp=st.total_comp / it, wait=st.total_wait / it, gated=st.total_gated / it,
        tcdm=st.total_tcdm / it, scu=st.total_scu / it, cycles=st.cycles / it,
    )
    e_total = DEFAULT_ENERGY.energy_pj(act)
    e_ideal = sfr * DEFAULT_ENERGY.nop_power_per_cycle_pj(n)
    return cyc_overhead, (e_total - e_ideal) / e_ideal


def min_sfr_at(threshold: float, curve: List[Tuple[int, float]]) -> float:
    """Smallest SFR with overhead <= threshold (log-linear interpolation)."""
    prev = None
    for sfr, ov in curve:
        if ov <= threshold:
            if prev is None:
                return float(sfr)
            sfr0, ov0 = prev
            # linear interpolate in 1/sfr space (overhead ~ cost/sfr)
            frac = (ov0 - threshold) / max(ov0 - ov, 1e-12)
            return sfr0 + frac * (sfr - sfr0)
        prev = (sfr, ov)
    return float("inf")


def run(n_cores: int = 8, iters: int = 16, verbose: bool = True) -> Dict:
    variants = available_policies()
    curves = {}
    for variant in variants:
        cyc_curve, en_curve = [], []
        for sfr in SFRS:
            c, e = _overheads(variant, n_cores, sfr, iters)
            cyc_curve.append((sfr, c))
            en_curve.append((sfr, e))
        curves[variant] = {"cycles": cyc_curve, "energy": en_curve}

    result = {}
    for variant, cc in curves.items():
        result[variant] = {
            "min_sfr_cycles_10pct": min_sfr_at(0.10, cc["cycles"]),
            "min_sfr_energy_10pct": min_sfr_at(0.10, cc["energy"]),
            "paper_min_sfr_energy": PAPER_MIN_SFR_ENERGY_8.get(variant),
            "curves": cc,
        }

    if verbose:
        print(f"\n== Fig. 5: overhead vs SFR size ({n_cores} cores) ==")
        hdr = "SFR:       " + "".join(f"{s:>8d}" for s in SFRS)
        print(hdr)
        for variant in variants:
            row = curves[variant]["energy"]
            print(
                f"{variant:5s} E-ovh " + "".join(f"{ov*100:7.1f}%" for _, ov in row)
            )
        print("\nminimum SFR @ 10% energy overhead (measured vs paper):")
        for variant in variants:
            m = result[variant]["min_sfr_energy_10pct"]
            p = result[variant]["paper_min_sfr_energy"]
            ps = f"(paper {p:7.1f})" if p is not None else "(paper    -  )"
            print(f"  {variant:5s}: {m:8.1f} cycles   {ps}")
        ratio = (
            result["sw"]["min_sfr_energy_10pct"]
            / max(result["scu"]["min_sfr_energy_10pct"], 1e-9)
        )
        print(f"  SW/SCU reduction: {ratio:.1f}x (paper: ~41x)")
    return result


if __name__ == "__main__":
    run()
