"""Resilience benchmark: fault-rate x recovery-mode sweep on the sweep service.

A fixed, deterministic stream of 8-core SCU barrier jobs is served by the
slot-recycling fleet (``repro.serve.fleet_service``) while a seeded
:class:`repro.core.scu.faults.FaultPlan` injects lost barrier wake-ups into
a fraction of the jobs (the *fault rate*).  A lost barrier wake deadlocks
its cluster -- the victim sleeps forever on an event the SCU already
consumed -- so an unprotected job burns its whole cycle budget and times
out.  Four recovery modes run the identical stream:

* ``none``      -- legacy fail-fast: first timeout is terminal;
* ``retry``     -- :class:`RetryPolicy` re-runs failed jobs with exponential
  backoff; the fault is transient (attempt 1 only), so every retry lands;
* ``degrade``   -- the fault is *persistent* (every scu attempt loses the
  wake), so retrying the same config cannot help; after ``degrade_after``
  failures the service rebuilds the job on the fallback ``sw`` policy;
* ``watchdog``  -- no retries: a release-mode :class:`Watchdog` on the SCU
  force-wakes stuck sleepers in-run, completing every job first attempt.

Reported per (fault-rate, mode) cell: failure rate, recovery latency
(mean scheduler rounds submit-to-terminal), wasted cycles (cycle budget
burnt by failed attempts), total attempts, degraded jobs and watchdog
releases.  Everything is counted in cycles or scheduler rounds of a seeded
deterministic simulation, so the numbers are bit-exact across machines and
hard-gated by ``scripts/bench_compare.py``; the artifact is identical under
``--fast`` and full runs.

    PYTHONPATH=src python -m benchmarks.resilience [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import random
from typing import Dict, List, Optional

from repro.core.scu.faults import FaultEvent, FaultPlan, Watchdog
from repro.core.scu.programs import prep_barrier_bench
from repro.serve.fleet_service import FleetService, RetryPolicy

# fixed stream geometry: 12 eight-core barrier jobs on a 4x8-lane fleet --
# small enough that the benchmark is cheap, wide enough that failed jobs
# and their retries genuinely compete for slots
N_JOBS = 12
N_SLOTS = 4
SLOT_CORES = 8
ITERS = 6
SFR = 60
# cycle budget per attempt: a deadlocked job burns exactly this many cycles
# before timing out, which makes "wasted cycles" a crisp, countable cost
MAX_CYCLES = 4000

# the barrier event line (EV.BARRIER); losing it on one core deadlocks the
# whole barrier -- everyone else arrives and sleeps waiting for round N+1
_BARRIER_LINE_MASK = 1 << 8

FAULT_RATES = (0.0, 0.5)
MODES = ("none", "retry", "degrade", "watchdog")

_SEED = 0xFA017


def _victims(rate: float) -> List[Optional[int]]:
    """Deterministic per-job victim core (None = job runs clean)."""
    rng = random.Random(_SEED)
    out: List[Optional[int]] = []
    for _ in range(N_JOBS):
        hit = rng.random() < rate
        core = rng.randrange(SLOT_CORES)  # always drawn: rates share victims
        out.append(core if hit else None)
    return out


def _fault_plan(victim: int) -> FaultPlan:
    """Lose the barrier wake on ``victim`` early in the run (plans are
    single-use, so build a fresh one per attempt)."""
    return FaultPlan([
        FaultEvent("lost_wake", cycle=10, core=victim, lines=_BARRIER_LINE_MASK)
    ])


def _config(policy: str, victim: Optional[int], watchdog: bool,
            sink: Optional[List[Watchdog]] = None):
    fb = prep_barrier_bench(policy, SLOT_CORES, sfr=SFR, iters=ITERS)
    fb.config.max_cycles = MAX_CYCLES
    cl = fb.config.cluster
    if victim is not None:
        cl.faults = _fault_plan(victim)
    if watchdog and cl.scu is not None:
        wd = Watchdog(timeout=400, mode="release")
        cl.scu.watchdog = wd
        if sink is not None:
            sink.append(wd)
    return fb.config


def _run_cell(rate: float, mode: str) -> Dict:
    victims = _victims(rate)
    watchdogs: List[Watchdog] = []

    retry = None
    if mode == "retry":
        retry = RetryPolicy(max_attempts=3, backoff_rounds=1, backoff_factor=2)
    elif mode == "degrade":
        retry = RetryPolicy(max_attempts=3, backoff_rounds=1, degrade_after=1)

    svc = FleetService(
        n_slots=N_SLOTS, slot_cores=SLOT_CORES,
        queue_limit=N_JOBS, retry=retry,
    )

    jobs = []
    for victim in victims:
        if mode == "retry":
            # transient fault: only the first attempt loses the wake
            def factory(attempt, v=victim):
                return _config("scu", v if attempt == 1 else None, False)
            jobs.append(svc.submit(factory=factory))
        elif mode == "degrade":
            # persistent fault: every scu attempt loses the wake; the
            # fallback rebuilds on the software policy (no SCU sleep to lose)
            def factory(attempt, v=victim):
                return _config("scu", v, False)

            def fallback(attempt):
                return _config("sw", None, False)
            jobs.append(svc.submit(factory=factory, fallback_factory=fallback))
        elif mode == "watchdog":
            def factory(attempt, v=victim):
                return _config("scu", v, True, sink=watchdogs)
            jobs.append(svc.submit(factory=factory))
        else:  # none
            def factory(attempt, v=victim):
                return _config("scu", v, False)
            jobs.append(svc.submit(factory=factory))

    svc.run_until_drained()

    failed = [j for j in jobs if j.state == "failed"]
    done = [j for j in jobs if j.state == "done"]
    assert len(failed) + len(done) == N_JOBS
    lat = [j.latency_rounds for j in jobs]
    return {
        "failure_rate": len(failed) / N_JOBS,
        "failed_jobs": len(failed),
        "completed_jobs": len(done),
        "total_attempts": sum(j.attempts for j in jobs),
        "degraded_jobs": sum(1 for j in jobs if j.degraded),
        "wasted_cycles": sum(j.wasted_cycles for j in jobs),
        "rounds": svc.round,
        "mean_latency_rounds": sum(lat) / N_JOBS,
        "watchdog_releases": sum(w.release_count for w in watchdogs),
        "mean_completed_cycles": (
            sum(j.stats.cycles for j in done) / len(done) if done else 0.0
        ),
    }


def run(verbose: bool = True) -> Dict:
    cells: Dict[str, Dict[str, Dict]] = {}
    for rate in FAULT_RATES:
        key = f"rate{rate:g}"
        cells[key] = {mode: _run_cell(rate, mode) for mode in MODES}

    # the headline claim, asserted (not just reported): at a fault rate
    # where fail-fast loses jobs, every recovery mode completes the stream
    faulty = cells[f"rate{FAULT_RATES[-1]:g}"]
    assert faulty["none"]["failed_jobs"] > 0, "fault rate too low to matter"
    for mode in ("retry", "degrade", "watchdog"):
        assert faulty[mode]["failure_rate"] == 0.0, (
            f"{mode} mode lost jobs: {faulty[mode]}"
        )
    # and clean traffic is untouched by the recovery machinery
    clean = cells[f"rate{FAULT_RATES[0]:g}"]
    assert all(c["failure_rate"] == 0.0 for c in clean.values())
    assert clean["none"]["total_attempts"] == N_JOBS

    result = {
        "fleet": {"n_slots": N_SLOTS, "slot_cores": SLOT_CORES},
        "n_jobs": N_JOBS,
        "max_cycles": MAX_CYCLES,
        "fault_rates": list(FAULT_RATES),
        "cells": cells,
    }

    if verbose:
        print(f"\n== Resilience sweep ({N_JOBS} jobs, "
              f"{N_SLOTS}x{SLOT_CORES}-lane fleet, lost barrier wake-ups) ==")
        print(f"{'rate':>5s} {'mode':9s} {'fail%':>6s} {'attempts':>8s} "
              f"{'wasted cyc':>10s} {'rounds':>7s} {'mean lat':>8s} "
              f"{'degr':>4s} {'wd rel':>6s}")
        for rate in FAULT_RATES:
            for mode in MODES:
                c = cells[f"rate{rate:g}"][mode]
                print(
                    f"{rate:5.2f} {mode:9s} {c['failure_rate']:6.0%} "
                    f"{c['total_attempts']:8d} {c['wasted_cycles']:10d} "
                    f"{c['rounds']:7d} {c['mean_latency_rounds']:8.1f} "
                    f"{c['degraded_jobs']:4d} {c['watchdog_releases']:6d}"
                )
        f = faulty
        print(
            f"\nat {FAULT_RATES[-1]:.0%} fault rate: fail-fast loses "
            f"{f['none']['failed_jobs']}/{N_JOBS} jobs; retry/degrade/watchdog "
            f"complete 12/12 (wasted cycles {f['none']['wasted_cycles']} -> "
            f"{f['retry']['wasted_cycles']} / {f['degrade']['wasted_cycles']} / "
            f"{f['watchdog']['wasted_cycles']})"
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", help="write results as JSON")
    args = ap.parse_args()
    result = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
