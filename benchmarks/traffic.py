"""Sweep-service traffic benchmark: continuous batching vs drain baseline.

A fixed, deterministic stream of heterogeneous sweep jobs (every registered
policy x barrier/mutex/chain/work-queue shapes x 8/16 cores) is served by
the slot-recycling fleet (``repro.serve.fleet_service``) under two arrival
processes -- bursty and Poisson -- and two admission modes on the *same*
engine:

* ``continuous`` -- finished jobs free lanes mid-flight, queued jobs take
  them at the next scheduling round;
* ``drain`` -- the submit-in-fixed-batches baseline: admissions wait until
  the whole fleet has drained, the utilization loss continuous batching
  removes.

Reported per scenario and mode: completion rounds, p50/p99 job latency and
the idle-lane fraction -- all counted in **scheduler rounds**, so they are
bit-deterministic and hard-gated by ``scripts/bench_compare.py`` like every
cycle metric.  Wall-clock enters only as the same-run ``speedup`` ratio
(drain wall / continuous wall), soft-gated like the engine_perf ratios.
The per-job energy split (``repro.serve.energy``) adds tail energy per
discipline: p99 spin vs idle energy across each policy's jobs.

    PYTHONPATH=src python -m benchmarks.traffic [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.scu.programs import (
    FleetBench,
    prep_barrier_bench,
    prep_chain_bench,
    prep_mutex_bench,
    prep_work_queue_bench,
)
from repro.serve.arrivals import bursty_trace, poisson_trace
from repro.serve.energy import job_energy
from repro.serve.fleet_service import FleetService
from repro.sync import available_policies

# fixed service geometry: 6 slots x 16 lanes; 8-core jobs occupy half a
# slot (the wasted tail lanes are charged to the idle fraction honestly)
N_SLOTS = 6
SLOT_CORES = 16

ADMISSION_MODES = ("continuous", "drain")


def _job_mix() -> List[Tuple[str, FleetBench]]:
    """The deterministic job stream: 6 shapes per registered policy.

    Service times spread over two orders of magnitude (short hardware
    barriers to long software mutex herds), which is what makes fixed
    batches straggle.  Fresh benches every call -- generators are
    single-use."""
    jobs: List[Tuple[str, FleetBench]] = []
    for p in available_policies():
        jobs += [
            (p, prep_barrier_bench(p, 8, sfr=0, iters=6)),
            (p, prep_mutex_bench(p, 8, t_crit=10, iters=6)),
            (p, prep_barrier_bench(p, 8, sfr=400, iters=4)),
            (p, prep_barrier_bench(p, 16, sfr=50, iters=4)),
            (p, prep_chain_bench(p, 8, sfr=100, iters=4, depth=4)),
            (p, prep_work_queue_bench(p, 4, 4, items=16)),
        ]
    return jobs


def _arrival_traces(n_jobs: int) -> Dict[str, List[int]]:
    """Both scenarios, deterministic in the fixed seeds.

    Bursty: bursts wider than the fleet, long gaps between them -- the
    adversarial case for drain dispatch.  Poisson: steady random load."""
    assert n_jobs % 7 == 0, "mix is 6 shapes x policies; bursts of 7 tile it"
    return {
        "bursty": bursty_trace(
            n_bursts=n_jobs // 7, burst_size=7, gap_rounds=600,
            seed=17, jitter=40,
        ),
        "poisson": poisson_trace(rate=0.01, n_jobs=n_jobs, seed=17),
    }


def _serve(benches, arrivals, mode: str):
    """Run one (scenario, mode) cell; returns (service, jobs, wall_s)."""
    svc = FleetService(
        n_slots=N_SLOTS, slot_cores=SLOT_CORES,
        queue_limit=len(benches), admission=mode,
    )
    jobs = []
    i = 0
    guard = 0
    t0 = time.perf_counter()
    while i < len(benches) or svc.pending or svc.fleet.occupied:
        while i < len(benches) and arrivals[i] <= svc.round:
            jobs.append(svc.submit(benches[i][1].config))
            i += 1
        svc.step()
        guard += 1
        if guard > 50_000_000:
            raise RuntimeError("traffic benchmark failed to drain")
    wall = time.perf_counter() - t0
    return svc, jobs, wall


def _pct(values, q) -> float:
    """Deterministic percentile (no interpolation -- an observed value)."""
    return float(np.percentile(np.asarray(values, dtype=np.int64), q,
                               method="lower"))


def run(verbose: bool = True) -> Dict:
    mix = _job_mix()
    traces = _arrival_traces(len(mix))

    scenarios: Dict[str, Dict] = {}
    wall_totals = {m: 0.0 for m in ADMISSION_MODES}
    energy_jobs = None  # per-policy tail energy, from the bursty/continuous cell
    for name, trace in traces.items():
        cell: Dict[str, Dict] = {}
        for mode in ADMISSION_MODES:
            benches = _job_mix()  # fresh generators per cell
            svc, jobs, wall = _serve(benches, trace, mode)
            assert len(jobs) == len(mix)
            assert all(j.error is None for j in jobs)
            lat = [j.latency_rounds for j in jobs]
            cell[mode] = {
                "rounds": svc.round,
                "p50_latency_rounds": _pct(lat, 50),
                "p99_latency_rounds": _pct(lat, 99),
                "idle_lane_fraction": svc.idle_lane_fraction,
                "wall_s": wall,
            }
            wall_totals[mode] += wall
            if name == "bursty" and mode == "continuous":
                energy_jobs = [(label, j) for (label, _), j in zip(benches, jobs)]
        scenarios[name] = {
            "arrivals": {"first": trace[0], "last": trace[-1]},
            **cell,
        }

    # tail energy per discipline: p99 of the idle/spin split across each
    # policy's jobs (deterministic -- pure function of the gated stats)
    energy_tail: Dict[str, Dict[str, float]] = {}
    for policy in available_policies():
        splits = [job_energy(j.stats) for label, j in energy_jobs
                  if label == policy]
        energy_tail[policy] = {
            "p99_spin_pj": _pct([round(e.spin_pj) for e in splits], 99),
            "p99_idle_pj": _pct([round(e.idle_pj) for e in splits], 99),
        }

    result = {
        "fleet": {"n_slots": N_SLOTS, "slot_cores": SLOT_CORES},
        "n_jobs": len(mix),
        "scenarios": scenarios,
        "energy_tail": energy_tail,
        # same-run dispatch ratio (the soft-gated key): how much wall time
        # the drain baseline costs relative to continuous admission
        "speedup": wall_totals["drain"] / max(wall_totals["continuous"], 1e-9),
    }

    if verbose:
        print(f"\n== Sweep-service traffic ({len(mix)} jobs, "
              f"{N_SLOTS}x{SLOT_CORES}-lane fleet) ==")
        print(f"{'scenario':9s} {'mode':11s} {'rounds':>8s} {'p50 lat':>9s} "
              f"{'p99 lat':>9s} {'idle':>6s}")
        for name, sc in scenarios.items():
            for mode in ADMISSION_MODES:
                r = sc[mode]
                print(
                    f"{name:9s} {mode:11s} {r['rounds']:8d} "
                    f"{r['p50_latency_rounds']:9.0f} "
                    f"{r['p99_latency_rounds']:9.0f} "
                    f"{r['idle_lane_fraction']:6.1%}"
                )
        b = scenarios["bursty"]
        print(
            f"\nbursty p99 latency: drain {b['drain']['p99_latency_rounds']:.0f}"
            f" -> continuous {b['continuous']['p99_latency_rounds']:.0f} rounds"
            f"; idle lanes {b['drain']['idle_lane_fraction']:.1%} -> "
            f"{b['continuous']['idle_lane_fraction']:.1%}"
        )
        print(f"wall-clock: drain/continuous = {result['speedup']:.2f}x")
        tail = ", ".join(
            f"{p}: spin {v['p99_spin_pj']:.0f} / idle {v['p99_idle_pj']:.0f}"
            for p, v in energy_tail.items()
        )
        print(f"p99 energy per discipline (pJ): {tail}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", help="write results as JSON")
    args = ap.parse_args()
    result = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
