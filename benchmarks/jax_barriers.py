"""Chip-level barrier strategies: real wall-clock on host devices.

The Fig. 5 experiment transplanted to devices: N host devices execute
(compute-region + barrier) loops under every registered ``repro.sync``
policy; we sweep the compute-region size and report the measured overhead
curves + min region @10% -- the shape of the paper's result reproduced at
chip granularity with actual timings.

Run in a fresh process (device count must be set before jax init):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.jax_barriers
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_axis_mesh, shard_map
from repro.sync import available_policies, get_policy

REGION_SIZES = [1, 2, 4, 8, 16, 32, 64]  # matmul repetitions between barriers
N_BARRIERS = 16
DIM = 128


def _make_step(mesh, strategy: str, region: int):
    def body(x, a):
        # compute region: `region` small matmuls (the SFR analogue)
        for _ in range(N_BARRIERS):
            for _ in range(region):
                x = jnp.tanh(x @ a)
            cnt = get_policy(strategy).chip_barrier(jnp.ones((), jnp.float32), "x")
            x = x + cnt * 0  # keep the barrier on the graph
        return x

    return jax.jit(
        shard_map(body, mesh=mesh, in_specs=(P("x"), P()), out_specs=P("x"))
    )


def _time(fn, *args, reps=5) -> float:
    fn(*args)[0].block_until_ready()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(verbose: bool = True) -> Dict:
    n = jax.device_count()
    if n < 2:
        print("[jax_barriers] needs >=2 devices; skipping")
        return {}
    mesh = make_axis_mesh((n,), ("x",))
    x = jnp.ones((n * 8, DIM), jnp.float32)
    a = jnp.eye(DIM, dtype=jnp.float32) * 0.99

    strategies = available_policies()
    results: Dict = {"devices": n, "curves": {}}
    # reference: compute-only time per region unit
    def compute_only(x, a, region=max(REGION_SIZES)):
        def body(x, a):
            for _ in range(N_BARRIERS):
                for _ in range(region):
                    x = jnp.tanh(x @ a)
            return x
        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("x"), P()), out_specs=P("x")))

    t_full = _time(compute_only(x, a), x, a)
    unit = t_full / (N_BARRIERS * max(REGION_SIZES))

    for strategy in strategies:
        curve = []
        for region in REGION_SIZES:
            fn = _make_step(mesh, strategy, region)
            t = _time(fn, x, a)
            t_ideal = unit * N_BARRIERS * region
            overhead = (t - t_ideal) / t_ideal
            curve.append((region, t / N_BARRIERS * 1e6, overhead))
        results["curves"][strategy] = curve

    if verbose:
        print(f"\n== Chip-level barrier disciplines ({n} host devices) ==")
        print("region  " + "".join(f"{s:>10s}" for s in strategies))
        for i, region in enumerate(REGION_SIZES):
            row = [results["curves"][s][i][2] for s in strategies]
            print(f"{region:6d}  " + "".join(f"{o*100:9.0f}%" for o in row))
        for s in strategies:
            per_barrier = results["curves"][s][0][1]
            print(f"  {s}: ~{per_barrier:.0f} us per barrier at region=1")
    return results


if __name__ == "__main__":
    run()
