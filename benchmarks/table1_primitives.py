"""Paper Table 1: raw synchronization-primitive costs (cycles + energy).

Reproduces the paper's microbenchmark methodology on the Tier-1 simulator:
loops of back-to-back primitives on 2/4/8 cores, averaged; energy from the
calibrated model.  Prints measured vs paper values and relative error.

The variant list comes from the ``repro.sync`` policy registry, so every
registered discipline is measured -- the paper's triad against its Table 1
numbers, extensions (e.g. ``tree``) as new rows without paper references.

:func:`run_scaling` extends the table beyond the paper's 8-core cluster to
MemPool-scale 16..256-core clusters (Riedel et al., 2023) -- affordable
because the event-driven engine skips quiescent cycles (see
``benchmarks/engine_perf.py``).

Both sweeps dispatch through the **fleet engine**: every (primitive,
policy, core-count) cell is prepared up front and the whole table runs as
one batched ``simulate_fleet`` call (bit-exact per config against
one-at-a-time runs; see ``repro.core.scu.engine``).
"""

from __future__ import annotations

from repro.core.scu.energy import DEFAULT_ENERGY, Activity
from repro.core.scu.programs import make_fleet, prep_barrier_bench, prep_mutex_bench
from repro.sync import available_policies

PAPER = {
    # (primitive, policy): ((cycles 2/4/8), (energy nJ 2/4/8))
    ("barrier", "scu"): ((6, 6, 6), (0.1, 0.1, 0.1)),
    ("barrier", "tas"): ((52, 91, 176), (0.8, 1.7, 4.3)),
    ("barrier", "sw"): ((47, 87, 176), (0.8, 1.8, 4.7)),
    ("mutex_t0", "scu"): ((12, 23, 44), (0.2, 0.3, 0.6)),
    ("mutex_t0", "tas"): ((25, 39, 69), (0.4, 0.7, 1.6)),
    ("mutex_t0", "sw"): ((12, 25, 72), (0.2, 0.5, 1.6)),
    ("mutex_t10", "scu"): ((13, 24, 50), (0.2, 0.3, 0.7)),
    ("mutex_t10", "tas"): ((26, 50, 89), (0.4, 0.9, 2.1)),
    ("mutex_t10", "sw"): ((13, 26, 55), (0.2, 0.6, 1.5)),
}

PRIMITIVES = ("barrier", "mutex_t0", "mutex_t10")


def _energy_nj(r, n, t_crit):
    act = Activity.per_iter(
        r.stats, r.iters, comp_offset=n * t_crit, cycles_offset=n * t_crit
    )
    return DEFAULT_ENERGY.energy_nj(act)


def _prep_cell(prim: str, policy: str, n: int, iters: int):
    if prim == "barrier":
        return prep_barrier_bench(policy, n, sfr=0, iters=iters)
    t_crit = 10 if prim.endswith("t10") else 0
    return prep_mutex_bench(policy, n, t_crit=t_crit, iters=iters)


def run(iters: int = 64, verbose: bool = True):
    # one batched fleet call for the whole table (prim x policy x cores)
    cells = [
        (prim, policy, n)
        for prim in PRIMITIVES
        for policy in available_policies()
        for n in (2, 4, 8)
    ]
    results = iter(make_fleet([_prep_cell(p, v, n, iters) for p, v, n in cells]))
    rows = []
    for prim in PRIMITIVES:
        t_crit = 10 if prim.endswith("t10") else 0
        for policy in available_policies():
            meas_c, meas_e = [], []
            for n in (2, 4, 8):
                r = next(results)
                meas_c.append(r.prim_cycles)
                meas_e.append(_energy_nj(r, n, t_crit))
            pc, pe = PAPER.get((prim, policy), (None, None))
            rows.append((prim, policy, meas_c, pc, meas_e, pe))

    if verbose:
        print("\n== Table 1: primitive costs (simulated vs paper) ==")
        print(f"{'prim':10s} {'var':4s} | cycles meas (paper)            | energy nJ meas (paper)")
        for prim, var, mc, pc, me, pe in rows:
            cyc = "  ".join(
                f"{m:6.1f}({str(p) if pc else '-':>3s})"
                for m, p in zip(mc, pc or (None,) * 3)
            )
            en = "  ".join(
                f"{m:5.2f}({str(p) if pe else '-':>3s})"
                for m, p in zip(me, pe or (None,) * 3)
            )
            print(f"{prim:10s} {var:4s} | {cyc} | {en}")
        scu8 = next(r for r in rows if r[0] == "barrier" and r[1] == "scu")
        sw8 = next(r for r in rows if r[0] == "barrier" and r[1] == "sw")
        print(
            f"\nSCU vs SW barrier @8 cores: {sw8[2][2]/scu8[2][2]:.1f}x cycles "
            f"(paper: 29x), {sw8[4][2]/scu8[4][2]:.1f}x energy (paper: 41x)"
        )
    return rows


def run_scaling(
    core_counts=(16, 32, 64, 128, 256), iters: int = 8, verbose: bool = True
):
    """Table-1 rows beyond the paper: 16..256-core clusters, every policy.

    The paper's SCU supports up to 16 cores; these rows extrapolate its
    design point to MemPool-scale clusters (Riedel et al. 2023 run 256
    cores), where the hardware barrier's O(1) cost versus the central-
    counter barriers' superlinear growth (and the tournament tree's log
    depth) is the whole argument.  The 128/256-core rows average fewer
    iterations: the software disciplines' per-iteration cost grows
    superlinearly while the averages converge just as fast.
    """
    # one fleet per core count: configs of one size stay one array program
    # (mixing a 256-core straggler into the 16-core batch would widen every
    # flattened kernel for the whole run)
    per_n = {}
    for n in core_counts:
        it = iters if n < 128 else max(2, iters // 4)
        cells = [
            (prim, policy)
            for prim in PRIMITIVES
            for policy in available_policies()
        ]
        per_n[n] = dict(zip(cells, make_fleet([
            _prep_cell(p, v, n, it) for p, v in cells
        ])))
    rows = []
    for prim in PRIMITIVES:
        t_crit = 10 if prim.endswith("t10") else 0
        for policy in available_policies():
            meas_c, meas_e = [], []
            for n in core_counts:
                r = per_n[n][(prim, policy)]
                meas_c.append(r.prim_cycles)
                meas_e.append(_energy_nj(r, n, t_crit))
            rows.append((prim, policy, list(core_counts), meas_c, meas_e))

    if verbose:
        counts = "/".join(str(n) for n in core_counts)
        print(f"\n== Table 1 (scaling): primitive costs @ {counts} cores ==")
        print(f"{'prim':10s} {'var':5s} | cycles {counts:24s} | energy nJ")
        for prim, var, _, mc, me in rows:
            cyc = "  ".join(f"{m:8.1f}" for m in mc)
            en = "  ".join(f"{m:6.2f}" for m in me)
            print(f"{prim:10s} {var:5s} | {cyc} | {en}")
        nmax = core_counts[-1]
        scu = next(r for r in rows if r[0] == "barrier" and r[1] == "scu")
        sw = next(r for r in rows if r[0] == "barrier" and r[1] == "sw")
        print(
            f"\nSCU vs SW barrier @{nmax} cores: {sw[3][-1]/scu[3][-1]:.0f}x "
            f"cycles, {sw[4][-1]/scu[4][-1]:.0f}x energy (paper @8: 29x/41x)"
        )
    return rows


if __name__ == "__main__":
    run()
    run_scaling()
