"""Checkpoint/restore benchmark: live migration and preemptive priority.

Two deterministic experiments over the checkpoint/restore machinery
(:mod:`repro.core.scu.checkpoint`), both counted in cycles and scheduler
rounds of seeded runs, so every number is bit-exact across machines and
hard-gated by ``scripts/bench_compare.py``.

**Migration** -- a :class:`repro.serve.fleet_pool.FleetPool` of two
single-slot domains serves compiled 8-core SCU barrier jobs.  Domain 0 is
sick: every admission there is armed with a voltage droop that freezes all
eight cores mid-run, so the attempt burns to its ``max_cycles`` cap and
times out.  The identical schedule runs twice:

* ``restart`` -- plain reroute: the retry is rebuilt from scratch on the
  healthy domain, so the whole failed attempt (``max_cycles`` cycles) is
  wasted;
* ``migrate`` -- a :class:`repro.serve.fleet_service.CheckpointPolicy`
  checkpoints in-flight members every few rounds; the retry *resumes* from
  the last pre-fault checkpoint on the healthy domain (the plan is
  stripped -- the fault was the domain's, not the job's), so only the
  cycles since that checkpoint are lost.

**Preemptive scheduling** -- a single-lane
:class:`repro.serve.fleet_service.FleetService` runs long low-priority
jobs; a short high-priority job arrives while the lane is busy and the
queue is deep.  Three admission disciplines run the identical stream:

* ``fifo``     -- arrival order: the high-priority job drains last;
* ``priority`` -- the queue is priority-ordered, but the running job
  holds the lane until it finishes;
* ``preempt``  -- the running job is checkpointed and evicted, the
  high-priority job takes its lane the round it arrives, and the victim
  resumes from its checkpoint later -- losing zero cycles.

The headline claims are asserted in-run, not just reported: migration
wastes strictly fewer cycles than restart-reroute on the same fault
script, and the preempting service admits the high-priority job before
any queued low-priority job while wasting no cycles on the victim.

    PYTHONPATH=src python -m benchmarks.preemption [--json PATH]
"""

from __future__ import annotations

import argparse
import json
from typing import Dict

from repro.core.scu.faults import FaultEvent, FaultPlan
from repro.core.scu.programs import prep_barrier_bench
from repro.serve.fleet_pool import FleetPool
from repro.serve.fleet_service import (
    CheckpointPolicy,
    FleetService,
    RetryPolicy,
)

SLOT_CORES = 8
SFR = 20

# migration experiment: two single-slot domains, domain 0 sick
N_DOMAINS = 2
MIG_JOBS = 2
MIG_ITERS = 128  # ~3.3k cycles clean, so the droop below lands mid-run
MIG_MAX_CYCLES = 4000  # a frozen attempt burns exactly this much
DROOP_CYCLE = 2000  # fault fires past several checkpoint boundaries
CKPT_INTERVAL = 4  # rounds between in-flight checkpoints
VICTIM_DOMAIN = 0

# scheduling experiment: one lane, deep queue of long jobs
LOW_JOBS = 3
LOW_ITERS = 128
HI_ITERS = 8
HI_PRIORITY = 5
HI_ARRIVAL_ROUND = 6  # service rounds before the high-priority job lands

SCHED_MODES = ("fifo", "priority", "preempt")
MIG_MODES = ("restart", "migrate")


def _job_config(iters: int, max_cycles: int = 10_000_000):
    """A compiled (trace-lowered, hence checkpointable) SCU barrier job."""
    fb = prep_barrier_bench("scu", SLOT_CORES, sfr=SFR, iters=iters,
                            compiled=True)
    fb.config.max_cycles = max_cycles
    return fb.config


def _droop_plan() -> FaultPlan:
    """Freeze every core long past the cycle budget: the attempt times
    out at ``max_cycles`` with its (uncorrupted) state stuck mid-run."""
    return FaultPlan([
        FaultEvent("droop", cycle=DROOP_CYCLE, cores=tuple(range(SLOT_CORES)),
                   span=1_000_000, domain="sick")
    ])


def _inject(domain: int, config):
    """Domain-scoped chaos: every fresh admission to the victim domain is
    droop-armed (checkpoint-resumed admissions skip this hook -- the
    fault belongs to the domain, not the resumed job)."""
    if domain == VICTIM_DOMAIN:
        config.cluster.faults = _droop_plan()
    return config


def _factory(attempt: int):
    return _job_config(MIG_ITERS, MIG_MAX_CYCLES)


def _run_migration_cell(mode: str) -> Dict:
    pool = FleetPool(
        n_domains=N_DOMAINS, n_slots=1, slot_cores=SLOT_CORES,
        retry=RetryPolicy(max_attempts=3, backoff_rounds=0, reroute=True),
        inject=_inject,
        checkpoint=CheckpointPolicy(CKPT_INTERVAL) if mode == "migrate"
        else None,
    )
    jobs = [pool.submit(factory=_factory) for _ in range(MIG_JOBS)]
    pool.run_until_drained(max_rounds=200_000)

    failed = [j for j in jobs if j.state == "failed"]
    lat = [j.latency_rounds for j in jobs]
    return {
        "failure_rate": len(failed) / MIG_JOBS,
        "failed_jobs": len(failed),
        "completed_jobs": MIG_JOBS - len(failed),
        "total_attempts": sum(j.attempts for j in jobs),
        "wasted_cycles": pool.wasted_cycles,
        "reroutes": pool.reroutes,
        "migrations": pool.migrations,
        "rounds": pool.round,
        "mean_latency_rounds": sum(lat) / MIG_JOBS,
    }


def _run_schedule_cell(mode: str) -> Dict:
    svc = FleetService(
        1, SLOT_CORES,
        admission_order="fifo" if mode == "fifo" else "priority",
        preempt=(mode == "preempt"),
    )
    lows = [svc.submit(_job_config(LOW_ITERS)) for _ in range(LOW_JOBS)]
    for _ in range(HI_ARRIVAL_ROUND):
        svc.step()
    hi = svc.submit(_job_config(HI_ITERS), priority=HI_PRIORITY)
    svc.run_until_drained()

    jobs = lows + [hi]
    assert all(j.state == "done" for j in jobs), [j.state for j in jobs]
    if mode == "preempt":
        # the headline: the high-priority job took a busy lane the round
        # it arrived, ahead of every queued low-priority job, and the
        # suspended victim lost zero cycles
        assert svc.preemptions >= 1, "preempt cell never preempted"
        assert hi.admitted_round == hi.submitted_round
        queued_lows = [j for j in lows if j.admitted_round > hi.submitted_round]
        assert all(hi.admitted_round < j.admitted_round for j in queued_lows)
        assert sum(j.wasted_cycles for j in jobs) == 0, (
            "preemption must not waste victim cycles"
        )
    lat = [j.latency_rounds for j in jobs]
    return {
        "failure_rate": 0.0,
        "completed_jobs": len(jobs),
        "preemptions": svc.preemptions,
        "wasted_cycles": sum(j.wasted_cycles for j in jobs),
        "rounds": svc.round,
        "mean_latency_rounds": sum(lat) / len(jobs),
        "hi_latency_rounds": hi.latency_rounds,
        "hi_queue_rounds": hi.queue_rounds,
    }


def run(verbose: bool = True) -> Dict:
    migration = {mode: _run_migration_cell(mode) for mode in MIG_MODES}
    schedule = {mode: _run_schedule_cell(mode) for mode in SCHED_MODES}

    # headline claims, asserted (not just reported)
    mig, res = migration["migrate"], migration["restart"]
    assert res["failure_rate"] == 0.0 and mig["failure_rate"] == 0.0, (
        "both recovery modes must complete the stream"
    )
    assert mig["migrations"] >= 1, "migrate cell never migrated"
    assert mig["wasted_cycles"] < res["wasted_cycles"], (
        "resuming from a checkpoint must waste strictly fewer cycles "
        f"than restarting: {mig['wasted_cycles']} vs {res['wasted_cycles']}"
    )
    hi_lat = {m: schedule[m]["hi_latency_rounds"] for m in SCHED_MODES}
    assert hi_lat["preempt"] < hi_lat["priority"] <= hi_lat["fifo"], (
        f"priority/preemption must cut high-priority latency: {hi_lat}"
    )

    result = {
        "geometry": {"slot_cores": SLOT_CORES, "n_domains": N_DOMAINS,
                     "victim_domain": VICTIM_DOMAIN,
                     "checkpoint_interval_rounds": CKPT_INTERVAL},
        "migration": migration,
        "schedule": schedule,
    }

    if verbose:
        print(f"\n== Live migration ({MIG_JOBS} jobs, {N_DOMAINS} domains "
              f"x 1x{SLOT_CORES} lanes, domain {VICTIM_DOMAIN} droops at "
              f"cycle {DROOP_CYCLE}, budget {MIG_MAX_CYCLES}) ==")
        print(f"{'mode':8s} {'wasted cyc':>10s} {'attempts':>8s} "
              f"{'reroutes':>8s} {'migrations':>10s} {'rounds':>7s}")
        for mode in MIG_MODES:
            c = migration[mode]
            print(f"{mode:8s} {c['wasted_cycles']:10d} "
                  f"{c['total_attempts']:8d} {c['reroutes']:8d} "
                  f"{c['migrations']:10d} {c['rounds']:7d}")
        print(f"-> migration saves {res['wasted_cycles'] - mig['wasted_cycles']}"
              f" of {res['wasted_cycles']} wasted cycles on the same fault")

        print(f"\n== Preemptive priority ({LOW_JOBS} long low-priority jobs, "
              f"one priority-{HI_PRIORITY} arrival at round "
              f"{HI_ARRIVAL_ROUND}, single lane) ==")
        print(f"{'mode':9s} {'hi latency':>10s} {'hi queued':>9s} "
              f"{'mean lat':>8s} {'preempt':>7s} {'wasted':>6s}")
        for mode in SCHED_MODES:
            c = schedule[mode]
            print(f"{mode:9s} {c['hi_latency_rounds']:10d} "
                  f"{c['hi_queue_rounds']:9d} {c['mean_latency_rounds']:8.1f} "
                  f"{c['preemptions']:7d} {c['wasted_cycles']:6d}")
        print(f"-> preemption admits the high-priority job in its arrival "
              f"round (latency {hi_lat['fifo']} -> {hi_lat['priority']} -> "
              f"{hi_lat['preempt']} rounds) at zero wasted victim cycles")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", help="write results as JSON")
    args = ap.parse_args()
    result = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
