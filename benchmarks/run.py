"""Benchmark harness: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Order: Tier-1 paper reproduction (Table 1, Fig. 5, Table 2), then the
Tier-2 roofline read-out from the dry-run artifacts.  The chip-level
barrier timing benchmark needs its own process with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and is invoked as a
subprocess (device count is locked at jax init).
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the slow PCA app")
    args = ap.parse_args()

    from benchmarks import fig5_overhead, roofline, table1_primitives, table2_apps

    print("#" * 72)
    print("# Tier 1 -- paper-faithful reproduction (cycle-accurate simulator)")
    print("#" * 72)
    table1_primitives.run()
    fig5_overhead.run()
    table2_apps.run(include_slow=not args.fast)

    print("\n" + "#" * 72)
    print("# Tier 2 -- chip-level barrier disciplines (8 host devices)")
    print("#" * 72)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.jax_barriers"],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    print(r.stdout)
    if r.returncode != 0:
        print("[jax_barriers] failed:", r.stderr[-2000:])

    print("\n" + "#" * 72)
    print("# Tier 2 -- roofline from the multi-pod dry-run artifacts")
    print("#" * 72)
    roofline.run()


if __name__ == "__main__":
    main()
