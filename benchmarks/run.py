"""Benchmark harness: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json PATH]
                                            [--only SECTION[,SECTION...]]

Every section is a :class:`BenchSpec` in the ``BENCHES`` registry: a name
(the ``--only`` handle), a banner, the artifact keys it contributes to the
``--json`` output, and a runner.  The registry is the single source of
truth for the benchmark front-end -- ``--only`` validation, run order and
the JSON schema all derive from it, so a new section registers once and
cannot drift from ``scripts/bench_compare.py`` / ``tests/test_bench_schema``
(which introspect ``artifact_keys()``).

Order: Tier-1 paper reproduction (Table 1, Fig. 5, Table 2), the pipelined
producer-consumer chain and multi-producer work-queue microbenchmarks (SCU
event FIFO), the scaling sweeps (16/32/64/128/256-core clusters; --fast
samples 16/64/128/256), the engine-throughput benchmark (quiescent,
contended, fleet-dispatch and compiled-trace sweeps), the sweep-service
traffic benchmark (continuous batching vs drain baseline on the
slot-recycling fleet), the resilience sweep (deterministic fault
injection x recovery mode: retry, degradation, watchdog release), the
fault-domain chaos sweep (domain fault rate x routing policy on the
multi-fleet pool) and the checkpoint/restore benchmark (live migration vs
restart-reroute, preemptive priority scheduling), then the Tier-2
roofline read-out from the dry-run artifacts.  The
Table-1/Fig-5/chain/work-queue sweeps and their scaling variants dispatch
through the batched fleet engine
(``repro.core.scu.engine.simulate_fleet``); per-config numbers are
bit-exact against sequential runs.  The chip-level barrier timing
benchmark needs its own process with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and is invoked as a
subprocess (device count is locked at jax init); its failure propagates to
this process's exit code so CI actually gates on it.

``--only`` restricts the run to a comma-separated subset of sections (see
``BENCHES``; unknown names exit nonzero) for CI and local iteration.
Note a filtered ``--json`` artifact is partial and will not satisfy the
full schema gate in ``scripts/bench_compare.py``.

``--json`` writes the machine-readable key numbers (Table-1/Fig-5 rows,
scaling rows, engine throughput per mode) -- the seed of the performance
trajectory tracked across PRs.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import subprocess
import sys
from typing import Callable, Dict, Tuple


def _jsonable(obj):
    """Recursively convert benchmark results to strict-JSON-safe values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, (int, str, bool)) or obj is None:
        return obj
    return str(obj)


def _table1_json(rows):
    return [
        {
            "primitive": prim,
            "policy": policy,
            "cycles": meas_c,
            "paper_cycles": list(pc) if pc else None,
            "energy_nj": meas_e,
            "paper_energy_nj": list(pe) if pe else None,
        }
        for prim, policy, meas_c, pc, meas_e, pe in rows
    ]


def _table1_scaling_json(rows):
    return [
        {
            "primitive": prim,
            "policy": policy,
            "core_counts": counts,
            "cycles": meas_c,
            "energy_nj": meas_e,
        }
        for prim, policy, counts, meas_c, meas_e in rows
    ]


def _fig5_json(result):
    return {
        variant: {
            "min_sfr_cycles_10pct": r["min_sfr_cycles_10pct"],
            "min_sfr_energy_10pct": r["min_sfr_energy_10pct"],
            "paper_min_sfr_energy": r["paper_min_sfr_energy"],
        }
        for variant, r in result.items()
    }


# --------------------------------------------------------------------------
# The bench registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """One registered benchmark section.

    ``run(args)`` returns ``(artifact_fragment, rc)``: the fragment is
    merged into the ``--json`` artifact; a nonzero rc propagates to the
    process exit code (the section already printed why).
    """

    name: str
    title: str
    json_keys: Tuple[str, ...]
    run: Callable[[argparse.Namespace], Tuple[Dict, int]]


BENCHES: Dict[str, BenchSpec] = {}


def register_bench(name: str, title: str, json_keys: Tuple[str, ...] = ()):
    """Register a section; insertion order is run order."""

    def deco(fn):
        BENCHES[name] = BenchSpec(name=name, title=title, json_keys=json_keys, run=fn)
        return fn

    return deco


def artifact_keys() -> Dict[str, Tuple[str, ...]]:
    """Section name -> the top-level ``--json`` keys it contributes (the
    contract the schema gate checks against)."""
    return {name: spec.json_keys for name, spec in BENCHES.items()}


@register_bench(
    "table1",
    "Tier 1 -- Table 1: primitive costs (cycle-accurate simulator)",
    ("table1",),
)
def _run_table1(args):
    from benchmarks import table1_primitives

    return {"table1": _table1_json(table1_primitives.run())}, 0


@register_bench(
    "fig5",
    "Tier 1 -- Fig. 5: synchronization overhead vs SFR",
    ("fig5",),
)
def _run_fig5(args):
    from benchmarks import fig5_overhead

    return {"fig5": _fig5_json(fig5_overhead.run(dense=not args.fast))}, 0


@register_bench(
    "table2",
    "Tier 1 -- Table 2: application kernels",
    ("table2",),
)
def _run_table2(args):
    from benchmarks import table2_apps

    return {"table2": table2_apps.run(include_slow=not args.fast)}, 0


@register_bench(
    "chain",
    "Tier 1 -- pipelined producer-consumer chains (SCU event FIFO)",
    ("chain",),
)
def _run_chain(args):
    from benchmarks import chain_pipeline

    return {"chain": chain_pipeline.run()}, 0


@register_bench(
    "work_queue",
    "Tier 1 -- multi-producer work queues (mutex vs SCU event FIFO)",
    ("work_queue",),
)
def _run_work_queue(args):
    from benchmarks import work_queue

    return {"work_queue": work_queue.run()}, 0


@register_bench(
    "scaling",
    "Tier 1 -- scaling sweeps (vectorized engine: 16..256 cores)",
    ("table1_scaling", "fig5_scaling", "chain_scaling", "work_queue_scaling"),
)
def _run_scaling(args):
    from benchmarks import chain_pipeline, fig5_overhead, table1_primitives, work_queue

    # --fast (the CI smoke) samples the decades; the full run is dense.
    # The 128/256-core rows are affordable because the contended path
    # runs on the vectorized structure-of-arrays engine core.
    scale_counts = (16, 64, 128, 256) if args.fast else (16, 32, 64, 128, 256)
    frag = {
        "table1_scaling": _table1_scaling_json(
            table1_primitives.run_scaling(core_counts=scale_counts)
        ),
        "fig5_scaling": {
            n: _fig5_json(r)
            for n, r in fig5_overhead.run_scaling(core_counts=scale_counts).items()
        },
        "chain_scaling": chain_pipeline.run_scaling(core_counts=scale_counts),
        "work_queue_scaling": work_queue.run_scaling(core_counts=scale_counts),
    }
    return frag, 0


@register_bench(
    "engine_perf",
    "Engine throughput -- lockstep vs fast-forward vs fleet vs compiled",
    ("engine_perf",),
)
def _run_engine_perf(args):
    from benchmarks import engine_perf

    # reduced sweep under --fast: the lockstep side is the slow half, and
    # the dedicated CI perf-smoke job already runs the full benchmark
    perf = (
        engine_perf.run(sfrs=(1000, 2500), iters=4)
        if args.fast
        else engine_perf.run()
    )
    contended = engine_perf.run_contended(
        core_counts=(8, 64) if args.fast else engine_perf.CONTENDED_CORES
    )
    fleet = engine_perf.run_fleet()
    compiled = engine_perf.run_compiled()
    frag = {
        "engine_perf": {
            "cycles_per_sec": perf["cycles_per_sec"],
            "speedup": perf["speedup"],
            "n_cores": perf["n_cores"],
            "sfrs": perf["sfrs"],
            "contended": {
                "cycles_per_sec": contended["cycles_per_sec"],
                "speedup": contended["speedup"],
                "core_counts": contended["core_counts"],
                "sfrs": contended["sfrs"],
            },
            "fleet": {
                "configs": fleet["configs"],
                "configs_8core": fleet["configs_8core"],
                "wall_s": fleet["wall_s"],
                "speedup": fleet["speedup"],
                "speedup_8core": fleet["speedup_8core"],
            },
            "compiled": {
                "configs": compiled["configs"],
                "iters": compiled["iters"],
                "wall_s": compiled["wall_s"],
                "lower_s": compiled["lower_s"],
                "trace_jumps": compiled["trace_jumps"],
                "trace_jump_cycles": compiled["trace_jump_cycles"],
                "speedup": compiled["speedup"],
                "speedup_incl_lowering": compiled["speedup_incl_lowering"],
            },
        }
    }
    return frag, 0


@register_bench(
    "traffic",
    "Sweep-service traffic -- continuous batching vs drain baseline",
    ("traffic",),
)
def _run_traffic(args):
    from benchmarks import traffic

    # one fixed size under --fast and full: the round-count metrics are
    # deterministic and hard-gated, so the artifact must not vary
    return {"traffic": traffic.run()}, 0


@register_bench(
    "resilience",
    "Resilience -- fault injection x recovery mode on the sweep service",
    ("resilience",),
)
def _run_resilience(args):
    from benchmarks import resilience

    # fixed size under --fast and full: every metric is cycle- or
    # round-counted on a seeded deterministic run and hard-gated
    return {"resilience": resilience.run()}, 0


@register_bench(
    "fault_domains",
    "Fault domains -- chaos sweep x routing policy on the fleet pool",
    ("fault_domains",),
)
def _run_fault_domains(args):
    from benchmarks import fault_domains

    # fixed size under --fast and full: every metric is cycle- or
    # round-counted on a seeded deterministic run and hard-gated
    return {"fault_domains": fault_domains.run()}, 0


@register_bench(
    "preemption",
    "Checkpoint/restore -- live migration + preemptive priority scheduling",
    ("preemption",),
)
def _run_preemption(args):
    from benchmarks import preemption

    # fixed size under --fast and full: every metric is cycle- or
    # round-counted on a seeded deterministic run and hard-gated
    return {"preemption": preemption.run()}, 0


@register_bench(
    "jax_barriers",
    "Tier 2 -- chip-level barrier disciplines (8 host devices)",
    ("jax_barriers_ok",),
)
def _run_jax_barriers(args):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.jax_barriers"],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    print(r.stdout)
    if r.returncode != 0:
        print("[jax_barriers] failed:", r.stderr[-2000:])
    return {"jax_barriers_ok": r.returncode == 0}, (1 if r.returncode != 0 else 0)


@register_bench(
    "roofline",
    "Tier 2 -- roofline from the multi-pod dry-run artifacts",
    (),
)
def _run_roofline(args):
    from benchmarks import roofline

    roofline.run()
    return {}, 0


# legacy alias: the ordered section-name tuple some callers/tests enumerate
SECTIONS = tuple(BENCHES)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the slow PCA app")
    ap.add_argument(
        "--json", metavar="PATH",
        help="write Table-1/Fig-5/scaling/engine-perf key numbers as JSON",
    )
    ap.add_argument(
        "--only", metavar="SECTION[,SECTION...]",
        help=f"run only the given sections (of: {', '.join(BENCHES)}); "
        "a filtered --json artifact is partial and fails the full schema gate",
    )
    args = ap.parse_args()

    only = None
    if args.only:
        only = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = only - set(BENCHES)
        if unknown or not only:
            print(
                f"[run] unknown section(s): {', '.join(sorted(unknown)) or '(none given)'}; "
                f"valid sections: {', '.join(BENCHES)}",
                file=sys.stderr,
            )
            return 2

    results: Dict = {}
    rc = 0
    for spec in BENCHES.values():
        if only is not None and spec.name not in only:
            continue
        print("\n" + "#" * 72)
        print(f"# {spec.title}")
        print("#" * 72)
        frag, section_rc = spec.run(args)
        results.update(frag)
        rc = rc or section_rc

    if args.json:
        with open(args.json, "w") as f:
            json.dump(_jsonable(results), f, indent=2)
        print(f"\nwrote {args.json}")

    if rc:
        print("\nbenchmarks FAILED (see section output above)", file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
