"""Benchmark harness: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json PATH]
                                            [--only SECTION[,SECTION...]]

Order: Tier-1 paper reproduction (Table 1, Fig. 5, Table 2), the pipelined
producer-consumer chain and multi-producer work-queue microbenchmarks (SCU
event FIFO), the scaling sweeps (16/32/64/128/256-core clusters; --fast
samples 16/64/128/256), the engine-throughput benchmark (quiescent,
contended and fleet-dispatch sweeps), the sweep-service traffic
benchmark (continuous batching vs drain baseline on the slot-recycling
fleet) and the resilience sweep (deterministic fault injection x recovery
mode: retry, degradation, watchdog release), then the Tier-2 roofline
read-out
from the dry-run artifacts.  The Table-1/Fig-5/chain/work-queue sweeps and
their scaling variants dispatch through the batched fleet engine
(``repro.core.scu.engine.simulate_fleet``); per-config numbers are
bit-exact against sequential runs.  The chip-level barrier timing
benchmark needs its own process with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and is invoked as a
subprocess (device count is locked at jax init); its failure propagates to
this process's exit code so CI actually gates on it.

``--only`` restricts the run to a comma-separated subset of sections (see
``SECTIONS``; unknown names exit nonzero) for CI and local iteration.
Note a filtered ``--json`` artifact is partial and will not satisfy the
full schema gate in ``scripts/bench_compare.py``.

``--json`` writes the machine-readable key numbers (Table-1/Fig-5 rows,
scaling rows, engine throughput per mode) -- the seed of the performance
trajectory tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys


def _jsonable(obj):
    """Recursively convert benchmark results to strict-JSON-safe values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, (int, str, bool)) or obj is None:
        return obj
    return str(obj)


def _table1_json(rows):
    return [
        {
            "primitive": prim,
            "policy": policy,
            "cycles": meas_c,
            "paper_cycles": list(pc) if pc else None,
            "energy_nj": meas_e,
            "paper_energy_nj": list(pe) if pe else None,
        }
        for prim, policy, meas_c, pc, meas_e, pe in rows
    ]


def _table1_scaling_json(rows):
    return [
        {
            "primitive": prim,
            "policy": policy,
            "core_counts": counts,
            "cycles": meas_c,
            "energy_nj": meas_e,
        }
        for prim, policy, counts, meas_c, meas_e in rows
    ]


def _fig5_json(result):
    return {
        variant: {
            "min_sfr_cycles_10pct": r["min_sfr_cycles_10pct"],
            "min_sfr_energy_10pct": r["min_sfr_energy_10pct"],
            "paper_min_sfr_energy": r["paper_min_sfr_energy"],
        }
        for variant, r in result.items()
    }


# --only section names, in run order
SECTIONS = (
    "table1",
    "fig5",
    "table2",
    "chain",
    "work_queue",
    "scaling",
    "engine_perf",
    "traffic",
    "resilience",
    "jax_barriers",
    "roofline",
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the slow PCA app")
    ap.add_argument(
        "--json", metavar="PATH",
        help="write Table-1/Fig-5/scaling/engine-perf key numbers as JSON",
    )
    ap.add_argument(
        "--only", metavar="SECTION[,SECTION...]",
        help=f"run only the given sections (of: {', '.join(SECTIONS)}); "
        "a filtered --json artifact is partial and fails the full schema gate",
    )
    args = ap.parse_args()

    only = None
    if args.only:
        only = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = only - set(SECTIONS)
        if unknown or not only:
            print(
                f"[run] unknown section(s): {', '.join(sorted(unknown)) or '(none given)'}; "
                f"valid sections: {', '.join(SECTIONS)}",
                file=sys.stderr,
            )
            return 2

    def want(section: str) -> bool:
        return only is None or section in only

    from benchmarks import (
        chain_pipeline,
        engine_perf,
        fig5_overhead,
        resilience,
        roofline,
        table1_primitives,
        table2_apps,
        traffic,
        work_queue,
    )

    results = {}
    rc = 0

    if want("table1") or want("fig5") or want("table2"):
        print("#" * 72)
        print("# Tier 1 -- paper-faithful reproduction (cycle-accurate simulator)")
        print("#" * 72)
        if want("table1"):
            results["table1"] = _table1_json(table1_primitives.run())
        if want("fig5"):
            results["fig5"] = _fig5_json(fig5_overhead.run(dense=not args.fast))
        if want("table2"):
            results["table2"] = table2_apps.run(include_slow=not args.fast)

    if want("chain"):
        print("\n" + "#" * 72)
        print("# Tier 1 -- pipelined producer-consumer chains (SCU event FIFO)")
        print("#" * 72)
        results["chain"] = chain_pipeline.run()

    if want("work_queue"):
        print("\n" + "#" * 72)
        print("# Tier 1 -- multi-producer work queues (mutex vs SCU event FIFO)")
        print("#" * 72)
        results["work_queue"] = work_queue.run()

    if want("scaling"):
        print("\n" + "#" * 72)
        print("# Tier 1 -- scaling sweeps (vectorized engine: 16..256 cores)")
        print("#" * 72)
        # --fast (the CI smoke) samples the decades; the full run is dense.
        # The 128/256-core rows are affordable because the contended path
        # runs on the vectorized structure-of-arrays engine core.
        scale_counts = (
            (16, 64, 128, 256) if args.fast else (16, 32, 64, 128, 256)
        )
        results["table1_scaling"] = _table1_scaling_json(
            table1_primitives.run_scaling(core_counts=scale_counts)
        )
        fig5_scaling = fig5_overhead.run_scaling(core_counts=scale_counts)
        results["fig5_scaling"] = {
            n: _fig5_json(r) for n, r in fig5_scaling.items()
        }
        results["chain_scaling"] = chain_pipeline.run_scaling(
            core_counts=scale_counts
        )
        results["work_queue_scaling"] = work_queue.run_scaling(
            core_counts=scale_counts
        )

    if want("engine_perf"):
        print("\n" + "#" * 72)
        print("# Engine throughput -- lockstep vs fast-forward vs fleet")
        print("#" * 72)
        # reduced sweep under --fast: the lockstep side is the slow half, and
        # the dedicated CI perf-smoke job already runs the full benchmark
        perf = (
            engine_perf.run(sfrs=(1000, 2500), iters=4)
            if args.fast
            else engine_perf.run()
        )
        contended = engine_perf.run_contended(
            core_counts=(8, 64) if args.fast else engine_perf.CONTENDED_CORES
        )
        fleet = engine_perf.run_fleet()
        results["engine_perf"] = {
            "cycles_per_sec": perf["cycles_per_sec"],
            "speedup": perf["speedup"],
            "n_cores": perf["n_cores"],
            "sfrs": perf["sfrs"],
            "contended": {
                "cycles_per_sec": contended["cycles_per_sec"],
                "speedup": contended["speedup"],
                "core_counts": contended["core_counts"],
                "sfrs": contended["sfrs"],
            },
            "fleet": {
                "configs": fleet["configs"],
                "configs_8core": fleet["configs_8core"],
                "wall_s": fleet["wall_s"],
                "speedup": fleet["speedup"],
                "speedup_8core": fleet["speedup_8core"],
            },
        }

    if want("traffic"):
        print("\n" + "#" * 72)
        print("# Sweep-service traffic -- continuous batching vs drain baseline")
        print("#" * 72)
        # one fixed size under --fast and full: the round-count metrics are
        # deterministic and hard-gated, so the artifact must not vary
        results["traffic"] = traffic.run()

    if want("resilience"):
        print("\n" + "#" * 72)
        print("# Resilience -- fault injection x recovery mode on the sweep service")
        print("#" * 72)
        # fixed size under --fast and full: every metric is cycle- or
        # round-counted on a seeded deterministic run and hard-gated
        results["resilience"] = resilience.run()

    if want("jax_barriers"):
        print("\n" + "#" * 72)
        print("# Tier 2 -- chip-level barrier disciplines (8 host devices)")
        print("#" * 72)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = "src"
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.jax_barriers"],
            env=env,
            capture_output=True,
            text=True,
            timeout=1200,
        )
        print(r.stdout)
        results["jax_barriers_ok"] = r.returncode == 0
        if r.returncode != 0:
            print("[jax_barriers] failed:", r.stderr[-2000:])
            rc = 1

    if want("roofline"):
        print("\n" + "#" * 72)
        print("# Tier 2 -- roofline from the multi-pod dry-run artifacts")
        print("#" * 72)
        roofline.run()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(_jsonable(results), f, indent=2)
        print(f"\nwrote {args.json}")

    if rc:
        print("\nbenchmarks FAILED (jax_barriers subprocess)", file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
