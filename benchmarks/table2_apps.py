"""Paper Table 2 / Fig. 6: the nine DSP applications under every policy.

Runs the application synchronization skeletons on the Tier-1 simulator --
under every registered ``repro.sync`` policy -- and reports total cycles,
energy, power, sync-cycle shares, and the normalized improvements of the
SCU discipline over the SW baseline (Fig. 6).  ``n_cores`` defaults to the
paper's 8-core cluster but any count works (the event-driven engine makes
16/32/64-core app sweeps affordable -- the apps are SFR-dominated, exactly
the quiescent-span shape the fast path skips).
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core.scu.apps import APPS, run_app
from repro.sync import available_policies

PAPER = {
    # app: (SCU cycles, SW cycles, SCU energy uJ, SW energy uJ)
    "dwt": (11300, 12900, 0.7, 0.8),
    "dijkstra": (33700, 64900, 2.0, 4.0),
    "aes": (41200, 41600, 2.8, 2.9),
    "livermore6": (24500, 32800, 1.1, 2.1),
    "livermore2": (9200, 11300, 0.6, 0.8),
    "fft": (6100, 6400, 0.5, 0.5),
    "fann": (92400, 103800, 6.9, 7.9),
    "mfcc": (530000, 630000, 36.1, 43.5),
    "pca": (2480000, 2730000, 75.0, 148.3),
}


def run(
    include_slow: bool = True, verbose: bool = True, n_cores: int = 8
) -> List[Dict]:
    policies = available_policies()
    rows = []
    perf_gains, energy_gains = [], []
    sim_cycles, wall_t0 = 0, time.perf_counter()
    for name, app in APPS.items():
        if not include_slow and app.barriers > 1000:
            continue
        res = {v: run_app(app, v, n_cores=n_cores) for v in policies}
        sim_cycles += sum(r.cycles for r in res.values())
        scu, sw = res["scu"], res["sw"]
        pg = sw.cycles / scu.cycles - 1
        eg = sw.energy_uj / scu.energy_uj - 1
        perf_gains.append(pg)
        energy_gains.append(eg)
        rows.append(
            dict(
                app=name,
                cycles={v: r.cycles for v, r in res.items()},
                energy_uj={v: round(r.energy_uj, 2) for v, r in res.items()},
                power_mw={v: round(r.power_mw, 1) for v, r in res.items()},
                sync_total_pct={
                    v: round(100 * r.sync_total / max(r.cycles, 1), 1)
                    for v, r in res.items()
                },
                sync_active_pct={
                    v: round(100 * r.sync_active / max(r.cycles, 1), 1)
                    for v, r in res.items()
                },
                perf_gain_pct=round(100 * pg, 1),
                energy_gain_pct=round(100 * eg, 1),
                paper=PAPER.get(name),
            )
        )
    if verbose:
        print(
            "\n== Table 2 / Fig. 6: DSP applications "
            f"({' vs '.join(p.upper() for p in policies)}) =="
        )
        cyc_cols = "".join(f" {'cyc ' + p.upper():>9s}" for p in policies)
        print(
            f"{'app':11s}{cyc_cols} {'perf+':>7s} "
            f"{'E SCU':>7s} {'E SW':>7s} {'energy+':>8s}  (paper cyc/E SCU,SW)"
        )
        for r in rows:
            p = r["paper"]
            ps = f"({p[0]}/{p[1]}, {p[2]}/{p[3]})" if p else ""
            cyc = "".join(f" {r['cycles'][v]:>9d}" for v in policies)
            print(
                f"{r['app']:11s}{cyc} "
                f"{r['perf_gain_pct']:6.1f}% {r['energy_uj']['scu']:7.2f} "
                f"{r['energy_uj']['sw']:7.2f} {r['energy_gain_pct']:7.1f}%  {ps}"
            )
        if perf_gains:
            print(
                f"\nAVG perf gain +{100*sum(perf_gains)/len(perf_gains):.0f}% "
                f"(paper avg 23%, max 92%) | AVG energy gain "
                f"+{100*sum(energy_gains)/len(energy_gains):.0f}% (paper avg 39%, max 98%)"
            )
        wall = time.perf_counter() - wall_t0
        print(
            f"[engine] {sim_cycles:,} simulated cycles in {wall:.1f}s "
            f"({sim_cycles / max(wall, 1e-9):,.0f} cyc/s, event-driven mode)"
        )
    return rows


if __name__ == "__main__":
    run()
