"""§Roofline: three-term analysis per (arch x shape x mesh) from the dry-run.

Terms (per-device, TPU v5e constants):

    compute    = dot_flops / 197 TFLOP/s(bf16)
    memory     = bytes_accessed / 819 GB/s
    collective = wire_bytes / 50 GB/s per-chip ICI

All inputs come from the trip-count-aware HLO analysis recorded by
``repro.launch.dryrun`` (per-device, post-SPMD).  MODEL_FLOPS uses
6*N_active*D for training (3x forward for fwd+bwd) and 2*N_active*D for
prefill/decode; the HLO/MODEL ratio exposes remat and padding waste.
The "roofline fraction" is compute / max(terms): 1.0 = compute-bound at
peak; the §Perf loop drives the dominant term down.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per chip ICI

LEVERS = {
    "compute": "raise arithmetic efficiency: cut remat recompute (HLO/MODEL "
    "ratio), skip masked attention blocks, fuse via Pallas kernels",
    "memory": "cut HBM round-trips: Pallas flash/SSD kernels keep score and "
    "state tiles in VMEM; bf16 intermediates; larger fusion regions",
    "collective": "re-shard: bigger per-shard work, hierarchical/overlapped "
    "collectives (scu schedule), gradient compression, SP instead of TP "
    "resharding",
}


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    h = rec["hlo_analysis"]
    chips = rec["chips"]
    m = rec["model"]
    compute = h["dot_flops_per_device"] / PEAK_FLOPS
    memory = h["bytes_accessed_per_device"] / HBM_BW
    collective = h["wire_bytes_per_device"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    tokens = m["global_batch"] * (m["seq_len"] if m["kind"] != "decode" else 1)
    n_active = m["n_active_params"]
    model_flops = (6 if m["kind"] == "train" else 2) * n_active * tokens
    hlo_global = h["dot_flops_per_device"] * chips
    frac = compute / max(max(terms.values()), 1e-30)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "tag": rec.get("sync_strategy", "scu"),
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "roofline_fraction": frac,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / max(hlo_global, 1e-30),
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "lever": LEVERS[dominant],
    }


def load_all(art_dir: str = "artifacts/dryrun", mesh: str = "single") -> List[Dict]:
    rows = []
    d = Path(art_dir) / mesh
    if not d.exists():
        return rows
    for f in sorted(d.glob("*.json")):
        if f.stem.count("__") > 1:
            continue  # §Perf variant artifacts live alongside the baselines
        rec = json.loads(f.read_text())
        r = analyze_record(rec)
        if r is not None:
            r["file"] = f.name
            rows.append(r)
        elif rec.get("applicable") is False:
            rows.append(
                {"arch": rec["arch"], "shape": rec["shape"], "mesh": mesh,
                 "skip": rec.get("skip_reason", "")}
            )
    return rows


def run(art_dir: str = "artifacts/dryrun", verbose: bool = True) -> Dict:
    out = {}
    for mesh in ("single", "multi"):
        rows = load_all(art_dir, mesh)
        out[mesh] = rows
        if not verbose or not rows:
            continue
        print(f"\n== Roofline ({mesh} mesh) ==")
        print(
            f"{'arch':22s} {'shape':12s} {'comp ms':>9s} {'mem ms':>9s} "
            f"{'coll ms':>9s} {'dom':>5s} {'RLfrac':>7s} {'useful':>7s}"
        )
        for r in rows:
            if "skip" in r:
                print(f"{r['arch']:22s} {r['shape']:12s} SKIP ({r['skip'][:48]}...)")
                continue
            print(
                f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']*1e3:9.1f} "
                f"{r['memory_s']*1e3:9.1f} {r['collective_s']*1e3:9.1f} "
                f"{r['dominant'][:4]:>5s} {r['roofline_fraction']:7.3f} "
                f"{r['useful_ratio']:7.2f}"
            )
    return out


if __name__ == "__main__":
    run()
