"""Multi-producer work-queue microbenchmark (producers x consumers x policy).

P producer cores feed C consumer cores through one shared work queue; every
registered ``repro.sync`` policy supplies its own queue discipline (see
``repro.core.scu.programs.work_queue_programs``):

  * software policies (``sw``/``tas``/``tree``/``tree_ew``/``scu``) run the
    classic mutex-protected shared queue -- producers enqueue under the
    lock, consumers lock/check/retry until their quota arrives; what differs
    per policy is the mutex discipline (spin, notifier idle-wait, hardware
    mutex) and therefore the contention and idle-energy profile,
  * the ``fifo`` policy runs the queue natively on the SCU event FIFO:
    producers block on ``push_wait`` (hardware backpressure, Sec. 4.3),
    consumers clock-gate on ``pop`` -- nobody spins and nobody serializes
    through a lock.

Two read-outs: the producers-x-consumers split sweep on one cluster size
(who wins when the queue is producer- vs consumer-bound), and the scaling
sweep (half producers / half consumers on 16..256-core clusters).  Both
dispatch through the fleet engine -- one batched ``simulate_fleet`` call
per sweep/core-count, bit-exact per config against sequential runs.

    PYTHONPATH=src python -m benchmarks.work_queue
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scu.energy import DEFAULT_ENERGY, Activity
from repro.core.scu.programs import make_fleet, prep_work_queue_bench
from repro.sync import available_policies

# (producers, consumers) splits on the default 8-core cluster
SPLITS: Tuple[Tuple[int, int], ...] = ((1, 7), (2, 6), (4, 4), (6, 2))


def _energy_nj_per_item(r) -> float:
    return DEFAULT_ENERGY.energy_nj(Activity.per_iter(r.stats, r.iters))


def run(
    n_cores: int = 8,
    items: int = 64,
    t_produce: int = 30,
    t_consume: int = 30,
    splits: Optional[Sequence[Tuple[int, int]]] = None,
    verbose: bool = True,
) -> Dict:
    """The producers-x-consumers split sweep over every policy."""
    splits = list(splits) if splits is not None else list(SPLITS)
    policies = available_policies()
    # the whole (policy x split) grid as one batched fleet call
    grid = [(policy, s) for policy in policies for s in splits]
    for _, (n_prod, n_cons) in grid:
        assert n_prod + n_cons == n_cores, (n_prod, n_cons, n_cores)
    fleet_results = make_fleet([
        prep_work_queue_bench(
            policy, n_prod, n_cons, items=items,
            t_produce=t_produce, t_consume=t_consume,
        )
        for policy, (n_prod, n_cons) in grid
    ])
    rows: List[Dict] = []
    for (policy, (n_prod, n_cons)), r in zip(grid, fleet_results):
        rows.append({
            "policy": policy,
            "producers": n_prod,
            "consumers": n_cons,
            "items": items,
            "cycles_per_item": r.cycles_per_iter,
            "overhead_cycles": r.prim_cycles,
            "energy_nj_per_item": _energy_nj_per_item(r),
            "gated_per_item": r.gated_core_cycles_per_iter,
        })

    results = {
        "n_cores": n_cores,
        "items": items,
        "t_produce": t_produce,
        "t_consume": t_consume,
        "rows": rows,
    }

    if verbose:
        print(f"\n== Work queue: {items} items, {n_cores} cores ==")
        print(f"{'policy':8s}" + "".join(f"  {p}p/{c}c".rjust(10) for p, c in splits)
              + "   (cycles/item)")
        for policy in policies:
            vals = [r for r in rows if r["policy"] == policy]
            print(f"{policy:8s}" + "".join(
                f"  {v['cycles_per_item']:8.1f}" for v in vals))
        balanced = next((s for s in splits if s[0] == s[1]), splits[0])
        best_sw = min(
            (r["cycles_per_item"] for r in rows
             if r["policy"] != "fifo"
             and (r["producers"], r["consumers"]) == balanced),
            default=None,
        )
        fifo_c = next(
            (r["cycles_per_item"] for r in rows
             if r["policy"] == "fifo"
             and (r["producers"], r["consumers"]) == balanced),
            None,
        )
        if best_sw is not None and fifo_c:
            print(
                f"\n{balanced[0]}p/{balanced[1]}c split: fifo {fifo_c:.1f} "
                f"cyc/item vs best lock-based {best_sw:.1f} "
                f"({best_sw / fifo_c - 1:+.1%})"
            )
    return results


# Policies measured on the very large (128/256-core) queues: the herd on a
# single lock makes the idle-wait disciplines O(n) wakeups per item -- we
# keep one spin baseline, the hardware mutex and the native FIFO queue and
# drop the rest (same rationale as chain_pipeline.SCALING_LARGE_POLICIES).
SCALING_LARGE_POLICIES = ("scu", "sw", "fifo")
SCALING_LARGE_FROM = 128


def run_scaling(
    core_counts: Sequence[int] = (16, 32, 64, 128, 256),
    items_per_core: int = 2,
    t_produce: int = 30,
    t_consume: int = 30,
    verbose: bool = True,
) -> List[Dict]:
    """Half-producers/half-consumers splits on MemPool-scale clusters.

    Lock-based queues collapse as every core contends on one mutex; the
    event-FIFO queue keeps moving one item per cycle per port regardless of
    the core count."""
    rows: List[Dict] = []
    for n in core_counts:
        items = items_per_core * n
        policies = (
            [p for p in available_policies() if p in SCALING_LARGE_POLICIES]
            if n >= SCALING_LARGE_FROM
            else available_policies()
        )
        # one fleet per core count (see table1_primitives.run_scaling)
        results = make_fleet([
            prep_work_queue_bench(
                policy, n // 2, n - n // 2, items=items,
                t_produce=t_produce, t_consume=t_consume,
            )
            for policy in policies
        ])
        for policy, r in zip(policies, results):
            rows.append({
                "policy": policy,
                "n_cores": n,
                "items": items,
                "cycles_per_item": r.cycles_per_iter,
            })
    if verbose:
        counts = "/".join(str(n) for n in core_counts)
        print(f"\n== Work queue (scaling): cycles/item @ {counts} cores ==")
        print("policy  " + "".join(f"{n:>10d}" for n in core_counts))
        for policy in available_policies():
            vals = []
            for n in core_counts:
                r = next((x for x in rows
                          if x["policy"] == policy and x["n_cores"] == n), None)
                vals.append(
                    f"{r['cycles_per_item']:10.1f}" if r else f"{'-':>10s}"
                )
            print(f"{policy:8s}" + "".join(vals))
    return rows


if __name__ == "__main__":
    run()
    run_scaling()
