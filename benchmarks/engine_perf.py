"""Engine throughput: simulated cycles per second, lockstep vs fastforward.

Times the Fig. 5 barrier sweep (SFR >= 1000, every registered ``repro.sync``
policy) under both engine modes of :class:`repro.core.scu.engine.Cluster`
and reports per-config and aggregate simulated-cycles-per-second.  The two
modes are asserted cycle-exact on every config while we are at it -- this
benchmark doubles as a coarse parity check (the fine-grained one lives in
``tests/test_scu_simulator.py``).

    PYTHONPATH=src python -m benchmarks.engine_perf [--json PATH]

The aggregate speedup is the headline number for the event-driven engine:
the quiescent spans it skips (SFR compute runs, clock-gated idle waits)
dominate realistic workloads, so the fast path is what makes 64-core
clusters and dense SFR grids sweepable at all.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence

from repro.core.scu.programs import run_barrier_bench
from repro.sync import available_policies

MODES = ("lockstep", "fastforward")

# the Fig. 5 sweep restricted to SFR >= 1000 (where skipping pays off most;
# smaller SFRs are spin-dominated and bound by the per-cycle reference path)
SFRS = (1000, 1600, 2500, 4000)


def run(
    n_cores: int = 8,
    sfrs: Sequence[int] = SFRS,
    iters: int = 8,
    policies: Optional[Sequence[str]] = None,
    verbose: bool = True,
) -> Dict:
    policies = tuple(policies) if policies else available_policies()
    rows = []
    totals = {m: {"cycles": 0, "wall_s": 0.0} for m in MODES}
    for policy in policies:
        for sfr in sfrs:
            per_mode = {}
            for mode in MODES:
                t0 = time.perf_counter()
                r = run_barrier_bench(
                    policy, n_cores, sfr=sfr, iters=iters, mode=mode
                )
                wall = time.perf_counter() - t0
                per_mode[mode] = {
                    "cycles": r.cycles_total,
                    "wall_s": wall,
                    "cycles_per_sec": r.cycles_total / max(wall, 1e-9),
                }
                totals[mode]["cycles"] += r.cycles_total
                totals[mode]["wall_s"] += wall
            if per_mode["lockstep"]["cycles"] != per_mode["fastforward"]["cycles"]:
                raise AssertionError(
                    f"engine modes diverged on {policy} @ sfr={sfr}: "
                    f"{per_mode['lockstep']['cycles']} vs "
                    f"{per_mode['fastforward']['cycles']} cycles"
                )
            rows.append({"policy": policy, "sfr": sfr, **{
                m: per_mode[m] for m in MODES
            }})

    throughput = {
        m: totals[m]["cycles"] / max(totals[m]["wall_s"], 1e-9) for m in MODES
    }
    speedup = throughput["fastforward"] / max(throughput["lockstep"], 1e-9)
    result = {
        "n_cores": n_cores,
        "sfrs": list(sfrs),
        "iters": iters,
        "policies": list(policies),
        "rows": rows,
        "cycles_per_sec": throughput,
        "speedup": speedup,
    }

    if verbose:
        print(f"\n== Engine throughput ({n_cores} cores, SFR sweep >= 1000) ==")
        print(f"{'policy':7s} {'sfr':>5s} | {'lockstep c/s':>13s} {'fastfwd c/s':>13s} {'speedup':>8s}")
        for row in rows:
            ls = row["lockstep"]["cycles_per_sec"]
            ff = row["fastforward"]["cycles_per_sec"]
            print(
                f"{row['policy']:7s} {row['sfr']:5d} | {ls:13,.0f} {ff:13,.0f} "
                f"{ff / max(ls, 1e-9):7.1f}x"
            )
        print(
            f"\naggregate: lockstep {throughput['lockstep']:,.0f} cyc/s, "
            f"fastforward {throughput['fastforward']:,.0f} cyc/s "
            f"-> {speedup:.1f}x"
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", help="write results as JSON")
    ap.add_argument("--n-cores", type=int, default=8)
    ap.add_argument("--iters", type=int, default=8)
    args = ap.parse_args()
    result = run(n_cores=args.n_cores, iters=args.iters)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
