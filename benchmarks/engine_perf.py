"""Engine throughput: simulated cycles per second, lockstep vs fastforward.

Three sweeps:

* **Quiescent** (the PR-2 headline): the Fig. 5 barrier sweep at SFR >= 1000
  under both engine modes.  Dominated by compute spans and clock-gated
  waits, i.e. by the tier-1 quiescent-span skipper.  Both modes run on
  every config and are asserted cycle-exact -- this benchmark doubles as a
  coarse parity check (the fine-grained one lives in
  ``tests/test_scu_simulator.py``).
* **Contended** (the PR-4 headline): the Table-1/Fig-5 shapes at SFR < 100,
  where every cycle carries arbitration or spin traffic, across cluster
  sizes up to 256 cores.  This is the regime served by the vectorized
  structure-of-arrays step and the spin-phase batch resolver; lockstep is
  only run (and parity-asserted) on the smallest cluster -- reference-
  stepping a contended 256-core cluster is exactly the cost the vectorized
  engine exists to avoid.
* **Compiled** (the PR-8 headline): the fleet sweep's 8-core spin-heavy
  barrier/mutex shapes executed twice -- as plain generator programs and as
  static micro-op traces (``repro.core.scu.trace``), which drop per-micro-op
  generator resumption and let the period-collapse monitor jump over
  repeated whole-cluster periods.  Per-config stats are asserted
  bit-identical; the ratio is the compiled-dispatch speedup.
* **Fleet** (the PR-5 headline): a fixed 64-config combined
  Table-1 + Fig-5 + chain + work-queue sweep, run once config-at-a-time
  (the sequential dispatch the benchmarks used before the fleet engine)
  and once as one batched ``simulate_fleet`` call.  Per-config results are
  asserted bit-identical; the wall-clock ratio is the fleet speedup, with
  a separate ratio for the 8-core-only subset (the configs that sat below
  the single-cluster vectorization threshold before fleet mode).

    PYTHONPATH=src python -m benchmarks.engine_perf [--json PATH]

The aggregate simulated-cycles-per-second numbers feed the soft throughput
gate in ``scripts/bench_compare.py`` (warn < 1.0x, fail < 0.5x of the
committed baseline).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence

from repro.core.scu.programs import run_barrier_bench
from repro.sync import available_policies

MODES = ("lockstep", "fastforward")

# the Fig. 5 sweep restricted to SFR >= 1000 (where skipping pays off most)
SFRS = (1000, 1600, 2500, 4000)

# the contended regime: SFR < 100, arbitration/spin traffic every cycle
SFRS_CONTENDED = (8, 32, 64)
CONTENDED_CORES = (8, 64, 256)


def run(
    n_cores: int = 8,
    sfrs: Sequence[int] = SFRS,
    iters: int = 8,
    policies: Optional[Sequence[str]] = None,
    verbose: bool = True,
) -> Dict:
    policies = tuple(policies) if policies else available_policies()
    rows = []
    totals = {m: {"cycles": 0, "wall_s": 0.0} for m in MODES}
    for policy in policies:
        for sfr in sfrs:
            per_mode = {}
            for mode in MODES:
                t0 = time.perf_counter()
                r = run_barrier_bench(
                    policy, n_cores, sfr=sfr, iters=iters, mode=mode
                )
                wall = time.perf_counter() - t0
                per_mode[mode] = {
                    "cycles": r.cycles_total,
                    "wall_s": wall,
                    "cycles_per_sec": r.cycles_total / max(wall, 1e-9),
                }
                totals[mode]["cycles"] += r.cycles_total
                totals[mode]["wall_s"] += wall
            if per_mode["lockstep"]["cycles"] != per_mode["fastforward"]["cycles"]:
                raise AssertionError(
                    f"engine modes diverged on {policy} @ sfr={sfr}: "
                    f"{per_mode['lockstep']['cycles']} vs "
                    f"{per_mode['fastforward']['cycles']} cycles"
                )
            rows.append({"policy": policy, "sfr": sfr, **{
                m: per_mode[m] for m in MODES
            }})

    throughput = {
        m: totals[m]["cycles"] / max(totals[m]["wall_s"], 1e-9) for m in MODES
    }
    speedup = throughput["fastforward"] / max(throughput["lockstep"], 1e-9)
    result = {
        "n_cores": n_cores,
        "sfrs": list(sfrs),
        "iters": iters,
        "policies": list(policies),
        "rows": rows,
        "cycles_per_sec": throughput,
        "speedup": speedup,
    }

    if verbose:
        print(f"\n== Engine throughput ({n_cores} cores, SFR sweep >= 1000) ==")
        print(f"{'policy':8s} {'sfr':>5s} | {'lockstep c/s':>13s} {'fastfwd c/s':>13s} {'speedup':>8s}")
        for row in rows:
            ls = row["lockstep"]["cycles_per_sec"]
            ff = row["fastforward"]["cycles_per_sec"]
            print(
                f"{row['policy']:8s} {row['sfr']:5d} | {ls:13,.0f} {ff:13,.0f} "
                f"{ff / max(ls, 1e-9):7.1f}x"
            )
        print(
            f"\naggregate: lockstep {throughput['lockstep']:,.0f} cyc/s, "
            f"fastforward {throughput['fastforward']:,.0f} cyc/s "
            f"-> {speedup:.1f}x"
        )
    return result


def run_contended(
    core_counts: Sequence[int] = CONTENDED_CORES,
    sfrs: Sequence[int] = SFRS_CONTENDED,
    policies: Optional[Sequence[str]] = None,
    verbose: bool = True,
) -> Dict:
    """Fastforward throughput on the contended (SFR < 100) sweeps.

    Parity against lockstep is asserted (and the lockstep side timed, for
    the machine-independent ``speedup`` ratio) on the largest cluster size
    up to 64 cores -- small enough that reference-stepping stays
    affordable, large enough that the vectorized path carries the cycles;
    the 128/256-core sizes are covered by the randomized cross-checks in
    ``tests/test_scu_simulator.py``.
    """
    policies = tuple(policies) if policies else available_policies()
    rows = []
    total_cycles = 0
    total_wall = 0.0
    parity_cycles = 0
    parity_fast_wall = 0.0
    parity_lock_wall = 0.0
    small = [n for n in core_counts if n <= 64]
    parity_n = max(small) if small else min(core_counts)
    for n in core_counts:
        iters = 4 if n <= 64 else 2
        for policy in policies:
            for sfr in sfrs:
                t0 = time.perf_counter()
                r = run_barrier_bench(
                    policy, n, sfr=sfr, iters=iters, mode="fastforward"
                )
                wall = time.perf_counter() - t0
                if n == parity_n:
                    t0 = time.perf_counter()
                    ref = run_barrier_bench(
                        policy, n, sfr=sfr, iters=iters, mode="lockstep"
                    )
                    lock_wall = time.perf_counter() - t0
                    if ref.stats != r.stats:
                        raise AssertionError(
                            f"engine modes diverged on contended {policy} "
                            f"@ n={n}, sfr={sfr}"
                        )
                    parity_cycles += r.cycles_total
                    parity_fast_wall += wall
                    parity_lock_wall += lock_wall
                rows.append({
                    "policy": policy,
                    "n_cores": n,
                    "sfr": sfr,
                    "cycles": r.cycles_total,
                    "wall_s": wall,
                    "cycles_per_sec": r.cycles_total / max(wall, 1e-9),
                })
                total_cycles += r.cycles_total
                total_wall += wall

    result = {
        "core_counts": list(core_counts),
        "sfrs": list(sfrs),
        "policies": list(policies),
        "rows": rows,
        "cycles": total_cycles,
        "wall_s": total_wall,
        "cycles_per_sec": total_cycles / max(total_wall, 1e-9),
        # fastforward-over-lockstep on the parity-checked (smallest) cluster
        # size: a same-run, same-machine ratio -- absolute cyc/s depends on
        # the host, so the CI throughput gate compares this instead
        "speedup": (parity_cycles / max(parity_fast_wall, 1e-9))
        / max(parity_cycles / max(parity_lock_wall, 1e-9), 1e-9),
    }
    if verbose:
        counts = "/".join(str(n) for n in core_counts)
        print(f"\n== Engine throughput (contended: SFR < 100, {counts} cores) ==")
        print(f"{'policy':8s}" + "".join(f"{n:>12d}" for n in core_counts)
              + "   (fastforward cyc/s, aggregated over SFRs)")
        for policy in policies:
            vals = []
            for n in core_counts:
                sel = [r for r in rows if r["policy"] == policy and r["n_cores"] == n]
                cyc = sum(r["cycles"] for r in sel)
                wall = sum(r["wall_s"] for r in sel)
                vals.append(cyc / max(wall, 1e-9))
            print(f"{policy:8s}" + "".join(f"{v:12,.0f}" for v in vals))
        print(
            f"\ncontended aggregate: {result['cycles_per_sec']:,.0f} cyc/s; "
            f"fastforward vs lockstep @ {parity_n} cores: "
            f"{result['speedup']:.1f}x"
        )
    return result


def _fleet_benches():
    """The fixed 64-config combined sweep behind the ``fleet`` row.

    Table-1 shapes (barrier/mutex), Fig-5 SFR points, pipelined chains and
    work queues for every registered policy -- 42 eight-core configs (the
    previously-unvectorizable regime) plus 16- and 32-core scaling shapes.
    Returns fresh benches every call: generators and shared policy state
    are single-use, and the sequential/fleet passes must replay identical
    programs.
    """
    from repro.core.scu.programs import (
        prep_barrier_bench,
        prep_chain_bench,
        prep_mutex_bench,
        prep_work_queue_bench,
    )

    benches = []
    for p in available_policies():
        benches += [
            # Table-1 shapes @ 8 cores
            prep_barrier_bench(p, 8, sfr=0, iters=16),
            prep_mutex_bench(p, 8, t_crit=10, iters=16),
            # Fig-5 SFR points @ 8 cores
            prep_barrier_bench(p, 8, sfr=100, iters=16),
            prep_barrier_bench(p, 8, sfr=1000, iters=16),
            # pipelined chain + work queue @ 8 cores
            prep_chain_bench(p, 8, sfr=200, iters=16, depth=8),
            prep_work_queue_bench(p, 4, 4, items=32),
            # scaling shapes (16/32 cores)
            prep_barrier_bench(p, 16, sfr=0, iters=8),
            prep_chain_bench(p, 16, sfr=200, iters=8, depth=8),
            prep_work_queue_bench(p, 8, 8, items=32),
        ]
    benches.append(prep_barrier_bench("scu", 32, sfr=160, iters=8))
    return benches


def run_fleet(verbose: bool = True) -> Dict:
    """Batched-fleet vs sequential dispatch on the fixed 64-config sweep.

    Both passes run the *same* engine code per config (fastforward tiers);
    the only difference is dispatch -- one ``simulate_fleet`` call vs one
    ``Cluster.run()`` per config -- so the wall-clock ratio is a same-run,
    same-machine measure of the batching win (machine-independent, like
    the other engine speedup gates).  Per-config ``ClusterStats`` are
    asserted bit-identical between the two dispatches.
    """
    from repro.core.scu.programs import make_fleet

    # sequential pass, timed per bench so the 8-core subset cost falls out
    benches = _fleet_benches()
    seq_results = []
    seq_wall = []
    for b in benches:
        t0 = time.perf_counter()
        seq_results.append(b.run_sequential())
        seq_wall.append(time.perf_counter() - t0)
    t_seq = sum(seq_wall)

    # batched pass (fresh benches), then bit-exactness
    fresh = _fleet_benches()
    t0 = time.perf_counter()
    fleet_results = make_fleet(fresh)
    t_fleet = time.perf_counter() - t0
    for s, f in zip(seq_results, fleet_results):
        if s.stats != f.stats:
            raise AssertionError(
                f"fleet dispatch diverged from sequential on "
                f"{s.variant}/{s.primitive}@{s.n_cores}"
            )

    # the 8-core-only subset as its own fleet
    is8 = [b.config.cluster.n_cores == 8 for b in benches]
    t_seq8 = sum(w for w, m in zip(seq_wall, is8) if m)
    fresh8 = [b for b in _fleet_benches() if b.config.cluster.n_cores == 8]
    t0 = time.perf_counter()
    fleet8 = make_fleet(fresh8)
    t_fleet8 = time.perf_counter() - t0
    seq8 = [r for r, m in zip(seq_results, is8) if m]
    for s, f in zip(seq8, fleet8):
        if s.stats != f.stats:
            raise AssertionError(
                f"8-core fleet diverged on {s.variant}/{s.primitive}"
            )
    total_cycles = sum(r.cycles_total for r in seq_results)

    result = {
        "configs": len(benches),
        "configs_8core": sum(is8),
        "cycles": total_cycles,
        "wall_s": {
            "sequential": t_seq,
            "fleet": t_fleet,
            "sequential_8core": t_seq8,
            "fleet_8core": t_fleet8,
        },
        # same-run dispatch ratios (the soft-gated keys)
        "speedup": t_seq / max(t_fleet, 1e-9),
        "speedup_8core": t_seq8 / max(t_fleet8, 1e-9),
    }
    if verbose:
        print(f"\n== Fleet dispatch ({len(benches)} configs, combined "
              "Table-1/Fig-5/chain/work-queue sweep) ==")
        print(
            f"sequential {t_seq:6.2f}s  fleet {t_fleet:6.2f}s  "
            f"-> {result['speedup']:.2f}x  (bit-exact per config)"
        )
        print(
            f"8-core subset ({sum(is8)} configs): sequential {t_seq8:6.2f}s  "
            f"fleet {t_fleet8:6.2f}s  -> {result['speedup_8core']:.2f}x"
        )
    return result


# the compiled-trace row: the fleet sweep's 8-core spin-heavy shapes (the
# barrier/mutex configs where every cycle is spin or lock traffic) at enough
# iterations for the period-collapse monitor to amortize its detection
# warmup (the sw/tas whole-cluster state has period 8 iterations -- the
# round-robin pointers rotate with the arrival order -- so ~3 periods are
# simulated before the first jump lands)
COMPILED_POLICIES = ("sw", "tas", "tree", "tree4")
COMPILED_SFRS = (0, 100)
COMPILED_ITERS = 128


def _compiled_benches(compiled: bool):
    from repro.core.scu.programs import prep_barrier_bench, prep_mutex_bench

    benches = []
    for p in COMPILED_POLICIES:
        for sfr in COMPILED_SFRS:
            benches.append(
                prep_barrier_bench(
                    p, 8, sfr=sfr, iters=COMPILED_ITERS, compiled=compiled
                )
            )
        benches.append(
            prep_mutex_bench(
                p, 8, t_crit=10, iters=COMPILED_ITERS, compiled=compiled
            )
        )
    return benches


def run_compiled(verbose: bool = True) -> Dict:
    """Compiled-trace vs generator execution on the spin-heavy 8-core subset.

    Both passes run identical programs through the same fastforward engine;
    the compiled pass lowers them to static micro-op traces
    (:mod:`repro.core.scu.trace`) first, which (a) replaces per-micro-op
    generator resumption with table fetches and (b) arms the whole-cluster
    period-collapse monitor.  Per-config ``ClusterStats`` are asserted
    bit-identical, so the wall-clock ratio is a same-run, same-machine
    dispatch measure like the fleet row (lowering happens at prep time, is
    excluded from the ratio, and is reported as ``lower_s``).
    """
    gen_benches = _compiled_benches(False)
    t0 = time.perf_counter()
    gen_results = [b.run_sequential() for b in gen_benches]
    t_gen = time.perf_counter() - t0

    t0 = time.perf_counter()
    comp_benches = _compiled_benches(True)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp_results = [b.run_sequential() for b in comp_benches]
    t_comp = time.perf_counter() - t0

    for g, c in zip(gen_results, comp_results):
        if g.stats != c.stats:
            raise AssertionError(
                f"compiled trace diverged from generator on "
                f"{g.variant}/{g.primitive}@{g.n_cores}"
            )
    jumps = sum(b.config.cluster.trace_jumps for b in comp_benches)
    jumped = sum(b.config.cluster.trace_jump_cycles for b in comp_benches)
    total_cycles = sum(r.cycles_total for r in gen_results)

    result = {
        "configs": len(gen_benches),
        "iters": COMPILED_ITERS,
        "cycles": total_cycles,
        "wall_s": {"generator": t_gen, "compiled": t_comp},
        "lower_s": t_lower,
        "trace_jumps": jumps,
        "trace_jump_cycles": jumped,
        # same-run dispatch ratio (the soft-gated key)
        "speedup": t_gen / max(t_comp, 1e-9),
        "speedup_incl_lowering": t_gen / max(t_comp + t_lower, 1e-9),
    }
    if verbose:
        print(f"\n== Compiled traces ({len(gen_benches)} spin-heavy 8-core "
              "configs, barrier/mutex) ==")
        print(
            f"generator {t_gen:6.2f}s  compiled {t_comp:6.2f}s "
            f"(+{t_lower:.2f}s lowering)  -> {result['speedup']:.2f}x  "
            f"(bit-exact per config; {jumps} jumps collapsed "
            f"{jumped}/{total_cycles} cycles)"
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", help="write results as JSON")
    ap.add_argument("--n-cores", type=int, default=8)
    ap.add_argument("--iters", type=int, default=8)
    args = ap.parse_args()
    result = run(n_cores=args.n_cores, iters=args.iters)
    result["contended"] = run_contended()
    result["fleet"] = run_fleet()
    result["compiled"] = run_compiled()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
