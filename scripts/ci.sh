#!/usr/bin/env bash
# Offline smoke gate: the tier-1 verify command plus the fast benchmark pass.
#
#   ./scripts/ci.sh          # full tier-1 suite + fast benchmarks
#   ./scripts/ci.sh --fast   # tests + no-jax compiled smoke, skip benchmarks
#   ./scripts/ci.sh --tests  # tests only (skip smoke and benchmark passes)
#
# Everything runs offline: the suite needs no network and no optional
# dependencies (hypothesis falls back to tests/_hypothesis_compat.py).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 verify: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--tests" ]]; then
    echo "== compiled-trace smoke without jax (REPRO_NO_JAX=1, numpy path) =="
    # Exercises the PR-8 compiled dispatcher -- lowering, trace cursors,
    # run_traces_xp -- in a process that never imports jax.
    python scripts/compiled_smoke.py
fi

echo "== fault-injection parity fuzz (non-gating) =="
# Fresh random seeds every run; tests/test_faults.py pins a fixed seed set,
# this keeps rolling new ones.  A divergence prints the replay seed and
# warns without failing the gate (file an issue with the seed).
if ! python scripts/fault_fuzz.py --trials 20; then
    echo "WARN: fault_fuzz found an engine-mode divergence (see seed above);" \
         "non-gating, continuing"
fi
# Domain lane: correlated droop/scu_blackout/bank_blackout plans over whole
# fault domains (stresses blackout-window replay across engine tiers).
if ! python scripts/fault_fuzz.py --trials 10 --domain-only; then
    echo "WARN: fault_fuzz --domain-only found an engine-mode divergence" \
         "(see seed above); non-gating, continuing"
fi
# Snapshot lane: checkpoint/restore parity -- random compiled workloads
# suspended at a random round boundary and resumed in a fresh fleet must
# drain to a bit-identical outcome (tests/test_checkpoint.py pins seeds).
if ! python scripts/fault_fuzz.py --trials 10 --snapshot; then
    echo "WARN: fault_fuzz --snapshot found a checkpoint/restore divergence" \
         "(see seed above); non-gating, continuing"
fi

if [[ "${1:-}" != "--tests" && "${1:-}" != "--fast" ]]; then
    echo "== benchmark smoke: benchmarks/run.py --fast --json BENCH_tier1.json =="
    # --json seeds the perf trajectory (Table-1/Fig-5 key numbers + engine
    # throughput per mode); a jax_barriers subprocess failure exits nonzero.
    # The Table-1/Fig-5/chain/work-queue sweeps (and their scaling variants)
    # dispatch through the batched fleet engine (simulate_fleet), and the
    # engine_perf fleet row asserts batched-vs-sequential bit-exactness --
    # so this smoke gate exercises the fleet path end-to-end on every run.
    python -m benchmarks.run --fast --json BENCH_tier1.json

    echo "== benchmark regression gate: bench_compare vs committed baseline =="
    # The simulator is deterministic, so the cycle-exact key numbers must
    # reproduce; >2% above benchmarks/golden/BENCH_baseline.json fails.
    # Refresh the baseline in the PR that intentionally moves the numbers:
    #   python -m benchmarks.run --fast --json benchmarks/golden/BENCH_baseline.json
    python scripts/bench_compare.py \
        benchmarks/golden/BENCH_baseline.json BENCH_tier1.json
fi

echo "== ci.sh: all green =="
