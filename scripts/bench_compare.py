#!/usr/bin/env python
"""Benchmark-regression gate: compare a ``benchmarks/run.py --json`` artifact
against the committed golden baseline.

    python scripts/bench_compare.py BASELINE CURRENT [--threshold 0.02]

The simulator is cycle-exact and fully deterministic (seeded RNG, no
wall-clock inputs), so the key numbers -- Table-1 primitive cycles, Fig-5
minimum SFR at 10% overhead, Table-2 app cycles, pipelined-chain and
work-queue cost, their 16..256-core scaling rows, the sweep-service
traffic latency/idle/energy-tail metrics (counted in deterministic
scheduler rounds), the resilience sweep's failure/recovery metrics
(seeded fault injection, cycle- and round-counted), the fault-domain
chaos sweep's routing metrics (reroutes, quarantines, wasted cycles on
the multi-fleet pool), and the checkpoint/restore benchmark's migration
and preemption metrics (wasted cycles, high-priority latency) -- must
reproduce
bit-for-bit on any machine (the sweeps dispatch through the batched fleet
engine, which is bit-exact per config against sequential runs).  A current value more than ``threshold`` above the baseline fails
the gate (exit 1); wall-clock metrics (engine throughput, jax_barriers
timings) are deliberately *not* compared.  Improvements are reported but
never fail; refresh the baseline in the same PR that moves the numbers:

    PYTHONPATH=src python -m benchmarks.run --fast --json \
        benchmarks/golden/BENCH_baseline.json

Also exposes :func:`validate_schema` -- the machine-readable contract of the
``--json`` artifact, shared with ``tests/test_bench_schema.py`` so the
schema cannot drift silently out from under this gate.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Optional, Tuple

# Every metric compared here is lower-is-better and cycle-derived (hence
# deterministic).  ``None`` encodes infinity (json.dump of float("inf") is
# not strict JSON; benchmarks/run.py maps non-finite values to null).
Metrics = Dict[str, Optional[float]]

FIG5_KEYS = ("min_sfr_cycles_10pct", "min_sfr_energy_10pct")


def _num(v) -> Optional[float]:
    return None if v is None else float(v)


def extract_metrics(results: Dict) -> Metrics:
    """Flatten the deterministic key numbers of a benchmark artifact."""
    m: Metrics = {}
    for row in results.get("table1", []):
        for n, v in zip((2, 4, 8), row["cycles"]):
            m[f"table1/{row['primitive']}/{row['policy']}/cycles@{n}"] = _num(v)
    for row in results.get("table1_scaling", []):
        for n, v in zip(row["core_counts"], row["cycles"]):
            key = f"table1_scaling/{row['primitive']}/{row['policy']}/cycles@{n}"
            m[key] = _num(v)
    for policy, r in results.get("fig5", {}).items():
        for k in FIG5_KEYS:
            m[f"fig5/{policy}/{k}"] = _num(r[k])
    for n, per_policy in results.get("fig5_scaling", {}).items():
        for policy, r in per_policy.items():
            for k in FIG5_KEYS:
                m[f"fig5_scaling@{n}/{policy}/{k}"] = _num(r[k])
    for row in results.get("table2", []):
        for policy, cycles in row["cycles"].items():
            m[f"table2/{row['app']}/{policy}/cycles"] = _num(cycles)
    chain = results.get("chain", {})
    for row in chain.get("rows", []):
        key = f"chain/{row['policy']}/sfr{row['sfr']}/cycles_per_item"
        m[key] = _num(row["cycles_per_item"])
    for row in chain.get("depth_sweep", []):
        m[f"chain/fifo/depth{row['depth']}/cycles_per_item"] = _num(
            row["cycles_per_item"]
        )
    for row in chain.get("apps", []):
        for policy, cycles in row["cycles"].items():
            m[f"chain_app/{row['app']}/{policy}/cycles"] = _num(cycles)
    for row in results.get("chain_scaling", []):
        key = f"chain_scaling/{row['policy']}@{row['n_cores']}/cycles_per_item"
        m[key] = _num(row["cycles_per_item"])
    for row in results.get("work_queue", {}).get("rows", []):
        key = (
            f"work_queue/{row['policy']}/p{row['producers']}c{row['consumers']}"
            "/cycles_per_item"
        )
        m[key] = _num(row["cycles_per_item"])
    for row in results.get("work_queue_scaling", []):
        key = f"work_queue_scaling/{row['policy']}@{row['n_cores']}/cycles_per_item"
        m[key] = _num(row["cycles_per_item"])
    # sweep-service traffic: latency/idle metrics are counted in scheduler
    # rounds (deterministic), so they gate as hard as cycle counts
    traffic = results.get("traffic", {})
    for name, sc in traffic.get("scenarios", {}).items():
        for mode in ("continuous", "drain"):
            r = sc.get(mode, {})
            for k in ("p50_latency_rounds", "p99_latency_rounds",
                      "idle_lane_fraction"):
                m[f"traffic/{name}/{mode}/{k}"] = _num(r.get(k))
    for policy, tail in traffic.get("energy_tail", {}).items():
        for k in ("p99_spin_pj", "p99_idle_pj"):
            m[f"traffic/energy/{policy}/{k}"] = _num(tail.get(k))
    # resilience sweep: every gated key is lower-is-better (failure_rate,
    # not completion_rate -- the gate only flags increases) and counted in
    # cycles or scheduler rounds of a seeded deterministic run
    for rate, modes in results.get("resilience", {}).get("cells", {}).items():
        for mode, c in modes.items():
            for k in ("failure_rate", "total_attempts", "wasted_cycles",
                      "rounds", "mean_latency_rounds", "degraded_jobs",
                      "watchdog_releases"):
                m[f"resilience/{rate}/{mode}/{k}"] = _num(c.get(k))
    # fault-domain chaos sweep: same story -- failure_rate, wasted cycles,
    # reroutes and quarantines are lower-is-better counts of a seeded
    # deterministic run (zero baselines gate any increase absolutely)
    for rate, policies in results.get("fault_domains", {}).get("cells", {}).items():
        for policy, c in policies.items():
            for k in ("failure_rate", "total_attempts", "wasted_cycles",
                      "reroutes", "quarantines", "rounds",
                      "mean_latency_rounds", "watchdog_trips"):
                m[f"fault_domains/{rate}/{policy}/{k}"] = _num(c.get(k))
    # checkpoint/restore benchmark: wasted cycles, rounds and latencies of
    # seeded deterministic runs; zero baselines (preempt wasted_cycles,
    # failure_rate) gate any increase absolutely
    pre = results.get("preemption", {})
    for mode, c in pre.get("migration", {}).items():
        for k in ("failure_rate", "total_attempts", "wasted_cycles",
                  "reroutes", "rounds", "mean_latency_rounds"):
            m[f"preemption/migration/{mode}/{k}"] = _num(c.get(k))
    for mode, c in pre.get("schedule", {}).items():
        for k in ("failure_rate", "wasted_cycles", "rounds",
                  "mean_latency_rounds", "hi_latency_rounds",
                  "hi_queue_rounds"):
            m[f"preemption/schedule/{mode}/{k}"] = _num(c.get(k))
    return m


# Engine-throughput keys (higher is better), gated *softly*.  Both are
# fastforward-over-lockstep speedups measured in the same run on the same
# hardware -- absolute cyc/s depends on the machine that generated the
# committed baseline, which a slower-but-healthy CI runner would fail; a
# same-run ratio only collapses when the fast path itself regresses.
THROUGHPUT_KEYS = (
    ("engine_perf/speedup",
     lambda r: r.get("engine_perf", {}).get("speedup")),
    ("engine_perf/contended/speedup",
     lambda r: r.get("engine_perf", {}).get("contended", {}).get("speedup")),
    # fleet-dispatch ratios: batched simulate_fleet vs config-at-a-time on
    # the fixed 64-config combined sweep, same run / same machine
    ("engine_perf/fleet/speedup",
     lambda r: r.get("engine_perf", {}).get("fleet", {}).get("speedup")),
    ("engine_perf/fleet/speedup_8core",
     lambda r: r.get("engine_perf", {}).get("fleet", {}).get("speedup_8core")),
    # compiled-trace dispatch ratio: trace-lowered programs (static micro-op
    # tables + whole-cluster period collapse) vs the same programs as
    # generators, on the spin-heavy 8-core subset, same run / same machine
    ("engine_perf/compiled/speedup",
     lambda r: r.get("engine_perf", {}).get("compiled", {}).get("speedup")),
    # sweep-service dispatch ratio: drain-baseline wall over continuous
    # wall on the identical job stream, same run / same machine
    ("traffic/speedup",
     lambda r: r.get("traffic", {}).get("speedup")),
)


def compare_throughput(
    baseline: Dict, current: Dict, fail_ratio: float = 0.5, warn_ratio: float = 1.0
) -> Tuple[List[str], List[str]]:
    """Soft gate on the engine's fastforward-vs-lockstep speedups.

    Returns (failures, warnings): a current speedup below ``fail_ratio`` x
    baseline fails, below ``warn_ratio`` x baseline only warns.
    """
    failures: List[str] = []
    warnings: List[str] = []
    for key, get in THROUGHPUT_KEYS:
        base, cur = get(baseline), get(current)
        if base is None:
            continue  # metric not in the committed baseline yet
        if cur is None:
            failures.append(f"{key}: disappeared from the artifact")
            continue
        ratio = cur / max(float(base), 1e-9)
        if ratio < fail_ratio:
            failures.append(
                f"{key}: {float(base):.1f}x -> {float(cur):.1f}x "
                f"({ratio:.2f}x of baseline, < {fail_ratio:.1f}x hard floor)"
            )
        elif ratio < warn_ratio:
            warnings.append(
                f"{key}: {float(base):.1f}x -> {float(cur):.1f}x "
                f"({ratio:.2f}x of baseline; wall-clock-derived, not failing)"
            )
    return failures, warnings


def compare(
    baseline: Dict, current: Dict, threshold: float = 0.02
) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes).  A regression is a compared metric more
    than ``threshold`` above baseline, newly infinite, or missing."""
    base_m = extract_metrics(baseline)
    cur_m = extract_metrics(current)
    regressions: List[str] = []
    notes: List[str] = []
    for key, base in sorted(base_m.items()):
        if key not in cur_m:
            regressions.append(f"{key}: metric disappeared from the artifact")
            continue
        cur = cur_m[key]
        if base is None:
            if cur is not None:
                notes.append(f"{key}: inf -> {cur:.2f} (improved)")
            continue
        if cur is None:
            regressions.append(f"{key}: {base:.2f} -> inf")
            continue
        if cur > base * (1.0 + threshold) + 1e-12:
            # a zero baseline (e.g. resilience failure_rate 0.0) gates any
            # increase absolutely -- there is no relative delta to print
            delta = f"+{cur / base - 1:.1%}" if base else "baseline was 0"
            regressions.append(f"{key}: {base:.2f} -> {cur:.2f} ({delta})")
        elif cur < base * (1.0 - threshold):
            notes.append(f"{key}: {base:.2f} -> {cur:.2f} ({cur / base - 1:.1%})")
    new = sorted(set(cur_m) - set(base_m))
    if new:
        notes.append(f"{len(new)} new metric(s) not in baseline (not gated)")
    return regressions, notes


# --------------------------------------------------------------------------
# --json artifact schema (shared with tests/test_bench_schema.py)
# --------------------------------------------------------------------------


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def _is_num_or_null(v) -> bool:
    return v is None or _is_num(v)


def validate_schema(results: Dict) -> List[str]:
    """Validate the ``benchmarks/run.py --json`` artifact structure.

    Returns a list of human-readable errors (empty = valid).  This is the
    contract both this gate and the perf-smoke artifact consumers rely on.
    """
    errors: List[str] = []

    def need(cond: bool, msg: str) -> bool:
        if not cond:
            errors.append(msg)
        return cond

    for key in (
        "table1", "table1_scaling", "table2", "chain_scaling",
        "work_queue_scaling",
    ):
        need(isinstance(results.get(key), list), f"{key}: missing or not a list")
    for key in ("fig5", "fig5_scaling", "chain", "work_queue", "engine_perf"):
        need(isinstance(results.get(key), dict), f"{key}: missing or not a dict")
    need(isinstance(results.get("jax_barriers_ok"), bool),
         "jax_barriers_ok: missing or not a bool")

    for i, row in enumerate(results.get("table1") or []):
        ctx = f"table1[{i}]"
        if not need(isinstance(row, dict), f"{ctx}: not a dict"):
            continue
        need(isinstance(row.get("primitive"), str), f"{ctx}.primitive: not a str")
        need(isinstance(row.get("policy"), str), f"{ctx}.policy: not a str")
        for field in ("cycles", "energy_nj"):
            vals = row.get(field)
            ok = isinstance(vals, list) and len(vals) == 3 and all(
                _is_num(v) for v in vals
            )
            need(ok, f"{ctx}.{field}: expected 3 finite numbers")

    for i, row in enumerate(results.get("table1_scaling") or []):
        ctx = f"table1_scaling[{i}]"
        if not need(isinstance(row, dict), f"{ctx}: not a dict"):
            continue
        counts = row.get("core_counts")
        need(isinstance(counts, list) and all(isinstance(n, int) for n in counts),
             f"{ctx}.core_counts: expected ints")
        for field in ("cycles", "energy_nj"):
            vals = row.get(field)
            ok = (isinstance(vals, list) and isinstance(counts, list)
                  and len(vals) == len(counts) and all(_is_num(v) for v in vals))
            need(ok, f"{ctx}.{field}: expected {field} per core count")

    for scope, fig5 in (
        ("fig5", results.get("fig5") or {}),
        *(
            (f"fig5_scaling@{n}", r)
            for n, r in (results.get("fig5_scaling") or {}).items()
        ),
    ):
        for policy, r in fig5.items():
            ctx = f"{scope}/{policy}"
            if not need(isinstance(r, dict), f"{ctx}: not a dict"):
                continue
            for k in FIG5_KEYS:
                need(_is_num_or_null(r.get(k, "missing")),
                     f"{ctx}.{k}: expected number or null")

    for i, row in enumerate(results.get("table2") or []):
        ctx = f"table2[{i}]"
        if not need(isinstance(row, dict), f"{ctx}: not a dict"):
            continue
        need(isinstance(row.get("app"), str), f"{ctx}.app: not a str")
        cyc = row.get("cycles")
        need(isinstance(cyc, dict) and cyc
             and all(_is_num(v) for v in cyc.values()),
             f"{ctx}.cycles: expected policy->cycles dict")

    chain = results.get("chain") or {}
    for key in ("rows", "depth_sweep", "apps"):
        need(isinstance(chain.get(key), list), f"chain.{key}: missing or not a list")
    for i, row in enumerate(chain.get("rows") or []):
        ctx = f"chain.rows[{i}]"
        if not need(isinstance(row, dict), f"{ctx}: not a dict"):
            continue
        need(isinstance(row.get("policy"), str), f"{ctx}.policy: not a str")
        for field in ("sfr", "depth", "cycles_per_item", "energy_nj_per_item"):
            need(_is_num(row.get(field)), f"{ctx}.{field}: expected finite number")

    wq = results.get("work_queue") or {}
    need(isinstance(wq.get("rows"), list), "work_queue.rows: missing or not a list")
    for i, row in enumerate(wq.get("rows") or []):
        ctx = f"work_queue.rows[{i}]"
        if not need(isinstance(row, dict), f"{ctx}: not a dict"):
            continue
        need(isinstance(row.get("policy"), str), f"{ctx}.policy: not a str")
        for field in ("producers", "consumers", "cycles_per_item",
                      "energy_nj_per_item"):
            need(_is_num(row.get(field)), f"{ctx}.{field}: expected finite number")

    perf = results.get("engine_perf") or {}
    cps = perf.get("cycles_per_sec")
    if need(isinstance(cps, dict), "engine_perf.cycles_per_sec: not a dict"):
        for mode in ("lockstep", "fastforward"):
            need(_is_num(cps.get(mode)),
                 f"engine_perf.cycles_per_sec.{mode}: expected finite number")
    need(_is_num(perf.get("speedup")), "engine_perf.speedup: expected finite number")
    contended = perf.get("contended")
    if need(isinstance(contended, dict),
            "engine_perf.contended: missing or not a dict"):
        need(_is_num(contended.get("cycles_per_sec")),
             "engine_perf.contended.cycles_per_sec: expected finite number")
        need(_is_num(contended.get("speedup")),
             "engine_perf.contended.speedup: expected finite number")
    fleet = perf.get("fleet")
    if need(isinstance(fleet, dict), "engine_perf.fleet: missing or not a dict"):
        need(_is_num(fleet.get("configs")),
             "engine_perf.fleet.configs: expected finite number")
        need(_is_num(fleet.get("speedup")),
             "engine_perf.fleet.speedup: expected finite number")
        need(_is_num(fleet.get("speedup_8core")),
             "engine_perf.fleet.speedup_8core: expected finite number")
    compiled = perf.get("compiled")
    if need(isinstance(compiled, dict),
            "engine_perf.compiled: missing or not a dict"):
        need(_is_num(compiled.get("configs")),
             "engine_perf.compiled.configs: expected finite number")
        need(_is_num(compiled.get("speedup")),
             "engine_perf.compiled.speedup: expected finite number")

    traffic = results.get("traffic")
    if need(isinstance(traffic, dict), "traffic: missing or not a dict"):
        scenarios = traffic.get("scenarios")
        if need(isinstance(scenarios, dict) and scenarios,
                "traffic.scenarios: missing or empty"):
            for name, sc in scenarios.items():
                for mode in ("continuous", "drain"):
                    ctx = f"traffic.scenarios.{name}.{mode}"
                    r = sc.get(mode) if isinstance(sc, dict) else None
                    if not need(isinstance(r, dict), f"{ctx}: not a dict"):
                        continue
                    for k in ("rounds", "p50_latency_rounds",
                              "p99_latency_rounds", "idle_lane_fraction"):
                        need(_is_num(r.get(k)),
                             f"{ctx}.{k}: expected finite number")
        tail = traffic.get("energy_tail")
        if need(isinstance(tail, dict) and tail,
                "traffic.energy_tail: missing or empty"):
            for policy, t in tail.items():
                for k in ("p99_spin_pj", "p99_idle_pj"):
                    need(isinstance(t, dict) and _is_num(t.get(k)),
                         f"traffic.energy_tail.{policy}.{k}: expected finite number")
        need(_is_num(traffic.get("speedup")),
             "traffic.speedup: expected finite number")

    res = results.get("resilience")
    if need(isinstance(res, dict), "resilience: missing or not a dict"):
        cells = res.get("cells")
        if need(isinstance(cells, dict) and cells,
                "resilience.cells: missing or empty"):
            for rate, modes in cells.items():
                if not need(isinstance(modes, dict) and modes,
                            f"resilience.cells.{rate}: missing or empty"):
                    continue
                for mode, c in modes.items():
                    ctx = f"resilience.cells.{rate}.{mode}"
                    if not need(isinstance(c, dict), f"{ctx}: not a dict"):
                        continue
                    for k in ("failure_rate", "failed_jobs", "completed_jobs",
                              "total_attempts", "degraded_jobs",
                              "wasted_cycles", "rounds",
                              "mean_latency_rounds", "watchdog_releases",
                              "mean_completed_cycles"):
                        need(_is_num(c.get(k)),
                             f"{ctx}.{k}: expected finite number")

    fd = results.get("fault_domains")
    if need(isinstance(fd, dict), "fault_domains: missing or not a dict"):
        cells = fd.get("cells")
        if need(isinstance(cells, dict) and cells,
                "fault_domains.cells: missing or empty"):
            for rate, policies in cells.items():
                if not need(isinstance(policies, dict) and policies,
                            f"fault_domains.cells.{rate}: missing or empty"):
                    continue
                for policy, c in policies.items():
                    ctx = f"fault_domains.cells.{rate}.{policy}"
                    if not need(isinstance(c, dict), f"{ctx}: not a dict"):
                        continue
                    for k in ("failure_rate", "failed_jobs", "completed_jobs",
                              "total_attempts", "reroutes", "quarantines",
                              "wasted_cycles", "rounds",
                              "mean_latency_rounds", "watchdog_trips"):
                        need(_is_num(c.get(k)),
                             f"{ctx}.{k}: expected finite number")

    pre = results.get("preemption")
    if need(isinstance(pre, dict), "preemption: missing or not a dict"):
        mig = pre.get("migration")
        if need(isinstance(mig, dict) and mig,
                "preemption.migration: missing or empty"):
            for mode, c in mig.items():
                ctx = f"preemption.migration.{mode}"
                if not need(isinstance(c, dict), f"{ctx}: not a dict"):
                    continue
                for k in ("failure_rate", "failed_jobs", "completed_jobs",
                          "total_attempts", "wasted_cycles", "reroutes",
                          "migrations", "rounds", "mean_latency_rounds"):
                    need(_is_num(c.get(k)),
                         f"{ctx}.{k}: expected finite number")
        sched = pre.get("schedule")
        if need(isinstance(sched, dict) and sched,
                "preemption.schedule: missing or empty"):
            for mode, c in sched.items():
                ctx = f"preemption.schedule.{mode}"
                if not need(isinstance(c, dict), f"{ctx}: not a dict"):
                    continue
                for k in ("failure_rate", "completed_jobs", "preemptions",
                          "wasted_cycles", "rounds", "mean_latency_rounds",
                          "hi_latency_rounds", "hi_queue_rounds"):
                    need(_is_num(c.get(k)),
                         f"{ctx}.{k}: expected finite number")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="golden baseline JSON (committed)")
    ap.add_argument("current", help="freshly produced benchmark JSON")
    ap.add_argument(
        "--threshold", type=float, default=0.02,
        help="relative regression tolerance on cycle-exact keys (default 2%%)",
    )
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
        with open(args.current) as f:
            current = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[bench_compare] cannot load artifacts: {e}", file=sys.stderr)
        return 2

    schema_errors = validate_schema(current)
    if schema_errors:
        print("[bench_compare] current artifact violates the --json schema:")
        for err in schema_errors:
            print(f"  SCHEMA {err}")
        return 2

    regressions, notes = compare(baseline, current, threshold=args.threshold)
    perf_fails, perf_warns = compare_throughput(baseline, current)
    regressions += perf_fails
    n_compared = len(extract_metrics(baseline))
    for note in notes:
        print(f"  note  {note}")
    for warn in perf_warns:
        print(f"  WARN  {warn}")
    if regressions:
        print(
            f"[bench_compare] {len(regressions)} regression(s) over "
            f"{args.threshold:.0%} on {n_compared} gated metrics:"
        )
        for r in regressions:
            print(f"  FAIL  {r}")
        return 1
    print(
        f"[bench_compare] OK: {n_compared} cycle-exact metrics within "
        f"{args.threshold:.0%} of baseline "
        f"(+ engine throughput soft gate: {len(perf_warns)} warning(s))"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
