"""No-jax smoke for the compiled trace path (the ``REPRO_NO_JAX=1`` CI lane).

Proves the numpy tier of the PR-8 dispatcher end to end without importing
jax anywhere in the process:

1. generator programs lower to static traces (``lower_or_fallback``) and the
   cursor run is bit-exact against the plain generator run;
2. ``run_traces_xp`` (the batched array executor, ``xp=numpy``) reproduces
   the engine's counters cycle for cycle on the same traces;
3. an untraceable (data-dependent-loop) program falls back to its generator
   and stays bit-exact;
4. ``sys.modules`` contains no jax at exit -- the real no-jax guarantee.

Run from the repo root with ``PYTHONPATH=src`` (scripts/ci.sh does); the
module force-sets ``REPRO_NO_JAX`` before anything from :mod:`repro` loads.
"""

import os
import sys

os.environ["REPRO_NO_JAX"] = "1"

if __package__ is None and "src" not in sys.path:  # direct invocation
    sys.path.insert(0, "src")

from repro.compat import HAS_JAX  # noqa: E402
from repro.core.scu import SCU, Cluster, Compute, Mem  # noqa: E402
from repro.core.scu.engine import _COUNTERS  # noqa: E402
from repro.core.scu.trace import (  # noqa: E402
    TraceBuilder,
    lower_or_fallback,
    run_traces_xp,
)

N = 8


def make_cluster():
    return Cluster(n_cores=N, scu=SCU(n_cores=N), mode="fastforward")


def traceable(cluster, cid):
    # value-independent: fixed trip count, pure TCDM traffic
    for it in range(6):
        yield Compute(2 + cid)
        yield Mem("sw", 0x80 + 4 * cid, 10 * cid + it)
        yield Mem("lw", 0x80 + 4 * ((cid + 1) % N))
        yield Mem("lw", 0x40)  # shared word: forced bank conflicts


def data_dependent(cluster, cid):
    yield Mem("sw", 0x200 + 4 * cid, cid % 3)
    v = yield Mem("lw", 0x200 + 4 * cid)
    for _ in range(v):  # trip count is a loaded value: untraceable
        yield Compute(3)


def check(name, got, want):
    if got != want:
        sys.exit(f"compiled_smoke: {name} mismatch:\n  got  {got}\n  want {want}")


def main():
    assert not HAS_JAX, "REPRO_NO_JAX must gate repro.compat.HAS_JAX"

    # 1. lowered cursors vs generator engine
    cl_ref = make_cluster()
    cl_ref.load([traceable] * N)
    ref = cl_ref.run()

    cl = make_cluster()
    lowered = [lower_or_fallback(traceable, cl, cid) for cid in range(N)]
    assert all(p.is_traced for p in lowered), "traceable program fell back"
    cl.load(lowered)
    check("cursor stats", cl.run(), ref)

    # 2. batched array executor vs engine counters
    cl2 = make_cluster()
    tables = [lower_or_fallback(traceable, cl2, cid) for cid in range(N)]
    res = run_traces_xp(tables, n_banks=cl2.n_banks)
    check("xp cycles", res["cycles"], ref.cycles)
    check("xp conflicts", res["bank_conflicts"], ref.bank_conflicts)
    for i, cname in enumerate(_COUNTERS):
        check(
            f"xp counter {cname}",
            res["counters"][cname].tolist(),
            [getattr(c, cname) for c in ref.cores],
        )

    # 3. untraceable program: declared fallback, still bit-exact
    cl3 = make_cluster()
    cl3.load([data_dependent] * N)
    ref3 = cl3.run()
    cl4 = make_cluster()
    fb = [lower_or_fallback(data_dependent, cl4, cid) for cid in range(N)]
    assert not any(p.is_traced for p in fb), "untraceable program got traced"
    cl4.load(fb)
    check("fallback stats", cl4.run(), ref3)

    # 4. the whole run never touched jax
    leaked = [m for m in sys.modules if m == "jax" or m.startswith("jax.")]
    assert not leaked, f"jax leaked into the no-jax lane: {leaked[:3]}"

    print(
        f"compiled_smoke: OK -- {N} cores, cursor+xp+fallback bit-exact, "
        f"cycles={ref.cycles}, no jax imported"
    )


if __name__ == "__main__":
    main()
