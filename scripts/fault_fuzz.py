#!/usr/bin/env python
"""Randomized fault-injection parity fuzz (the non-gating CI step).

Each trial draws a random workload (policy x core count x shape), a random
:class:`FaultPlan` and optionally a watchdog, runs it under both engine
modes and requires the identical outcome -- same stats on completion, same
cycle and wait-for dump on a deadlock.  A fraction of trials sample
*domain-scoped* plans (``FaultPlan.random_domain``: correlated droop /
scu_blackout / bank_blackout events over contiguous core and bank groups)
instead of independent per-core events; ``--domain-only`` restricts the
run to those (the dedicated CI lane).  The in-tree ``tests/test_faults.py``
suite pins a fixed seed set; this fuzz keeps rolling fresh seeds in CI so
parity holes surface early without gating merges on an unbounded search.

``--snapshot`` switches to checkpoint/restore parity trials instead: a
random compiled workload under a random *non-deadlocking* domain plan runs
uninterrupted on a :class:`SlotFleet` for reference, then again suspended
at a random round boundary (``SlotFleet.suspend``) and resumed into a
fresh fleet (``SlotFleet.restore``) -- the drained outcome must be
bit-identical.  A divergence prints the eval-able plan, the checkpoint
round and cycle (``tests/test_checkpoint.py`` pins the fixed seed set).

    PYTHONPATH=src python scripts/fault_fuzz.py [--trials N] [--seed S]
                                                [--domain-only | --snapshot]

The base seed is randomized per invocation unless ``--seed`` is given; on
failure the exact reproduction command (seed + trial) and the minimal
eval-able ``FaultPlan`` repr are printed.
"""

from __future__ import annotations

import argparse
import random
import sys

from repro.core.scu.faults import DeadlockError, FaultPlan, SimTimeout, Watchdog
from repro.core.scu.programs import (
    prep_barrier_bench,
    prep_chain_bench,
    prep_mutex_bench,
)

POLICIES = ("scu", "tas", "sw", "tree", "fifo")
CORES = (8, 16, 64)
MAX_CYCLES = 12_000


def _prep(rng: random.Random, policy: str, n: int, mode: str):
    shape = rng.randrange(3) if n <= 16 else 0
    iters = rng.randint(2, 6)
    if shape == 0:
        return prep_barrier_bench(policy, n, sfr=rng.choice((0, 20, 150)),
                                  iters=iters, mode=mode)
    if shape == 1:
        return prep_mutex_bench(policy, n, t_crit=rng.randint(0, 12),
                                iters=iters, mode=mode)
    return prep_chain_bench(policy, n, sfr=rng.choice((20, 100)),
                            iters=iters, depth=rng.choice((1, 4)), mode=mode)


def run_trial(trial_seed: int, domain_only: bool = False) -> bool:
    """One parity trial; returns True when both engine modes agree."""
    rng = random.Random(trial_seed)
    policy = rng.choice(POLICIES)
    n = rng.choice(CORES)
    # ~40% of mixed trials (and every --domain-only trial) draw correlated
    # domain-scoped plans; the rest keep the independent per-core sampler
    domain = domain_only or rng.random() < 0.4
    if domain:
        plan = FaultPlan.random_domain(
            trial_seed, n_cores=n, n_banks=2 * n, horizon=500,
            n_events=rng.randint(1, 4), n_domains=rng.choice((2, 4)),
        )
    else:
        plan = FaultPlan.random(
            trial_seed, n_cores=n, n_banks=2 * n, horizon=500,
            n_events=rng.randint(1, 5),
        )
    use_watchdog = rng.random() < 0.3
    wd_mode = rng.choice(("release", "raise"))
    wd_timeout = rng.randint(100, 600)

    outcomes = []
    for mode in ("lockstep", "fastforward"):
        sub = random.Random(trial_seed)  # identical workload draw per mode
        fb = _prep(sub, policy, n, mode)
        cl = fb.config.cluster
        cl.faults = plan.clone()
        if use_watchdog and cl.scu is not None:
            cl.scu.watchdog = Watchdog(timeout=wd_timeout, mode=wd_mode)
        cl.load(fb.config.programs)
        try:
            cl.run(MAX_CYCLES)
            outcomes.append(("done", cl.stats))
        except SimTimeout as e:
            outcomes.append(("timeout", cl.cycle, str(e)))
        except DeadlockError as e:
            outcomes.append(("deadlock", e.graph.cycle, str(e)))
    if outcomes[0] != outcomes[1]:
        print(f"PARITY MISMATCH (trial seed {trial_seed}): "
              f"{policy}@{n}, watchdog={use_watchdog}, domain={domain}")
        print(f"  lockstep:    {outcomes[0][:2]}")
        print(f"  fastforward: {outcomes[1][:2]}")
        print(f"  plan: {plan!r}")  # eval-able: paste into a pinned test
        return False
    return True


def run_snapshot_trial(trial_seed: int) -> bool:
    """One checkpoint/restore parity trial on the slot-recycling fleet.

    Draws a compiled (trace-lowered, hence checkpointable) barrier
    workload and a non-deadlocking domain-scoped plan (droop and blackout
    events defer progress but never destroy it), runs it uninterrupted for
    the reference outcome, then suspends the same workload at a random
    round boundary and resumes it in a *different* fleet.  Returns True
    when both outcomes are bit-identical."""
    from repro.core.scu.engine import SlotFleet

    rng = random.Random(trial_seed)
    policy = rng.choice(POLICIES)
    n = rng.choice(CORES)
    iters = rng.randint(2, 6)
    sfr = rng.choice((0, 20, 150))
    plan = FaultPlan.random_domain(
        trial_seed, n_cores=n, n_banks=2 * n, horizon=500,
        n_events=rng.randint(1, 4), n_domains=rng.choice((2, 4)),
    )

    def config():
        fb = prep_barrier_bench(policy, n, sfr=sfr, iters=iters,
                                compiled=True)
        fb.config.max_cycles = MAX_CYCLES
        fb.config.cluster.faults = plan.clone()
        return fb.config

    def outcome(member):
        if member.error is not None:
            return ("failed", member.cluster.cycle, member.error)
        return ("done", member.cluster.stats)

    # uninterrupted reference + the run's total round count
    fleet = SlotFleet(1, n)
    fleet.admit(config())
    rounds, fin = 0, []
    while not fin:
        fin = fleet.advance()
        rounds += 1
    ref = outcome(fin[0])
    if rounds < 2:
        return True  # nothing in-flight to suspend

    k = 1 + rng.randrange(rounds - 1)  # a strictly mid-run round boundary
    fleet = SlotFleet(1, n)
    slot = fleet.admit(config())
    for _ in range(k):
        fleet.advance()
    ckpt = fleet.suspend(slot)
    other = SlotFleet(2, n)
    other.restore(ckpt)
    fin = []
    while not fin:
        fin = other.advance()
    got = outcome(fin[0])

    if got != ref:
        print(f"SNAPSHOT PARITY MISMATCH (trial seed {trial_seed}): "
              f"{policy}@{n}, sfr={sfr}, iters={iters}, "
              f"suspended at round {k} (cycle {ckpt.cycle})")
        print(f"  uninterrupted: {ref[:2]}")
        print(f"  restored:      {got[:2]}")
        print(f"  plan: {plan!r}")  # eval-able: paste into a pinned test
        return False
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trials", type=int, default=20)
    ap.add_argument("--seed", type=int, default=None,
                    help="base seed (default: randomized, printed for replay)")
    ap.add_argument("--domain-only", action="store_true",
                    help="draw only domain-scoped plans (the CI domain lane)")
    ap.add_argument("--snapshot", action="store_true",
                    help="checkpoint/restore parity trials on the slot "
                    "fleet (the CI snapshot lane)")
    args = ap.parse_args(argv)
    if args.domain_only and args.snapshot:
        ap.error("--domain-only and --snapshot are separate lanes")

    base = args.seed if args.seed is not None else random.randrange(2**31)
    lane = (" --domain-only" if args.domain_only
            else " --snapshot" if args.snapshot else "")
    print(f"[fault_fuzz] base seed {base}, {args.trials} trials "
          f"(replay: scripts/fault_fuzz.py --seed {base} "
          f"--trials {args.trials}{lane})")
    failures = 0
    for i in range(args.trials):
        if args.snapshot:
            ok = run_snapshot_trial(base + i)
        else:
            ok = run_trial(base + i, domain_only=args.domain_only)
        if not ok:
            failures += 1
            print(f"[fault_fuzz] reproduce just this trial: "
                  f"scripts/fault_fuzz.py --seed {base + i} --trials 1{lane}")
    if failures:
        print(f"[fault_fuzz] {failures}/{args.trials} trials diverged "
              f"(base seed {base})")
        return 1
    what = ("across suspend/restore" if args.snapshot
            else "across engine modes")
    print(f"[fault_fuzz] OK: {args.trials} randomized trials bit-exact {what}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
