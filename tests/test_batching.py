"""Tests for the continuous-batching decode scheduler (serve/batching.py).

These pin the exemplar semantics the fleet sweep service mirrors one level
up: FIFO admission from a queue into fixed slots, slot recycling after a
finish, and the deadline force-finish straggler guard.
"""

import numpy as np

from repro.serve.batching import ContinuousBatcher, Request


def _step(batcher, token=7):
    """One decode step feeding every slot the same sampled token."""
    sampled = np.full((batcher.batch_slots,), token, np.int32)
    return batcher.observe(sampled)


def test_fifo_admission_order():
    """Queued requests are admitted in submission order, exactly filling
    the free slots; the overflow waits."""
    b = ContinuousBatcher(batch_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[1, 2], max_new_tokens=4) for i in range(4)]
    for r in reqs:
        b.submit(r)
    admitted = b.admit()
    assert admitted == [0, 1]
    assert b.slots[0].rid == 0 and b.slots[1].rid == 1
    assert b.pending == 2 and b.active == 2
    assert b.admit() == []  # no free slot: nothing admitted, queue intact
    assert b.pending == 2


def test_slot_reuse_after_finish():
    """A finished request frees its slot; the next admit() hands that slot
    to the oldest queued request with clean decode state."""
    b = ContinuousBatcher(batch_slots=2, max_seq=32, pad_token=0)
    short = Request(rid=0, prompt=[1], max_new_tokens=1)
    long = Request(rid=1, prompt=[1], max_new_tokens=8)
    waiting = Request(rid=2, prompt=[5, 6], max_new_tokens=2)
    for r in (short, long, waiting):
        b.submit(r)
    assert b.admit() == [0, 1]

    done = _step(b)
    assert [r.rid for r in done] == [0]  # short finished, long keeps going
    assert b.slots[0] is None
    assert b.positions[0] == 0 and b.next_tokens[0] == 0  # state scrubbed

    assert b.admit() == [0]  # freed slot recycled to the FIFO head
    assert b.slots[0].rid == 2
    assert b.positions[0] == len(waiting.prompt)
    assert b.next_tokens[0] == waiting.prompt[-1]

    # drain: nothing left queued, both remaining requests run to completion
    steps = 0
    while not b.drain_done():
        _step(b)
        b.admit()
        steps += 1
        assert steps < 64
    assert sorted(b.finished) == [0, 1, 2]
    assert len(long.generated) == 8
    assert len(waiting.generated) == 2


def test_deadline_force_finishes_straggler():
    """A request past deadline_steps is force-finished even though it has
    token budget left -- the serving watchdog."""
    b = ContinuousBatcher(batch_slots=1, max_seq=64)
    straggler = Request(
        rid=0, prompt=[1], max_new_tokens=1000, deadline_steps=3
    )
    b.submit(straggler)
    b.admit()
    done = []
    for _ in range(3):
        assert done == []
        done = _step(b)
    assert [r.rid for r in done] == [0]
    assert straggler.age == 3
    assert len(straggler.generated) == 3  # far short of max_new_tokens
    assert b.slots[0] is None  # slot freed for the next request


def test_max_seq_caps_generation():
    """The cache bound force-finishes a request whose position would run
    off the end of the static shape."""
    b = ContinuousBatcher(batch_slots=1, max_seq=4)
    r = Request(rid=0, prompt=[1], max_new_tokens=100)
    b.submit(r)
    b.admit()
    steps = 0
    while b.active:
        _step(b)
        steps += 1
        assert steps < 10
    assert steps == 2  # positions 1 -> 3 == max_seq - 1
