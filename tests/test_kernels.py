"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes (assignment requirement c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.scu_barrier.kernel import scu_self_signal_kernel
from repro.sync import get_policy
from repro.kernels.scu_barrier.ref import self_signal_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_fwd
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.sync import available_policies

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# flash attention: shape x dtype sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize(
    "b,h,kvh,s,d,bq,bk",
    [
        (1, 4, 4, 128, 64, 64, 64),  # MHA
        (2, 8, 2, 256, 64, 64, 128),  # GQA 4:1, rectangular blocks
        (1, 4, 1, 256, 128, 128, 64),  # MQA, 128-dim heads
        (1, 2, 2, 512, 64, 128, 128),  # longer sequence
    ],
)
def test_flash_kernel_matches_ref(b, h, kvh, s, d, bq, bk, dtype, rtol):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kvh, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kvh, s, d), dtype)
    out = flash_attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), causal=True
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=rtol, atol=rtol
    )


def test_flash_kernel_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    out = flash_attention_fwd(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_ops_wrapper_layout():
    """ops.flash_attention takes models' (b, s, h, d) layout."""
    ks = jax.random.split(KEY, 3)
    b, s, h, d = 1, 128, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, 2, d))
    v = jax.random.normal(ks[2], (b, s, 2, d))
    out_pallas = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    out_ref = flash_attention(q, k, v, block_q=64, block_k=64, interpret=False)
    np.testing.assert_allclose(
        np.asarray(out_pallas), np.asarray(out_ref), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# SSD scan: shape x dtype sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [
        (1, 128, 2, 32, 16, 32),
        (2, 128, 4, 64, 32, 64),
        (1, 256, 2, 64, 128, 128),  # mamba2-1.3b-like head/state dims
    ],
)
def test_ssd_kernel_matches_ref(b, s, h, p, n, chunk, dtype, tol):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, s, n)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (b, s, n)) * 0.3).astype(dtype)
    out = ssd_scan_fwd(x, dt, A, B, C, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(
        x.astype(jnp.float32), dt.astype(jnp.float32), A,
        B.astype(jnp.float32), C.astype(jnp.float32), chunk=chunk,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=tol, atol=tol
    )


def test_ssd_kernel_state_carry_across_chunks():
    """Multiple chunks must agree with a single-chunk run (state carried in
    VMEM scratch across the sequential grid axis)."""
    b, s, h, p, n = 1, 128, 1, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, n)) * 0.3
    out_multi = ssd_scan_fwd(x, dt, A, B, C, chunk=32, interpret=True)
    out_single = ssd_scan_fwd(x, dt, A, B, C, chunk=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out_multi), np.asarray(out_single), rtol=3e-4, atol=3e-4
    )


# ---------------------------------------------------------------------------
# SCU barrier: single-core event semantics + collective fallback equivalence
# ---------------------------------------------------------------------------


def test_scu_self_signal_semantics():
    x = jnp.arange(8, dtype=jnp.float32)
    out = scu_self_signal_kernel(x, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(self_signal_ref(x)))


@pytest.mark.parametrize("strategy", available_policies())
def test_barrier_strategies_equivalent(strategy):
    """Every registered discipline releases with the same arrival count."""
    n = min(4, jax.device_count())
    if n < 2:
        pytest.skip("needs >= 2 devices")
    from repro.compat import make_axis_mesh, shard_map

    mesh = make_axis_mesh((n,), ("x",))
    from jax.sharding import PartitionSpec as P

    arrive = jnp.ones((n,), jnp.float32)

    @jax.jit
    def run(a):
        return shard_map(
            lambda v: get_policy(strategy).chip_barrier(v, "x"),
            mesh=mesh,
            in_specs=P("x"),
            out_specs=P("x"),
        )(a)

    out = run(arrive)
    np.testing.assert_allclose(np.asarray(out), np.full((n,), float(n)))
