"""End-to-end behaviour tests spanning both tiers of the reproduction."""

import pytest

from repro.core.scu import APPS, run_app
from repro.core.scu.programs import run_barrier_bench


def test_paper_headline_sfr_reduction():
    """The paper's central claim end-to-end: the SCU makes fine-grain
    parallel regions affordable -- min SFR @10% drops by >25x vs SW."""
    from benchmarks.fig5_overhead import run

    result = run(n_cores=8, iters=8, verbose=False)
    scu = result["scu"]["min_sfr_energy_10pct"]
    sw = result["sw"]["min_sfr_energy_10pct"]
    assert scu < 100, f"SCU min SFR {scu} should be tens of cycles"
    assert sw / scu > 25, f"reduction {sw/scu:.1f}x (paper: 41x)"


def test_scu_wins_on_every_app():
    """Fig. 6: SCU improves (or matches) perf and energy on every app."""
    for name in ("dwt", "fft", "livermore6"):
        scu = run_app(APPS[name], "SCU")
        sw = run_app(APPS[name], "SW")
        assert scu.cycles <= sw.cycles
        assert scu.energy_uj <= sw.energy_uj * 1.01


def test_small_sfr_apps_gain_most():
    """The SFR size predicts the gain (Sec. 6.4's key observation)."""
    small = APPS["dijkstra"]  # SFR ~110
    large = APPS["aes"]  # SFR ~10k
    gain_small = run_app(small, "SW").cycles / run_app(small, "SCU").cycles
    gain_large = run_app(large, "SW").cycles / run_app(large, "SCU").cycles
    assert gain_small > gain_large + 0.2


def test_barrier_scaling_shape():
    """SCU flat in core count; SW superlinear (Fig. 3 / Tbl. 1 shape)."""
    scu = [run_barrier_bench("SCU", n, 0, iters=16).prim_cycles for n in (2, 4, 8)]
    sw = [run_barrier_bench("SW", n, 0, iters=16).prim_cycles for n in (2, 4, 8)]
    assert max(scu) - min(scu) < 1.0
    assert sw[2] > 3 * sw[0]


def test_dryrun_artifacts_complete_if_present():
    """If the sweep has been run, every (arch x shape x mesh) cell must be
    either ok or an assignment-mandated skip -- never silently missing."""
    import json
    from pathlib import Path

    from repro.configs.base import SHAPES
    from repro.configs.registry import list_archs

    art = Path("artifacts/dryrun")
    if not art.exists():
        pytest.skip("dry-run artifacts not generated in this environment")
    for mesh in ("single", "multi"):
        for arch in list_archs():
            for shape in SHAPES:
                f = art / mesh / f"{arch}__{shape}.json"
                assert f.exists(), f"missing cell {mesh}/{arch}/{shape}"
                rec = json.loads(f.read_text())
                assert rec.get("status") == "ok" or rec.get("applicable") is False, (
                    f"cell {mesh}/{arch}/{shape}: {rec.get('error', 'bad status')}"
                )
