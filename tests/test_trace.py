"""Trace IR contract tests: lowering parity, fallback, batched executors.

The compiled path (``repro.core.scu.trace``) must be *bit-exact* against the
generator engine -- same ``ClusterStats``, cycle for cycle -- for every
builtin policy and bench shape it claims to trace, and must fall back to the
generator cleanly (still bit-exact, ``is_traced`` False) whenever it cannot
prove a program value-independent.

Matrix coverage vs runtime: the full policy x bench grid runs at 8 cores;
at 64/256 the busy-wait policies (``tas``/``sw``) are excluded from the
combos whose *generator reference* is O(n^2)-spin x many episodes (mutex at
256, chain/work_queue at 64+) -- those single references alone take minutes
of wall clock, and the trace semantics they would exercise are identical to
the 8-core runs that do cover them.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scu import SCU, Cluster, Compute, Mem
from repro.core.scu.engine import _COUNTERS
from repro.core.scu.programs import (
    prep_barrier_bench,
    prep_chain_bench,
    prep_mutex_bench,
    prep_work_queue_bench,
)
from repro.core.scu.trace import (
    TraceBuilder,
    TraceProgram,
    Untraceable,
    lower_or_fallback,
    run_traces_xp,
    trace_generator,
)
from repro.compat import HAS_JAX

POLICIES = ("scu", "tas", "sw", "tree", "tree4", "tree_ew", "fifo")
SPIN = ("tas", "sw")  # losers hammer the TCDM; generator reference is O(n^2)

# workloads shrink with core count so the reference runs stay test-sized
_BENCHES = {
    "barrier": lambda v, n, c: prep_barrier_bench(
        v, n, sfr=7, iters={8: 6, 64: 3, 256: 1}[n], compiled=c
    ),
    "mutex": lambda v, n, c: prep_mutex_bench(
        v, n, t_crit=3, iters={8: 4, 64: 1, 256: 1}[n], compiled=c
    ),
    "chain": lambda v, n, c: prep_chain_bench(
        v, n, sfr=5, iters=2, depth=4, compiled=c
    ),
    "work_queue": lambda v, n, c: prep_work_queue_bench(
        v, n // 2, n - n // 2, items={8: 24, 64: 48, 256: 96}[n],
        t_produce=4, t_consume=4, compiled=c
    ),
}


def _combos():
    for n in (8, 64, 256):
        for variant in POLICIES:
            for bench in _BENCHES:
                if variant in SPIN and (
                    (n >= 64 and bench in ("chain", "work_queue"))
                    or (n == 256 and bench == "mutex")
                ):
                    continue  # minutes-long O(n^2) spin reference; see module docstring
                if variant in ("tree", "tree4") and n == 256 and bench == "chain":
                    continue  # combining trees poll child flags: ~100s/ref at 256
                yield n, variant, bench


_COMBOS = list(_combos())


@pytest.mark.parametrize(
    "n,variant,bench", _COMBOS,
    ids=[f"{b}-{v}-{n}" for n, v, b in _COMBOS],
)
def test_lowering_parity(n, variant, bench):
    """Compiled path == generator path, ClusterStats bit-exact."""
    mk = _BENCHES[bench]
    ref = mk(variant, n, False).run_sequential().stats
    got = mk(variant, n, True).run_sequential().stats
    assert got == ref


# which (bench, policy) combos must lower to *real* static traces, as
# opposed to the declared generator fallback.  fifo's mutex seeds a shared
# Python-side queue in cross-core execution order, and the generic
# mutex-protected work queue branches on shared occupancy -- both are
# order-dependent, so sentinel-tracing them would be silently wrong and the
# lowering refuses outright.
_TRACED = {
    "barrier": set(POLICIES),
    "mutex": set(POLICIES) - {"fifo"},
    "chain": set(POLICIES),
    "work_queue": {"fifo"},
}


@pytest.mark.parametrize("bench", tuple(_BENCHES))
@pytest.mark.parametrize("variant", POLICIES)
def test_traceability_matrix(variant, bench):
    """Each combo lowers to a static trace exactly when it is provably (or
    by policy-declared emitter) value-independent; everything else must be
    a declared fallback -- never a wrong trace."""
    fb = _BENCHES[bench](variant, 8, True)
    progs = fb.config.programs
    assert all(isinstance(p, TraceProgram) for p in progs)
    traced = sum(p.is_traced for p in progs)
    if variant in _TRACED[bench]:
        assert traced == len(progs)
    else:
        assert traced == 0


@given(ks=st.lists(st.integers(0, 5), min_size=4, max_size=4))
@settings(max_examples=15, deadline=None)
def test_untraceable_data_dependent_loop_falls_back(ks):
    """A loop whose trip count is a loaded value cannot be traced: the
    sentinel tracer must refuse (never record one unrolling as if it were
    universal) and the fallback must stay bit-exact."""

    def prog(cluster, cid):
        yield Mem("sw", 0x200 + 4 * cid, ks[cid])
        v = yield Mem("lw", 0x200 + 4 * cid)
        for _ in range(v):  # data-dependent trip count
            yield Compute(3)

    def make_cluster():
        return Cluster(n_cores=4, scu=SCU(n_cores=4), mode="fastforward")

    cl = make_cluster()
    cl.load([prog] * 4)
    ref = cl.run()

    cl2 = make_cluster()
    with pytest.raises(Untraceable):
        trace_generator(TraceBuilder(), prog(cl2, 0))
    lowered = [lower_or_fallback(prog, cl2, cid) for cid in range(4)]
    assert all(not p.is_traced for p in lowered)
    cl2.load(lowered)
    assert cl2.run() == ref


def test_trace_program_single_use_and_clone():
    """Cursor semantics mirror FaultPlan: one run per instance, clone() for
    a fresh instance -- even after the original was consumed."""
    tb = TraceBuilder()
    tb.compute(5)
    tb.mem("sw", 0x40, 1)
    tp = tb.build(label="t")
    cl = Cluster(n_cores=1, scu=SCU(n_cores=1))

    pre_clone = tp.clone()
    assert tp(cl, 0) is not None and tp.consumed
    with pytest.raises(RuntimeError, match="single-use"):
        tp(cl, 0)
    post_clone = tp.clone()  # cloning a consumed program is fine
    for c in (pre_clone, post_clone):
        assert not c.consumed and c.is_traced
        assert c(cl, 0) is not None


def _tcdm_traces(n):
    """Small pure-TCDM per-core traces with cross-core bank contention."""
    out = []
    for cid in range(n):
        tb = TraceBuilder()
        for it in range(3):
            tb.mark()
            tb.compute(2 + cid)
            tb.mem("sw", 0x80 + 4 * cid, 10 * cid + it)
            tb.mem("lw", 0x80 + 4 * ((cid + 1) % n))
            tb.mem("lw", 0x40)  # everyone hits one bank: forced conflicts
        out.append(tb.build(label=f"xp:{cid}"))
    return out


def test_run_traces_xp_matches_engine():
    """The batched array executor reimplements TCDM issue/arbitration/
    accounting from scratch; it must agree with the engine counter for
    counter, cycle for cycle."""
    n = 8
    cl = Cluster(n_cores=n, scu=SCU(n_cores=n), mode="lockstep")
    cl.load(_tcdm_traces(n))
    ref = cl.run()

    res = run_traces_xp(_tcdm_traces(n), n_banks=cl.n_banks)
    assert res["cycles"] == ref.cycles
    assert res["bank_conflicts"] == ref.bank_conflicts
    for i, name in enumerate(_COUNTERS):
        got = res["counters"][name].tolist()
        want = [getattr(c, name) for c in ref.cores]
        assert got == want, name


def test_run_traces_xp_is_single_use():
    progs = _tcdm_traces(2)
    run_traces_xp(progs, n_banks=4)
    with pytest.raises(RuntimeError, match="consumed"):
        run_traces_xp(progs, n_banks=4)


def test_run_traces_xp_rejects_scu_rows():
    tb = TraceBuilder()
    tb.compute(1)
    tb.scu("write", 0x10, 1)
    tp = tb.build()
    with pytest.raises(ValueError, match="SCU"):
        run_traces_xp([tp], n_banks=4)


@pytest.mark.skipif(not HAS_JAX, reason="jax unavailable")
def test_run_traces_jax_matches_numpy():
    from repro.core.scu.trace import run_traces_jax

    n = 4
    ref = run_traces_xp(_tcdm_traces(n), n_banks=2 * n)
    got = run_traces_jax(_tcdm_traces(n), n_banks=2 * n)
    assert got["cycles"] == ref["cycles"]
    assert got["bank_conflicts"] == ref["bank_conflicts"]
    for name in _COUNTERS:
        assert got["counters"][name].tolist() == ref["counters"][name].tolist()
    assert got["tcdm"] == ref["tcdm"]


def test_compiled_fleet_row_is_jumping():
    """The >=5x headline mechanism: under fastforward with all-trace
    cursors the run monitor must actually collapse periodic spans (tree
    converges after a few iterations), and diagnostics must say so."""
    fb = prep_barrier_bench("tree", 8, sfr=0, iters=64, compiled=True)
    ref = prep_barrier_bench("tree", 8, sfr=0, iters=64).run_sequential()
    got = fb.run_sequential()
    assert got.stats == ref.stats
    cl = fb.config.cluster
    assert cl.trace_jumps >= 1
    assert 0 < cl.trace_jump_cycles < got.stats.cycles
