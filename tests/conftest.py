"""Test env: a handful of host devices for the distributed-path tests.

NOTE: this deliberately requests 4 (not 512) devices -- the 512-device
production mesh exists only inside ``repro.launch.dryrun`` (per assignment).
"""

import importlib.util
import os
import sys
from pathlib import Path

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

# repo root on sys.path so `import benchmarks` works under pytest
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# Offline fallback: when the real hypothesis isn't installed (this container
# cannot pip install), alias the deterministic shim in before collection so
# `from hypothesis import given, settings` in the test modules keeps working.
if importlib.util.find_spec("hypothesis") is None:
    from tests import _hypothesis_compat

    sys.modules["hypothesis"] = _hypothesis_compat
    sys.modules["hypothesis.strategies"] = _hypothesis_compat.strategies
