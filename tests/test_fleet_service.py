"""Tests for the continuous-batching sweep service and slot fleet engine.

The load-bearing property is the tentpole guarantee: every job's
``ClusterStats`` is **bit-exact** against a sequential ``Cluster.run()`` of
the same config, no matter when it was admitted or what shared a batched
step with it -- including admissions landing mid-quiescent-span of a
co-resident slot, staggered random arrivals, slot recycling and a
co-resident job timing out.
"""

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scu import SCU, Cluster, Compute, Scu
from repro.core.scu.energy import DEFAULT_ENERGY, Activity
from repro.core.scu.engine import FleetConfig, SlotFleet
from repro.core.scu.programs import (
    prep_barrier_bench,
    prep_chain_bench,
    prep_mutex_bench,
    prep_work_queue_bench,
)
from repro.serve.arrivals import bursty_trace, poisson_trace
from repro.serve.energy import job_energy
from repro.core.scu.faults import FaultEvent, FaultPlan, Watchdog
from repro.serve.fleet_pool import BreakerPolicy, DomainHealth, FleetPool
from repro.serve.fleet_service import FleetService, QueueFull, RetryPolicy

POLICIES = ("scu", "tas", "sw", "tree", "tree4", "tree_ew", "fifo")


def make_cluster(n, mode="fastforward"):
    return Cluster(n_cores=n, scu=SCU(n_cores=n), mode=mode)


def _random_stream_benches(seed):
    """A mixed job stream: policies x 8/16/64 cores x several shapes and
    iteration counts, deterministic in ``seed`` (same recipe as the static
    fleet parity suite, sized for a serving stream)."""
    rng = random.Random(seed)
    benches = []
    for _ in range(rng.randint(6, 10)):
        policy = rng.choice(POLICIES)
        n = rng.choice((8, 8, 8, 16, 64))
        shape = rng.choice(("barrier", "mutex", "chain", "wq")) if n <= 16 \
            else "barrier"
        iters = rng.randint(2, 8)
        if shape == "barrier":
            benches.append(prep_barrier_bench(
                policy, n, sfr=rng.choice((0, 13, 100, 900)), iters=iters
            ))
        elif shape == "mutex":
            benches.append(prep_mutex_bench(
                policy, n, t_crit=rng.randint(0, 12),
                sfr=rng.choice((0, 37)), iters=iters,
            ))
        elif shape == "chain":
            benches.append(prep_chain_bench(
                policy, n, sfr=rng.choice((20, 150)), iters=iters,
                depth=rng.choice((1, 4, 8)),
            ))
        else:
            benches.append(prep_work_queue_bench(
                policy, n // 2, n - n // 2, items=2 * n,
                t_produce=rng.randint(1, 40), t_consume=rng.randint(1, 40),
            ))
    return benches


def _serve_stream(svc, benches, arrivals, max_rounds=5_000_000):
    """Drive a service: submit bench i when the round clock passes its
    arrival, step until everything drains.  Returns jobs in submit order."""
    jobs = [None] * len(benches)
    i = 0
    rounds = 0
    while i < len(benches) or svc.pending or svc.fleet.occupied:
        while i < len(benches) and arrivals[i] <= svc.round:
            jobs[i] = svc.submit(benches[i].config)
            i += 1
        svc.step()
        rounds += 1
        assert rounds < max_rounds, "service failed to drain"
    return jobs


# ---------------------------------------------------------------------------
# Tentpole: bit-exact parity under streamed admission
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_streamed_jobs_match_sequential_bit_exact(seed):
    """Randomized mixed-config stream with staggered Poisson arrivals:
    every job's ClusterStats must be identical to a sequential run of the
    same config -- the service's core contract."""
    seq = [b.run_sequential() for b in _random_stream_benches(seed)]
    benches = _random_stream_benches(seed)
    arrivals = poisson_trace(rate=0.005, n_jobs=len(benches), seed=seed)
    svc = FleetService(n_slots=3, slot_cores=64, queue_limit=64)
    jobs = _serve_stream(svc, benches, arrivals)
    for job, b, ref in zip(jobs, benches, seq):
        assert job.error is None
        assert b.finalize(job.stats) == ref, (
            f"stream diverged (seed={seed}): {ref.variant}/{ref.primitive}"
            f"@{ref.n_cores}"
        )
        assert job.latency_rounds >= 1
        assert job.queue_rounds >= 0


def test_admission_mid_quiescent_span_of_co_resident_slot():
    """Adversarial timing: slot 0 runs an all-cores-asleep long compute
    span; a FIFO churner is admitted while that span is in flight (and
    vice versa, a sleeper admitted mid-churn).  Both must stay bit-exact,
    and the sleeper's span must still be covered by fast-forward jumps."""

    def sleeper_cfg(span=50_000):
        from repro.core.scu.primitives import scu_barrier

        cl = make_cluster(8)

        def prog(cluster, cid):
            yield Compute(span)
            yield from scu_barrier(cluster, cid)

        return FleetConfig(cluster=cl, programs=[prog] * 8)

    def churner_cfg(items=200):
        cl = make_cluster(8)

        def producer(cluster, cid):
            for v in range(items):
                yield Compute(3)
                yield Scu("elw", ("fifo", 1, "push_wait"), v % 256)

        def consumer(cluster, cid):
            for _ in range(items):
                yield Scu("elw", ("fifo", 1, "pop"))

        def idle(cluster, cid):
            yield Compute(1)

        return FleetConfig(cluster=cl, programs=[producer, consumer] + [idle] * 6)

    ref = []
    for mk in (sleeper_cfg, churner_cfg):
        cfg = mk()
        cfg.cluster.load(cfg.programs)
        ref.append(cfg.cluster.run())

    for first, second, ref_first, ref_second in (
        (sleeper_cfg, churner_cfg, ref[0], ref[1]),
        (churner_cfg, sleeper_cfg, ref[1], ref[0]),
    ):
        fleet = SlotFleet(n_slots=2, slot_cores=8)
        cfg_a = first()
        slot_a = fleet.admit(cfg_a)
        # one round: A's generators advance and latch their countdowns --
        # the admission below lands mid-quiescent-span, before A's jump
        assert not fleet.advance()
        cfg_b = second()
        slot_b = fleet.admit(cfg_b)
        done = {}
        rounds = 0
        while fleet.occupied:
            for m in fleet.advance():
                done[m.index] = m.cluster.stats
                fleet.free(m.index)
            rounds += 1
            assert rounds < 10**6
        assert done[slot_a] == ref_first
        assert done[slot_b] == ref_second
        if first is sleeper_cfg:
            assert cfg_a.cluster.ff_cycles > 0.9 * ref_first.cycles, (
                "sleeper degraded to stepping while sharing the fleet"
            )


def test_slot_recycling_preserves_parity():
    """A slot that hosted a dirty job (FIFO traffic, latched elw waits)
    must be indistinguishable from a fresh one for its next occupant."""
    ref = prep_barrier_bench("scu", 8, sfr=10, iters=3).run_sequential()

    fleet = SlotFleet(n_slots=1, slot_cores=16)
    results = []
    for policy in ("tas", "scu", "fifo", "scu"):
        b = prep_barrier_bench(policy, 8, sfr=10, iters=3)
        slot = fleet.admit(b.config)
        assert slot == 0  # single slot, recycled every time
        rounds = 0
        while fleet.occupied:
            for m in fleet.advance():
                results.append((policy, b.finalize(m.cluster.stats)))
                fleet.free(m.index)
            rounds += 1
            assert rounds < 10**6
    for policy, res in results:
        if policy == "scu":
            assert res == ref, "recycled slot diverged from fresh run"


def test_timeout_contained_to_one_slot():
    """A deadlocked job must burn to its cap and fail alone -- with the
    exact message the sequential engine raises -- while a co-resident job
    finishes untouched; the failed slot must be recyclable."""
    def sleeper(cluster, cid):
        yield Scu("elw", ("notifier", 5, "wait"))

    def finisher(cluster, cid):
        yield Compute(3)

    dead = FleetConfig(
        cluster=make_cluster(2), programs=[sleeper, finisher], max_cycles=4096
    )
    # sequential reference failure
    seq = make_cluster(2)
    seq.load([sleeper, finisher])
    with pytest.raises(RuntimeError, match="did not finish") as exc:
        seq.run(max_cycles=4096)

    ok_bench = prep_barrier_bench("scu", 8, sfr=10, iters=3)
    ok_ref = prep_barrier_bench("scu", 8, sfr=10, iters=3).run_sequential()

    svc = FleetService(n_slots=2, slot_cores=8)
    j_dead = svc.submit(dead)
    j_ok = svc.submit(ok_bench.config)
    svc.run_until_drained()
    assert j_ok.error is None
    assert ok_bench.finalize(j_ok.stats) == ok_ref
    assert j_dead.failed
    assert j_dead.error == str(exc.value)
    assert "SLEEP" in j_dead.error  # deadlock state captured at the cap
    assert dead.cluster.cycle == 4096
    # the poisoned slot must serve the next job cleanly
    b2 = prep_barrier_bench("scu", 8, sfr=10, iters=3)
    j2 = svc.submit(b2.config)
    svc.run_until_drained()
    assert j2.error is None
    assert b2.finalize(j2.stats) == ok_ref


# ---------------------------------------------------------------------------
# Scheduling semantics: FIFO, backpressure, drain baseline, accounting
# ---------------------------------------------------------------------------


def test_jobs_admitted_fifo():
    """With one slot, jobs must be admitted -- and therefore finish -- in
    submission order, whatever their relative lengths."""
    svc = FleetService(n_slots=1, slot_cores=8, queue_limit=16)
    jobs = [
        svc.submit(prep_barrier_bench(p, 8, sfr=s, iters=i).config)
        for p, s, i in (("sw", 400, 6), ("scu", 0, 2), ("tas", 10, 3))
    ]
    done = svc.run_until_drained()
    assert [j.job_id for j in done] == [j.job_id for j in jobs]
    admits = [j.admitted_round for j in jobs]
    assert admits == sorted(admits)
    assert all(
        a.finished_round < b.admitted_round for a, b in zip(jobs, jobs[1:])
    ), "one slot: next job admits only after the previous finished"


def test_backpressure_rejects_deterministically():
    """A full queue must reject with QueueFull (the documented choice) and
    accept again after a slot drains the backlog."""
    svc = FleetService(n_slots=1, slot_cores=8, queue_limit=2)

    def mk():
        return prep_barrier_bench("scu", 8, sfr=0, iters=2).config

    svc.submit(mk())
    svc.submit(mk())
    with pytest.raises(QueueFull, match="queue full"):
        svc.submit(mk())
    assert svc.try_submit(mk()) is None  # non-raising twin, same decision
    assert svc.pending == 2
    svc.run_until_drained()
    assert svc.try_submit(mk()) is not None  # capacity is back


def test_submit_validates_configs_upfront():
    """Inadmissible configs never enter the queue: too-wide jobs, wrong
    engine mode and already-used clusters are rejected at submit()."""
    svc = FleetService(n_slots=2, slot_cores=8)

    with pytest.raises(ValueError, match="slot width"):
        svc.submit(prep_barrier_bench("scu", 16, sfr=0, iters=2).config)

    def prog(cluster, cid):
        yield Compute(1)

    with pytest.raises(ValueError, match="fastforward"):
        svc.submit(FleetConfig(
            cluster=make_cluster(2, mode="lockstep"), programs=[prog] * 2
        ))
    used = make_cluster(2)
    used.load([prog] * 2)
    used.run()
    with pytest.raises(ValueError, match="fresh"):
        svc.submit(FleetConfig(cluster=used, programs=[prog] * 2))
    assert svc.pending == 0


def test_continuous_beats_drain_on_stream():
    """Same stream, same fleet geometry: continuous admission must finish
    no later and waste fewer lane-rounds than the drain baseline -- the
    utilization argument the service exists for."""
    def build():
        return [
            prep_barrier_bench(p, n, sfr=s, iters=i)
            for p, n, s, i in (
                ("sw", 8, 400, 8), ("scu", 8, 0, 2), ("tas", 8, 10, 3),
                ("scu", 16, 0, 2), ("fifo", 8, 10, 4), ("scu", 8, 900, 2),
            )
        ]

    totals = {}
    for mode in ("continuous", "drain"):
        svc = FleetService(
            n_slots=2, slot_cores=16, admission=mode, queue_limit=16
        )
        for b in build():
            svc.submit(b.config)
        svc.run_until_drained()
        totals[mode] = (svc.round, svc.idle_lane_fraction)
    assert totals["continuous"][0] <= totals["drain"][0]
    assert totals["continuous"][1] < totals["drain"][1]


def test_latency_accounting_spans_queue_and_service():
    """latency = queue wait + service rounds (inclusive); the second job on
    a single-slot fleet must carry the first job's service time as queue
    rounds."""
    svc = FleetService(n_slots=1, slot_cores=8)
    a = svc.submit(prep_barrier_bench("scu", 8, sfr=100, iters=4).config)
    b = svc.submit(prep_barrier_bench("scu", 8, sfr=0, iters=2).config)
    svc.run_until_drained()
    assert a.queue_rounds == 0 and a.admitted_round == 0
    assert b.queue_rounds == a.finished_round + 1 - b.submitted_round
    for j in (a, b):
        assert j.latency_rounds == j.finished_round - j.submitted_round + 1


def test_slot_fleet_rejects_misuse():
    fleet = SlotFleet(n_slots=1, slot_cores=8)
    with pytest.raises(ValueError, match="at least one slot"):
        SlotFleet(n_slots=0, slot_cores=8)
    with pytest.raises(ValueError, match="already free"):
        fleet.free(0)
    b = prep_barrier_bench("scu", 8, sfr=0, iters=2)
    fleet.admit(b.config)
    with pytest.raises(ValueError, match="still running"):
        fleet.free(0)
    with pytest.raises(RuntimeError, match="no free slot"):
        fleet.admit(prep_barrier_bench("scu", 8, sfr=0, iters=2).config)


# ---------------------------------------------------------------------------
# Recovery: retry with backoff, degradation, terminal failures
# ---------------------------------------------------------------------------


def _lost_wake_plan(victim=3):
    # lose the barrier wake (EV.BARRIER = line 8) on one core: the whole
    # barrier deadlocks and the job burns to its cycle cap
    return FaultPlan([FaultEvent("lost_wake", cycle=10, core=victim,
                                 lines=1 << 8)])


def _transient_factory(attempt):
    """Faulty on attempt 1, clean after -- the retryable failure."""
    fb = prep_barrier_bench("scu", 8, sfr=20, iters=6)
    fb.config.max_cycles = 4096
    if attempt == 1:
        fb.config.cluster.faults = _lost_wake_plan()
    return fb.config


def _persistent_factory(attempt):
    """Every scu attempt loses the wake -- only degradation can help."""
    fb = prep_barrier_bench("scu", 8, sfr=20, iters=6)
    fb.config.max_cycles = 4096
    fb.config.cluster.faults = _lost_wake_plan()
    return fb.config


def _sw_fallback(attempt):
    fb = prep_barrier_bench("sw", 8, sfr=20, iters=6)
    return fb.config


def test_run_until_drained_terminates_on_permanent_failures():
    """Regression (the satellite fix): a queue holding only jobs that fail
    terminally must drain -- failed jobs leave the system instead of
    spinning the loop to max_rounds."""
    svc = FleetService(n_slots=1, slot_cores=8,
                       retry=RetryPolicy(max_attempts=2, backoff_rounds=1))
    jobs = [svc.submit(factory=_persistent_factory) for _ in range(3)]
    done = svc.run_until_drained(max_rounds=200_000)
    assert len(done) == 3
    assert all(j.state == "failed" and j.failed for j in jobs)
    assert all(j.attempts == 2 and len(j.fault_log) == 2 for j in jobs)
    assert all(j.finished_round is not None for j in jobs)
    assert not svc.queue and not svc._backoff and not svc.fleet.occupied


def test_retry_recovers_transient_fault():
    svc = FleetService(n_slots=2, slot_cores=8,
                       retry=RetryPolicy(max_attempts=3))
    j = svc.submit(factory=_transient_factory)
    svc.run_until_drained()
    assert j.state == "done" and j.error is None
    assert j.attempts == 2 and j.degraded is False
    assert len(j.fault_log) == 1
    log = j.fault_log[0]
    assert log["attempt"] == 1 and log["cycles"] == 4096
    assert "did not finish" in log["error"]
    assert j.wasted_cycles == 4096  # exactly the failed attempt's burn
    assert j.stats is not None


def test_no_retry_policy_keeps_fail_fast():
    svc = FleetService(n_slots=1, slot_cores=8)
    j = svc.submit(factory=_persistent_factory)
    svc.run_until_drained()
    assert j.state == "failed" and j.attempts == 1
    assert j.error is not None and "did not finish" in j.error


def test_retry_relowers_trace_configs():
    """Regression (PR-8 satellite): ``TraceProgram``s are single-use
    (cursor semantics mirror ``FaultPlan``), and a retry factory commonly
    rebuilds only the cluster while reusing the lowered traces -- lowering
    is the expensive part.  The service must hand every attempt fresh
    cursors instead of letting attempt 2 crash on the consumed programs."""
    fb = prep_barrier_bench("tas", 8, sfr=20, iters=6, compiled=True)
    traced = fb.config.programs
    assert all(getattr(p, "is_traced", False) for p in traced)
    ref = prep_barrier_bench("tas", 8, sfr=20, iters=6).run_sequential().stats

    def factory(attempt):
        fresh = prep_barrier_bench("tas", 8, sfr=20, iters=6)
        # attempt 1 is capped far below the real runtime, so it fails and
        # forces a retry over the *same* trace objects
        cap = 64 if attempt == 1 else 4_000_000
        return FleetConfig(
            cluster=fresh.config.cluster, programs=traced, max_cycles=cap
        )

    svc = FleetService(
        n_slots=2, slot_cores=8, retry=RetryPolicy(max_attempts=3)
    )
    j = svc.submit(factory=factory)
    svc.run_until_drained()
    assert j.state == "done" and j.error is None
    assert j.attempts == 2
    assert j.stats == ref  # retried attempt is still bit-exact


def test_backoff_grows_exponentially():
    """With backoff_rounds=2, factor=3 the re-queue delays are 2 then 6
    rounds: the gap between consecutive failures must grow while the
    per-attempt service time stays constant."""
    svc = FleetService(
        n_slots=1, slot_cores=8,
        retry=RetryPolicy(max_attempts=3, backoff_rounds=2, backoff_factor=3),
    )
    j = svc.submit(factory=_persistent_factory)
    svc.run_until_drained()
    assert j.attempts == 3 and j.state == "failed"
    r1, r2, r3 = (e["round"] for e in j.fault_log)
    assert r2 - r1 >= 1 + 2  # backoff + re-service
    assert (r3 - r2) - (r2 - r1) == 4  # delay grew 2 -> 6
    assert all(e["degraded"] is False for e in j.fault_log)


def test_degrade_to_fallback_policy():
    svc = FleetService(
        n_slots=2, slot_cores=8,
        retry=RetryPolicy(max_attempts=3, degrade_after=1),
    )
    j = svc.submit(factory=_persistent_factory, fallback_factory=_sw_fallback)
    svc.run_until_drained()
    assert j.state == "done" and j.degraded is True
    assert j.attempts == 2 and j.error is None
    # the successful attempt ran the sw fallback: stats match a clean sw run
    ref = prep_barrier_bench("sw", 8, sfr=20, iters=6).run_sequential()
    assert j.stats == ref.stats


def test_degrade_without_fallback_exhausts_attempts():
    svc = FleetService(
        n_slots=1, slot_cores=8,
        retry=RetryPolicy(max_attempts=3, degrade_after=1),
    )
    j = svc.submit(factory=_persistent_factory)  # no fallback given
    svc.run_until_drained()
    assert j.state == "failed" and j.attempts == 3 and j.degraded is False


def test_submit_requires_config_xor_factory():
    svc = FleetService(n_slots=1, slot_cores=8)
    with pytest.raises(ValueError, match="exactly one"):
        svc.submit()
    with pytest.raises(ValueError, match="exactly one"):
        svc.submit(prep_barrier_bench("scu", 8, iters=2).config,
                   factory=_transient_factory)


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="backoff_rounds"):
        RetryPolicy(backoff_rounds=-1)
    with pytest.raises(ValueError, match="backoff_factor"):
        RetryPolicy(backoff_factor=0)
    with pytest.raises(ValueError, match="degrade_after"):
        RetryPolicy(degrade_after=0)


def test_backoff_requeue_bypasses_queue_bound():
    """Satellite contract: a retry re-queue never competes with fresh
    submissions for queue space -- it lands even when the queue is at its
    bound (where try_submit is already rejecting)."""
    svc = FleetService(
        n_slots=1, slot_cores=8, queue_limit=1,
        retry=RetryPolicy(max_attempts=2, backoff_rounds=3),
    )
    j = svc.submit(factory=_persistent_factory)
    # run until the first failure puts the job into backoff
    rounds = 0
    while j.state != "backoff":
        svc.step()
        rounds += 1
        assert rounds < 200_000
    # fill the queue to its bound while the retry waits out the backoff
    filler = svc.submit(prep_barrier_bench("scu", 8, sfr=0, iters=2).config)
    assert svc.try_submit(
        prep_barrier_bench("scu", 8, sfr=0, iters=2).config
    ) is None, "the bound must reject fresh submissions"
    while j.state == "backoff":
        svc.step()
        assert len(svc.queue) <= svc.queue_limit + 1
    assert j.state in ("queued", "running", "failed"), \
        "the requeue must have bypassed the full queue"
    svc.run_until_drained()
    assert filler.state == "done"
    assert j.state == "failed" and j.attempts == 2


def test_retry_config_leaves_clean_traffic_untouched():
    """The recovery machinery must be invisible to jobs that never fail:
    same stream, with and without a RetryPolicy, identical outcomes."""
    def run(retry):
        svc = FleetService(n_slots=2, slot_cores=16, retry=retry,
                           queue_limit=16)
        benches = [
            prep_barrier_bench(p, n, sfr=s, iters=i)
            for p, n, s, i in (
                ("scu", 8, 0, 3), ("tas", 8, 40, 3), ("scu", 16, 10, 2),
                ("fifo", 8, 25, 4),
            )
        ]
        jobs = [svc.submit(b.config) for b in benches]
        svc.run_until_drained()
        return [(j.state, j.attempts, j.stats, j.finished_round)
                for j in jobs], svc.round

    plain, rounds_plain = run(None)
    with_retry, rounds_retry = run(RetryPolicy(max_attempts=3))
    assert plain == with_retry and rounds_plain == rounds_retry
    assert all(state == "done" and attempts == 1
               for state, attempts, _, _ in plain)


# ---------------------------------------------------------------------------
# Tenant isolation under faults (slot scrub fuzz)
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_tenant_isolation_under_fault_chains(seed):
    """Randomized admit/fail/free/admit chains on a recycled slot: however
    the previous tenant died (deadlock, blackout, armed-but-unfired drop
    filters), the next tenant's run is bit-exact against a fresh fleet and
    its SCU base-unit fault state starts scrubbed."""
    rng = random.Random(seed)
    ref = prep_barrier_bench("scu", 8, sfr=10, iters=3).run_sequential()

    fleet = SlotFleet(n_slots=2, slot_cores=8)
    for _ in range(rng.randint(2, 4)):
        # a faulty tenant: random kind, possibly deadlocking
        kind = rng.choice((
            "lost_wake", "stall", "bank_blackout", "spurious",
            "droop", "scu_blackout", "domain_blackout",
        ))
        fb = prep_barrier_bench(
            rng.choice(("scu", "tas", "fifo")), 8,
            sfr=rng.choice((0, 20)), iters=rng.randint(2, 5),
        )
        if kind == "lost_wake":
            # arm drops on several lines; some never fire before death
            fb.config.cluster.faults = FaultPlan([
                FaultEvent("lost_wake", cycle=rng.randrange(5, 50),
                           core=rng.randrange(8), lines=0xFFFFFFFF)
            ])
            fb.config.max_cycles = 2048
        elif kind == "stall":
            fb.config.cluster.faults = FaultPlan([
                FaultEvent("stall", rng.randrange(5, 50),
                           core=rng.randrange(8), span=rng.randrange(1, 60))
            ])
        elif kind == "bank_blackout":
            fb.config.cluster.faults = FaultPlan([
                FaultEvent("bank_blackout", rng.randrange(5, 50),
                           span=rng.randrange(1, 30), banks=(0, 3))
            ])
        elif kind == "droop":
            # correlated domain droop: half the cores stall at one cycle
            fb.config.cluster.faults = FaultPlan([
                FaultEvent("droop", rng.randrange(5, 50),
                           cores=tuple(range(4)), span=rng.randrange(1, 60),
                           domain="dom0")
            ])
        elif kind == "scu_blackout":
            # a window where the dying tenant's SCU neither fires nor
            # grants -- armed state must not leak into the next tenant
            fb.config.cluster.faults = FaultPlan([
                FaultEvent("scu_blackout", rng.randrange(5, 50),
                           span=rng.randrange(1, 40), domain="dom0")
            ])
        elif kind == "domain_blackout":
            # domain-wide bank blackout: every bank of one domain half
            fb.config.cluster.faults = FaultPlan([
                FaultEvent("bank_blackout", rng.randrange(5, 50),
                           span=rng.randrange(1, 30),
                           banks=tuple(range(8)), domain="dom0")
            ])
        else:
            fb.config.cluster.faults = FaultPlan([
                FaultEvent("spurious_wake", rng.randrange(5, 50),
                           core=rng.randrange(8),
                           line=rng.choice((0, 8, 9, 10)))
            ])
            fb.config.max_cycles = 2048
        slot = fleet.admit(fb.config)
        done_first = False
        rounds = 0
        while not done_first:
            for m in fleet.advance():
                done_first = done_first or m.index == slot
                fleet.free(m.index)
            rounds += 1
            assert rounds < 10**6

        # the recycled slot must serve a clean tenant bit-exactly
        b2 = prep_barrier_bench("scu", 8, sfr=10, iters=3)
        s2 = fleet.admit(b2.config)
        assert s2 == slot or fleet.n_slots > 1
        # scrubbed fault state: no armed drops leak across tenants
        scu = b2.config.cluster.scu
        assert not scu.base.drop.any() and not scu.base._drop_armed
        assert scu.base.dropped_events == 0
        rounds = 0
        while fleet.occupied:
            for m in fleet.advance():
                if m.index == s2:
                    assert m.error is None
                    assert b2.finalize(m.cluster.stats) == ref, (
                        f"seed={seed}: recycled slot leaked fault state"
                    )
                fleet.free(m.index)
            rounds += 1
            assert rounds < 10**6


# ---------------------------------------------------------------------------
# FleetPool: fault domains, health-aware routing, quarantine, reroute
# ---------------------------------------------------------------------------


def _clean_factory(attempt):
    fb = prep_barrier_bench("scu", 8, sfr=20, iters=4)
    fb.config.max_cycles = 4096
    return fb.config


def _victim_inject(victims):
    """An inject hook arming a deadlocking lost-wake plan on every config
    admitted to a victim domain -- faults tied to the *domain*, which is
    why rerouting escapes them."""
    def inject(domain, config):
        if domain in victims:
            config.cluster.faults = _lost_wake_plan()
        return config
    return inject


def test_pool_validation():
    with pytest.raises(ValueError, match="n_domains"):
        FleetPool(n_domains=0, n_slots=1, slot_cores=8)
    with pytest.raises(ValueError, match="placement"):
        FleetPool(n_domains=2, n_slots=1, slot_cores=8, placement="random")
    with pytest.raises(ValueError, match="queue_limit"):
        FleetPool(n_domains=2, n_slots=1, slot_cores=8, queue_limit=0)
    with pytest.raises(ValueError, match="probation_after"):
        BreakerPolicy(probation_after=0)
    with pytest.raises(ValueError, match="cooldown_rounds"):
        BreakerPolicy(cooldown_rounds=0)
    with pytest.raises(ValueError, match="probe_successes"):
        BreakerPolicy(probe_successes=0)
    with pytest.raises(ValueError, match="window"):
        DomainHealth(window=0)


def test_pool_placement_is_deterministic():
    """round-robin cycles domains in index order; least-loaded picks the
    emptiest domain with ties to the lower id -- both pure functions of
    the pool state, no randomness anywhere."""
    rr = FleetPool(n_domains=3, n_slots=2, slot_cores=8,
                   placement="round-robin")
    doms = [rr.submit(_clean_factory(1)).domain for _ in range(6)]
    assert doms == [0, 1, 2, 0, 1, 2]

    ll = FleetPool(n_domains=3, n_slots=2, slot_cores=8,
                   placement="least-loaded")
    doms = [ll.submit(_clean_factory(1)).domain for _ in range(6)]
    assert doms == [0, 1, 2, 0, 1, 2]  # load ties break to the lower id


def test_pool_clean_stream_matches_single_fleet_service():
    """With one domain and no faults the pool must be indistinguishable
    from the plain FleetService: same stats, same rounds, same lane
    accounting -- the new layer adds routing, not scheduling drift."""
    def build():
        return [
            prep_barrier_bench(p, 8, sfr=s, iters=i)
            for p, s, i in (
                ("scu", 0, 3), ("tas", 40, 3), ("fifo", 25, 4), ("sw", 10, 2),
            )
        ]

    svc = FleetService(n_slots=2, slot_cores=8, queue_limit=16)
    svc_jobs = [svc.submit(b.config) for b in build()]
    svc.run_until_drained()

    pool = FleetPool(n_domains=1, n_slots=2, slot_cores=8, queue_limit=16)
    pool_jobs = [pool.submit(b.config) for b in build()]
    pool.run_until_drained()

    for a, b in zip(svc_jobs, pool_jobs):
        assert a.stats == b.stats
        assert (a.state, a.admitted_round, a.finished_round) == \
            (b.state, b.admitted_round, b.finished_round)
    assert svc.round == pool.round
    assert svc.idle_lane_fraction == pool.idle_lane_fraction


def test_pool_fifo_fairness_per_domain():
    """Jobs placed on the same domain are admitted in submission order --
    and a rerouted retry joins the *tail* of its new domain's queue, never
    jumping the fresh submissions already waiting there."""
    pool = FleetPool(
        n_domains=2, n_slots=1, slot_cores=8, placement="round-robin",
        retry=RetryPolicy(max_attempts=2, backoff_rounds=0, reroute=True),
        inject=_victim_inject({0}),
    )
    # six jobs alternate 0,1,0,1,0,1; domain-0 jobs fail and reroute to 1
    jobs = [pool.submit(factory=_clean_factory) for _ in range(6)]
    pool.run_until_drained(max_rounds=500_000)
    assert all(j.state == "done" for j in jobs), \
        "every domain-0 casualty must complete after its reroute"
    assert pool.reroutes == 3
    d1_first = [j for j in jobs if j.domain == 1 and j.attempts == 1]
    rerouted = [j for j in jobs if j.attempts == 2]
    assert all(j.domain == 1 for j in rerouted)
    # FIFO per domain: among same-domain admissions, submit order holds,
    # and every fresh domain-1 job was admitted before any rerouted one
    # arrived in that queue
    for bucket in (d1_first, rerouted):
        admits = [j.admitted_round for j in bucket]
        assert admits == sorted(admits)
    assert max(j.admitted_round for j in d1_first) <= \
        min(j.admitted_round for j in rerouted)


def test_reroute_completes_jobs_inplace_retry_loses():
    """The tentpole serve claim, in miniature: a domain-pinned fault kills
    in-place retries (every attempt lands back in the blast radius) while
    reroute=True completes the same job on a healthy domain."""
    def run(reroute):
        pool = FleetPool(
            n_domains=2, n_slots=1, slot_cores=8,
            retry=RetryPolicy(max_attempts=2, backoff_rounds=1,
                              reroute=reroute),
            inject=_victim_inject({0}),
        )
        job = pool.submit(factory=_clean_factory)
        assert job.domain == 0  # least-loaded tie breaks to the victim
        pool.run_until_drained(max_rounds=500_000)
        return job, pool

    lost, _ = run(reroute=False)
    assert lost.state == "failed" and lost.attempts == 2
    assert all(e["domain"] == 0 for e in lost.fault_log)

    saved, pool = run(reroute=True)
    assert saved.state == "done" and saved.attempts == 2
    assert saved.domain == 1 and pool.reroutes == 1
    assert saved.fault_log[0]["domain"] == 0  # blame names the sick domain


def test_breaker_walks_the_state_machine():
    """healthy -> probation (window failures) -> quarantined (probation
    failure) -> probation (cooldown expiry) -> healthy (probe successes),
    all round-counted and observable."""
    sick = {"on": True}

    def inject(domain, config):
        if sick["on"]:
            config.cluster.faults = _lost_wake_plan()
        return config

    breaker = BreakerPolicy(probation_after=2, cooldown_rounds=4,
                            probe_successes=2)
    pool = FleetPool(
        n_domains=1, n_slots=2, slot_cores=8, breaker=breaker,
        retry=RetryPolicy(max_attempts=1), inject=inject,
    )
    # two failures in the window drop the domain to probation
    for _ in range(2):
        pool.submit(factory=_clean_factory)
    pool.run_until_drained(max_rounds=500_000)
    assert pool.states[0] == "probation"
    # a probation (probe) failure quarantines with a round-counted cooldown
    pool.submit(factory=_clean_factory)
    pool.run_until_drained(max_rounds=500_000)
    assert pool.states[0] == "quarantined"
    assert pool.quarantines == 1
    until = pool._cooldown_until[0]
    # a job queued against the quarantined domain waits out the cooldown
    sick["on"] = False
    j = pool.submit(factory=_clean_factory)
    pool.run_until_drained(max_rounds=500_000)
    assert j.state == "done"
    assert j.admitted_round >= until, "no admission before cooldown expiry"
    assert pool.states[0] == "probation"  # one success < probe_successes
    pool.submit(factory=_clean_factory)
    pool.run_until_drained(max_rounds=500_000)
    assert pool.states[0] == "healthy"  # second consecutive probe success


def test_quarantine_cuts_wasted_cycles_vs_reroute_alone():
    """With a stream arriving over rounds, reroute alone keeps feeding the
    victim domain (every placement there burns a full failed attempt);
    the breaker stops the bleeding after it trips -- strictly fewer wasted
    cycles, same 100% completion."""
    def run(breaker):
        pool = FleetPool(
            n_domains=2, n_slots=1, slot_cores=8,
            retry=RetryPolicy(max_attempts=3, backoff_rounds=0, reroute=True),
            breaker=breaker, inject=_victim_inject({0}),
        )
        # initial burst: least-loaded alternates 0,1,0,1 so the victim
        # domain holds a queued job when its first admission fails -- that
        # job becomes the probation probe whose failure quarantines
        jobs = [pool.submit(factory=_clean_factory) for _ in range(4)]
        for _ in range(2):
            for _ in range(40):  # stagger the tail across rounds
                pool.step()
            jobs.append(pool.submit(factory=_clean_factory))
        pool.run_until_drained(max_rounds=500_000)
        return jobs, pool

    jobs_r, pool_r = run(None)
    jobs_q, pool_q = run(BreakerPolicy(probation_after=1, cooldown_rounds=50,
                                       probe_successes=1))
    assert all(j.state == "done" for j in jobs_r)
    assert all(j.state == "done" for j in jobs_q)
    assert pool_q.quarantines >= 1
    assert pool_q.wasted_cycles < pool_r.wasted_cycles, (
        "quarantine must stop feeding the victim domain"
    )


def test_watchdog_trip_escalates_to_domain_blame():
    """The escalation chain: a slot-level watchdog trip surfaces as the
    member's DeadlockError, lands in the job's fault_log with domain blame
    and the WaitForGraph dump, counts on the domain's health record, and
    the breaker quarantines the domain."""
    def factory(attempt):
        fb = prep_barrier_bench("scu", 8, sfr=20, iters=6)
        fb.config.cluster.faults = _lost_wake_plan()
        fb.config.cluster.scu.watchdog = Watchdog(timeout=150, mode="raise")
        return fb.config

    pool = FleetPool(
        n_domains=2, n_slots=1, slot_cores=8,
        breaker=BreakerPolicy(probation_after=1, cooldown_rounds=10,
                              probe_successes=1),
        retry=RetryPolicy(max_attempts=1),
        inject=None,
    )
    j = pool.submit(factory=factory)
    d = j.domain
    pool.run_until_drained(max_rounds=500_000)
    assert j.state == "failed"
    assert "watchdog tripped" in j.error and "wait-for graph" in j.error
    entry = j.fault_log[0]
    assert entry["domain"] == d and entry["watchdog"] is True
    assert pool.health[d].watchdog_trips == 1
    assert pool.watchdog_trips == 1
    assert pool.states[d] == "probation", \
        "one window failure (probation_after=1) must demote the domain"
    report = pool.domain_report()
    assert report[d]["watchdog_trips"] == 1
    assert report[d]["state"] == "probation"


def test_pool_backpressure_and_requeue_bypass():
    """The global queue bound rejects fresh submissions (QueueFull /
    try_submit None) but a retry requeue bypasses it -- the pool-level
    twin of the FleetService satellite contract."""
    pool = FleetPool(
        n_domains=2, n_slots=1, slot_cores=8, queue_limit=2,
        retry=RetryPolicy(max_attempts=2, backoff_rounds=3, reroute=True),
        inject=_victim_inject({0}),
    )
    j = pool.submit(factory=_clean_factory)
    assert j.domain == 0
    rounds = 0
    while j.state != "backoff":
        pool.step()
        rounds += 1
        assert rounds < 200_000
    fillers = [
        pool.submit(prep_barrier_bench("scu", 8, sfr=0, iters=2).config)
        for _ in range(2)
    ]
    with pytest.raises(QueueFull, match="pool queue full"):
        pool.submit(prep_barrier_bench("scu", 8, sfr=0, iters=2).config)
    assert pool.try_submit(
        prep_barrier_bench("scu", 8, sfr=0, iters=2).config
    ) is None
    pool.run_until_drained(max_rounds=500_000)
    assert j.state == "done" and j.domain == 1  # requeued + rerouted
    assert all(f.state == "done" for f in fillers)


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------


def test_arrival_traces_deterministic_and_well_formed():
    for trace in (
        poisson_trace(0.05, 40, seed=7),
        bursty_trace(4, 10, gap_rounds=500, seed=7, jitter=20),
    ):
        assert len(trace) == 40
        assert all(isinstance(t, int) for t in trace)
        assert trace == sorted(trace), "arrivals must be non-decreasing"
        assert trace[0] >= 0
    assert poisson_trace(0.05, 40, seed=7) == poisson_trace(0.05, 40, seed=7)
    assert poisson_trace(0.05, 40, seed=8) != poisson_trace(0.05, 40, seed=7)
    assert bursty_trace(4, 10, 500, seed=7, jitter=20) == \
        bursty_trace(4, 10, 500, seed=7, jitter=20)
    # a zero-jitter burst is a same-round batch at each gap multiple
    assert bursty_trace(3, 2, 100, seed=0) == [0, 0, 100, 100, 200, 200]
    with pytest.raises(ValueError, match="rate"):
        poisson_trace(0.0, 4, seed=0)
    with pytest.raises(ValueError, match="gap_rounds"):
        bursty_trace(2, 2, -1, seed=0)


# ---------------------------------------------------------------------------
# Per-job energy split
# ---------------------------------------------------------------------------


def test_job_energy_components_sum_exactly():
    """The idle/spin/compute/static split is a regrouping of the calibrated
    model: components must sum to EnergyModel.energy_pj exactly."""
    st_ = prep_barrier_bench("tas", 8, sfr=10, iters=4).run_sequential().stats
    e = job_energy(st_)
    total = DEFAULT_ENERGY.energy_pj(Activity.from_stats(st_))
    assert e.total_pj == pytest.approx(total, abs=1e-9)
    assert e.wait_pj == pytest.approx(e.idle_pj + e.spin_pj, abs=1e-9)


def test_job_energy_separates_disciplines():
    """The whole point of the split: SCU mutex losers sleep clock-gated
    (idle energy), TAS losers hammer the TCDM (spin energy)."""
    scu_st = prep_mutex_bench(
        "scu", 8, t_crit=12, iters=8
    ).run_sequential().stats
    tas_st = prep_mutex_bench(
        "tas", 8, t_crit=12, iters=8
    ).run_sequential().stats
    e_scu = job_energy(scu_st)
    e_tas = job_energy(tas_st)
    assert e_scu.idle_pj > 0
    assert e_tas.spin_pj > e_scu.spin_pj
    assert e_scu.idle_pj > e_tas.idle_pj
