"""Fault injection, watchdog recovery and structured deadlock diagnostics.

The load-bearing property mirrors the engine's core contract: a
fault-injected run must stay **bit-exact** between the ``lockstep``
reference and every ``fastforward`` tier -- including runs that deadlock
(same timeout cycle, same wait-for dump) -- because the
:class:`FaultPlan` bound is minned into every fast-forward jump.  On top
of that: the one-shot lost-wake drop filter, watchdog release/trip
semantics, the structured :class:`SimTimeout`/:class:`DeadlockError`
diagnostics, and fault parity through both fleet engines.
"""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scu import SCU, Cluster, Compute, Scu
from repro.core.scu.engine import SlotFleet, simulate_fleet
from repro.core.scu.extensions import EventFifo
from repro.core.scu.faults import (
    ALL_LINES,
    DOMAIN_KINDS,
    FAULT_KINDS,
    DeadlockError,
    FaultEvent,
    FaultPlan,
    SimTimeout,
    Watchdog,
    build_wait_graph,
)
from repro.core.scu.programs import (
    prep_barrier_bench,
    prep_chain_bench,
    prep_mutex_bench,
)
from repro.core.scu.scu_unit import BaseUnits

# fault kinds that cannot deadlock a well-formed program (a lost or
# spurious wake can -- e.g. a swallowed barrier edge or a stale mutex
# election -- which is correct behaviour, just not drainable in a static
# fleet that aborts on the first failure).  The domain kinds all qualify:
# droop is a correlated stall, and both blackouts are finite windows that
# defer (never destroy) progress.
SAFE_KINDS = ("stall", "bank_blackout", "droop", "scu_blackout")

_BARRIER_LINE = 8


def _lost_barrier_plan(victim=3, cycle=10):
    return FaultPlan([
        FaultEvent("lost_wake", cycle=cycle, core=victim,
                   lines=1 << _BARRIER_LINE)
    ])


def _run_with_plan(policy, n_cores, mode, plan, sfr=20, iters=6,
                   max_cycles=20_000, watchdog=None):
    """One injected run; returns a comparable outcome tuple for either a
    completion or a timeout (both must match across engine modes)."""
    fb = prep_barrier_bench(policy, n_cores, sfr=sfr, iters=iters, mode=mode)
    cl = fb.config.cluster
    cl.faults = plan.clone() if plan is not None else None
    if watchdog is not None and cl.scu is not None:
        cl.scu.watchdog = Watchdog(**watchdog)
    cl.load(fb.config.programs)
    try:
        cl.run(max_cycles)
        return ("done", cl.stats, cl.faults.applied if cl.faults else [])
    except SimTimeout as e:
        return ("timeout", cl.cycle, str(e))
    except DeadlockError as e:
        return ("deadlock", e.graph.cycle, str(e))


# ---------------------------------------------------------------------------
# FaultPlan: schedule, bounds, cursor
# ---------------------------------------------------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("cosmic_ray", cycle=0, core=0)
    with pytest.raises(ValueError, match="cycle"):
        FaultEvent("stall", cycle=-1, core=0, span=3)
    with pytest.raises(ValueError, match="target core"):
        FaultEvent("lost_wake", cycle=0)
    with pytest.raises(ValueError, match="span"):
        FaultEvent("stall", cycle=0, core=0, span=0)
    with pytest.raises(ValueError, match="bank"):
        FaultEvent("bank_blackout", cycle=0, span=4)
    with pytest.raises(ValueError, match="span"):
        FaultEvent("droop", cycle=0, cores=(0, 1))
    with pytest.raises(ValueError, match="core"):
        FaultEvent("droop", cycle=0, span=3)
    with pytest.raises(ValueError, match="span"):
        FaultEvent("scu_blackout", cycle=0)


def test_next_event_bound_contract():
    """0 on a fault cycle or inside a blackout window, distance to the
    next fault otherwise, None when exhausted -- the exact contract the
    SCU extensions implement."""
    plan = FaultPlan([
        FaultEvent("stall", cycle=5, core=0, span=2),
        FaultEvent("bank_blackout", cycle=10, span=4, banks=(1, 3)),
        FaultEvent("spurious_wake", cycle=20, core=1, line=8),
    ])
    assert plan.next_event_bound(0) == 5
    assert plan.next_event_bound(5) == 0
    assert plan.next_event_bound(6) == 4
    assert plan.next_event_bound(10) == 0
    assert plan.next_event_bound(13) == 0  # inside [10, 14)
    assert plan.next_event_bound(14) == 6
    assert plan.next_event_bound(20) == 0
    assert plan.next_event_bound(21) is None
    assert plan.blacked_banks(9) == frozenset()
    assert plan.blacked_banks(10) == {1, 3}
    assert plan.blacked_banks(13) == {1, 3}
    assert plan.blacked_banks(14) == frozenset()
    assert FaultPlan().next_event_bound(0) is None


def test_next_event_bound_covers_scu_blackout_window():
    """The bound pins to 0 through the whole scu_blackout window -- every
    fast-forward tier must take full steps across it so the gated grants
    stay cycle-addressed."""
    plan = FaultPlan([FaultEvent("scu_blackout", cycle=6, span=5)])
    assert plan.next_event_bound(0) == 6
    for c in range(6, 11):
        assert plan.next_event_bound(c) == 0, f"cycle {c} inside the window"
        assert plan.scu_blacked(c)
    assert plan.next_event_bound(11) is None
    assert not plan.scu_blacked(5) and not plan.scu_blacked(11)
    assert not FaultPlan().scu_blacked(0)


def test_droop_schedules_one_event_for_the_whole_domain():
    """One droop = one plan cycle; the bound contract sees a single event
    and apply() extends every domain core's countdown at that cycle."""
    plan = FaultPlan([FaultEvent("droop", cycle=9, cores=(0, 2, 3), span=7)])
    assert plan.next_event_bound(0) == 9
    assert plan.next_event_bound(9) == 0
    assert plan.next_event_bound(10) is None


def test_plan_repr_round_trips():
    """repr(plan) is an eval-able reproducer (the fault_fuzz mismatch
    printout) carrying every field including domain scoping."""
    plan = FaultPlan.random_domain(
        3, n_cores=8, n_banks=16, horizon=200, n_events=4, n_domains=2
    )
    clone = eval(repr(plan), {"FaultPlan": FaultPlan, "FaultEvent": FaultEvent})
    assert clone.events == plan.events


def test_random_domain_is_seed_deterministic():
    a = FaultPlan.random_domain(11, n_cores=8, n_banks=16, horizon=300)
    b = FaultPlan.random_domain(11, n_cores=8, n_banks=16, horizon=300)
    c = FaultPlan.random_domain(12, n_cores=8, n_banks=16, horizon=300)
    assert a.events == b.events
    assert a.events != c.events
    assert all(e.kind in DOMAIN_KINDS for e in a.events)
    assert all(e.domain for e in a.events)
    for e in a.events:
        if e.kind == "droop":
            assert len(e.cores) == 4  # 8 cores / 2 domains
        if e.kind == "bank_blackout":
            assert len(e.banks) == 8  # 16 banks / 2 domains


def test_plan_is_single_use_and_clone_resets():
    plan = FaultPlan([FaultEvent("stall", cycle=2, core=0, span=3)])
    out = _run_with_plan("scu", 8, "fastforward", plan)
    assert out[0] == "done"
    assert out[2] and out[2][0]["kind"] == "stall"
    fresh = plan.clone()
    assert fresh._next == 0 and fresh.applied == []
    assert fresh.events == plan.events


def test_random_plan_is_seed_deterministic():
    a = FaultPlan.random(7, n_cores=8, n_banks=16, horizon=300)
    b = FaultPlan.random(7, n_cores=8, n_banks=16, horizon=300)
    c = FaultPlan.random(8, n_cores=8, n_banks=16, horizon=300)
    assert a.events == b.events
    assert a.events != c.events
    assert all(e.kind in FAULT_KINDS for e in a.events)


# ---------------------------------------------------------------------------
# Lost-wake drop filter + spurious-wake tolerance (unit level)
# ---------------------------------------------------------------------------


def test_drop_filter_is_one_shot():
    """An armed lost-wake drop eats exactly the next matching delivery on
    the target core, then disarms -- per line, per core."""
    u = BaseUnits(4)
    u.arm_drop(2, 1 << 8)
    delivered = u.deliver(8, 0b1111)
    assert delivered == 3
    assert u.ev_buf[2] == 0 and all(u.ev_buf[c] == 1 << 8 for c in (0, 1, 3))
    assert u.dropped_events == 1
    # disarmed: the same delivery now lands
    assert u.deliver(8, 0b0100) == 1
    assert u.ev_buf[2] == 1 << 8
    # a drop armed on line 8 does not touch other lines
    u.arm_drop(1, 1 << 8)
    assert u.deliver(9, 0b0010) == 1
    assert u.ev_buf[1] & (1 << 9)


def test_drop_filter_via_buffer_set():
    """Extensions that deliver through per-core buffer_set (mutex election,
    FIFO grants) hit the same filter."""
    u = BaseUnits(2)
    u.arm_drop(0, ALL_LINES)
    u[0].buffer_set(9)
    assert u.ev_buf[0] == 0 and u.dropped_events == 1
    u[0].buffer_set(9)
    assert u.ev_buf[0] == 1 << 9


def test_spurious_fifo_grant_returns_zero():
    """A waiter woken by an injected FIFO event (or a watchdog release)
    has no latched message; take_message must hand back 0, not raise."""
    f = EventFifo()
    assert f.take_message(5) == 0
    f.register_popper(1)
    f.push(42)
    f.evaluate(BaseUnits(2))
    assert f.take_message(1) == 42
    assert f.take_message(1) == 0


# ---------------------------------------------------------------------------
# The tentpole: fault-injected runs are bit-exact across engine modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_cores", (8, 16, 64))
@pytest.mark.parametrize("policy", ("scu", "tas", "fifo"))
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_fault_parity_lockstep_vs_fastforward(policy, n_cores, seed):
    """Randomized plans over every fault kind: completions must match
    stat-for-stat, deadlocks must time out at the same cycle with the
    identical wait-for dump."""
    plan = FaultPlan.random(
        seed, n_cores=n_cores, n_banks=2 * n_cores, horizon=400, n_events=4
    )
    ref = _run_with_plan(policy, n_cores, "lockstep", plan, max_cycles=20_000)
    ff = _run_with_plan(policy, n_cores, "fastforward", plan, max_cycles=20_000)
    assert ref == ff, f"seed={seed}: {policy}@{n_cores} diverged"


@pytest.mark.parametrize("kind", FAULT_KINDS)
def test_single_kind_parity(kind):
    """Each fault kind in isolation, on the sleep-heavy SCU barrier (the
    adversarial case for the quiescent-span jump)."""
    if kind == "lost_wake":
        plan = _lost_barrier_plan()
    elif kind == "spurious_wake":
        plan = FaultPlan([FaultEvent("spurious_wake", 40, core=2, line=8)])
    elif kind == "stall":
        plan = FaultPlan([FaultEvent("stall", 15, core=5, span=37)])
    elif kind == "droop":
        plan = FaultPlan([
            FaultEvent("droop", 15, cores=(0, 1, 2, 3), span=37, domain="dom0")
        ])
    elif kind == "scu_blackout":
        plan = FaultPlan([
            FaultEvent("scu_blackout", 20, span=45, domain="dom0")
        ])
    else:
        plan = FaultPlan([FaultEvent("bank_blackout", 8, span=20, banks=(0, 5))])
    ref = _run_with_plan("scu", 8, "lockstep", plan, max_cycles=8_000)
    ff = _run_with_plan("scu", 8, "fastforward", plan, max_cycles=8_000)
    assert ref == ff
    assert ref[0] == "done" or kind in ("lost_wake",)


@pytest.mark.parametrize("n_cores", (8, 16, 64))
@pytest.mark.parametrize("policy", ("scu", "tas", "fifo"))
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_domain_plan_parity_lockstep_vs_fastforward(policy, n_cores, seed):
    """The tentpole acceptance property for the new kinds: randomized
    *domain-scoped* plans (correlated droop, SCU blackout, domain-wide bank
    blackout) stay bit-exact across engine modes."""
    plan = FaultPlan.random_domain(
        seed, n_cores=n_cores, n_banks=2 * n_cores, horizon=400,
        n_events=3, n_domains=2,
    )
    ref = _run_with_plan(policy, n_cores, "lockstep", plan, max_cycles=20_000)
    ff = _run_with_plan(policy, n_cores, "fastforward", plan, max_cycles=20_000)
    assert ref == ff, f"seed={seed}: {policy}@{n_cores} diverged"


def test_scu_blackout_preserves_and_replays_armed_state():
    """During the window nothing fires or grants; the arrivals latched
    inside it replay on the first ungated evaluate, so the run completes --
    just later than the clean run -- and the blame log names the domain."""
    def run(plan):
        fb = prep_barrier_bench("scu", 8, sfr=20, iters=4, mode="fastforward")
        cl = fb.config.cluster
        cl.faults = plan
        cl.load(fb.config.programs)
        stats = cl.run(50_000)
        return cl, stats

    _, clean = run(None)
    blackout = FaultPlan([
        FaultEvent("scu_blackout", cycle=10, span=200, domain="dom0")
    ])
    cl, faulted = run(blackout)
    assert faulted.cycles > clean.cycles, \
        "a blackout across barrier traffic must defer completion"
    assert cl.faults.applied and cl.faults.applied[0]["domain"] == "dom0"


def test_scu_blackout_gates_grants_but_buffers_deliveries():
    """Unit-level window semantics: a notifier delivery during the window
    lands in the buffer but elw_poll refuses to grant until the window
    ends (armed state preserved, grant replayed)."""
    scu = SCU(n_cores=2)
    cl = Cluster(n_cores=2, scu=scu)
    cl.faults = FaultPlan([FaultEvent("scu_blackout", cycle=0, span=50)])
    cl.cycle = 0
    scu.elw_trigger(0, ("barrier", 0, "arrive_wait"))
    scu.elw_trigger(1, ("barrier", 0, "arrive_wait"))
    assert scu.scu_blacked()
    assert scu.evaluate(0) == 0, "comparators must not fire inside the window"
    assert scu.barriers[0].status, "the arrival must stay latched (armed)"
    assert not scu.elw_would_grant(0, ("barrier", 0, "arrive_wait"))
    granted, _ = scu.elw_poll(0, ("barrier", 0, "arrive_wait"))
    assert not granted
    cl.cycle = 50  # first cycle past the window
    assert not scu.scu_blacked()
    assert scu.evaluate(50) > 0, "armed state replays on the ungated evaluate"
    granted, _ = scu.elw_poll(0, ("barrier", 0, "arrive_wait"))
    assert granted


def test_mutex_and_chain_shapes_under_faults():
    for mk in (
        lambda mode: prep_mutex_bench("scu", 8, t_crit=9, iters=5, mode=mode),
        lambda mode: prep_chain_bench("fifo", 8, sfr=30, iters=4, depth=4,
                                      mode=mode),
    ):
        plan = FaultPlan([
            FaultEvent("stall", 12, core=1, span=23),
            FaultEvent("bank_blackout", 30, span=11, banks=(2,)),
        ])
        out = {}
        for mode in ("lockstep", "fastforward"):
            fb = mk(mode)
            cl = fb.config.cluster
            cl.faults = plan.clone()
            cl.load(fb.config.programs)
            cl.run(50_000)
            out[mode] = cl.stats
        assert out["lockstep"] == out["fastforward"]


def test_empty_plan_is_bit_exact_noop():
    """Cluster(faults=FaultPlan()) must reproduce the no-faults run exactly
    -- the property that lets the golden benchmark baseline stand."""
    ref = _run_with_plan("scu", 16, "fastforward", None)
    empty = _run_with_plan("scu", 16, "fastforward", FaultPlan())
    assert ref == empty


# ---------------------------------------------------------------------------
# Structured timeout + wait-for graph
# ---------------------------------------------------------------------------


def test_sim_timeout_keeps_legacy_prefix_and_adds_graph():
    fb = prep_barrier_bench("scu", 8, sfr=20, iters=6)
    cl = fb.config.cluster
    cl.faults = _lost_barrier_plan()
    cl.load(fb.config.programs)
    with pytest.raises(SimTimeout, match="did not finish") as exc:
        cl.run(max_cycles=4096)
    e = exc.value
    assert isinstance(e, DeadlockError) and isinstance(e, RuntimeError)
    msg = str(e)
    assert msg.startswith("cluster did not finish within 4096 cycles")
    assert "wait-for graph at cycle 4096" in msg
    for cid in range(8):
        assert f"core {cid}:" in msg
    assert "lost_wake" in msg  # the blame list names the injected fault
    assert e.graph is not None and e.graph.cycle == 4096
    assert len(e.graph.cores) == 8
    assert any(f["kind"] == "lost_wake" for f in e.graph.faults)


def test_wait_graph_snapshots_comparators():
    cl = Cluster(n_cores=2, scu=SCU(n_cores=2))

    def sleeper(cluster, cid):
        yield Scu("elw", ("barrier", 0, "arrive_wait"))

    def runner(cluster, cid):
        yield Compute(100_000)

    cl.load([sleeper, runner])
    with pytest.raises(SimTimeout):
        cl.run(max_cycles=512)
    g = build_wait_graph(cl)
    assert any("barrier[0]" in s for s in g.comparators)
    assert any("elw pending" in s for s in g.comparators)
    assert g.describe() == build_wait_graph(cl).describe()  # deterministic


# ---------------------------------------------------------------------------
# Watchdog: release recovery, trip escalation, bit-exact timing
# ---------------------------------------------------------------------------


def test_watchdog_release_recovers_lost_wake_bit_exact():
    wd = dict(timeout=150, mode="release")
    ref = _run_with_plan("scu", 8, "lockstep", _lost_barrier_plan(),
                         max_cycles=100_000, watchdog=wd)
    ff = _run_with_plan("scu", 8, "fastforward", _lost_barrier_plan(),
                        max_cycles=100_000, watchdog=wd)
    assert ref == ff
    assert ref[0] == "done", "release-mode watchdog must complete the run"


def test_watchdog_raise_trips_with_graph_same_cycle_both_modes():
    wd = dict(timeout=150, mode="raise")
    out = {}
    for mode in ("lockstep", "fastforward"):
        out[mode] = _run_with_plan("scu", 8, mode, _lost_barrier_plan(),
                                   max_cycles=10**7, watchdog=wd)
    assert out["lockstep"] == out["fastforward"]
    status, cycle, msg = out["fastforward"]
    assert status == "deadlock"
    assert cycle < 10_000, "trip must fire at the deadline, not the cap"
    assert "watchdog tripped" in msg and "wait-for graph" in msg


def test_watchdog_escalates_after_max_releases():
    """A comparator that stays stuck through releases is a hard fault: with
    the release budget exhausted the watchdog trips instead."""
    fb = prep_barrier_bench("scu", 8, sfr=20, iters=6)
    cl = fb.config.cluster
    cl.faults = _lost_barrier_plan()
    cl.scu.watchdog = Watchdog(timeout=150, mode="release", max_releases=0)
    cl.load(fb.config.programs)
    with pytest.raises(DeadlockError, match="watchdog tripped"):
        cl.run(max_cycles=10**6)


def test_watchdog_is_noop_on_healthy_run():
    ref = _run_with_plan("scu", 16, "fastforward", None)
    wd = _run_with_plan("scu", 16, "fastforward", None,
                        watchdog=dict(timeout=5_000, mode="raise"))
    assert ref == wd


def test_watchdog_validation():
    with pytest.raises(ValueError, match="timeout"):
        Watchdog(timeout=0)
    with pytest.raises(ValueError, match="mode"):
        Watchdog(timeout=10, mode="reboot")
    with pytest.raises(ValueError, match="max_releases"):
        Watchdog(timeout=10, max_releases=-1)


# ---------------------------------------------------------------------------
# Fleet engines under faults
# ---------------------------------------------------------------------------


def _prep_faulty(policy, n, seed, mode="fastforward"):
    fb = prep_barrier_bench(policy, n, sfr=25, iters=5, mode=mode)
    fb.config.cluster.faults = FaultPlan.random(
        seed, n_cores=n, n_banks=2 * n, horizon=300, n_events=3,
        kinds=SAFE_KINDS,
    )
    return fb


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_static_fleet_parity_under_faults(seed):
    """simulate_fleet with per-cluster fault plans: every member bit-exact
    against its own sequential run (non-deadlocking kinds -- the static
    fleet aborts the whole batch on a member failure, by design)."""
    shapes = [("scu", 8), ("tas", 8), ("fifo", 8), ("scu", 16), ("scu", 64)]
    seq = []
    for i, (p, n) in enumerate(shapes):
        fb = _prep_faulty(p, n, seed + i)
        fb.config.cluster.load(fb.config.programs)
        seq.append(fb.config.cluster.run(50_000))
    fleet_stats = simulate_fleet(
        [_prep_faulty(p, n, seed + i).config
         for i, (p, n) in enumerate(shapes)]
    )
    assert list(fleet_stats) == seq, f"seed={seed}: fleet diverged"


def test_slot_fleet_contains_fault_deadlock():
    """A fault-deadlocked tenant fails alone with the sequential engine's
    exact message; a co-resident clean job stays bit-exact and the slot
    recycles cleanly."""
    def faulty_cfg():
        fb = prep_barrier_bench("scu", 8, sfr=20, iters=6)
        fb.config.cluster.faults = _lost_barrier_plan()
        fb.config.max_cycles = 4096
        return fb.config

    seq_cfg = faulty_cfg()
    seq_cfg.cluster.load(seq_cfg.programs)
    with pytest.raises(SimTimeout) as exc:
        seq_cfg.cluster.run(4096)

    ok_bench = prep_barrier_bench("scu", 8, sfr=10, iters=3)
    ok_ref = prep_barrier_bench("scu", 8, sfr=10, iters=3).run_sequential()

    fleet = SlotFleet(n_slots=2, slot_cores=8)
    s_bad = fleet.admit(faulty_cfg())
    s_ok = fleet.admit(ok_bench.config)
    errors, stats = {}, {}
    rounds = 0
    while fleet.occupied:
        for m in fleet.advance():
            errors[m.index], stats[m.index] = m.error, m.cluster.stats
            fleet.free(m.index)
        rounds += 1
        assert rounds < 10**6
    assert errors[s_ok] is None
    assert ok_bench.finalize(stats[s_ok]) == ok_ref
    assert errors[s_bad] == str(exc.value)
    assert "lost_wake" in errors[s_bad]
    # the poisoned slot serves the next tenant cleanly
    b2 = prep_barrier_bench("scu", 8, sfr=10, iters=3)
    fleet.admit(b2.config)
    while fleet.occupied:
        for m in fleet.advance():
            assert m.error is None
            assert b2.finalize(m.cluster.stats) == ok_ref
            fleet.free(m.index)


def test_slot_fleet_watchdog_release_matches_sequential():
    """Watchdog-recovered runs stay bit-exact through the batched fleet."""
    def mk():
        fb = prep_barrier_bench("scu", 8, sfr=20, iters=6)
        cl = fb.config.cluster
        cl.faults = _lost_barrier_plan()
        cl.scu.watchdog = Watchdog(timeout=150, mode="release")
        return fb

    seq_fb = mk()
    seq_fb.config.cluster.load(seq_fb.config.programs)
    ref = seq_fb.config.cluster.run(100_000)

    fb = mk()
    fleet = SlotFleet(n_slots=1, slot_cores=8)
    fleet.admit(fb.config)
    rounds = 0
    while fleet.occupied:
        for m in fleet.advance():
            assert m.error is None
            assert m.cluster.stats == ref
            fleet.free(m.index)
        rounds += 1
        assert rounds < 10**6
