"""Deterministic fallback for ``hypothesis`` in offline environments.

The tier-1 suite uses a small subset of hypothesis (``given``/``settings``
with integer / sampled-from / list strategies).  When the real package is
unavailable (this container cannot pip install), ``tests/conftest.py``
installs this module into ``sys.modules['hypothesis']`` *before* collection,
so the test files' ``from hypothesis import given, settings`` imports keep
working unchanged.  When hypothesis IS importable, conftest leaves it alone
and this module is never used.

The fallback draws a fixed number of examples per test from a PRNG seeded
with the test name: deterministic across runs, different across tests, and
it always includes the strategy's boundary examples first (min/max for
integers, first/last for sampled_from) -- a cheap stand-in for hypothesis's
shrinking-toward-simple behaviour.
"""

from __future__ import annotations

import functools
import inspect
import random
from types import SimpleNamespace
from typing import Any, Callable, List

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A deterministic example source mirroring hypothesis's SearchStrategy."""

    def __init__(self, boundary: Callable[[], List[Any]], draw: Callable[[random.Random], Any]):
        self._boundary = boundary
        self._draw = draw

    def boundary_examples(self) -> List[Any]:
        return self._boundary()

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Strategy:
    return _Strategy(
        lambda: [min_value, max_value],
        lambda rng: rng.randint(min_value, max_value),
    )


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(
        lambda: [opts[0], opts[-1]],
        lambda rng: rng.choice(opts),
    )


def booleans() -> _Strategy:
    return sampled_from([False, True])


def floats(min_value: float = 0.0, max_value: float = 1.0, **_: Any) -> _Strategy:
    return _Strategy(
        lambda: [min_value, max_value],
        lambda rng: rng.uniform(min_value, max_value),
    )


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def boundary():
        return [
            [b] * max(min_size, 1) if min_size else []
            for b in elements.boundary_examples()
        ][:2]

    def draw(rng: random.Random):
        size = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(size)]

    return _Strategy(boundary, draw)


def just(value) -> _Strategy:
    return _Strategy(lambda: [value], lambda rng: value)


strategies = SimpleNamespace(
    integers=integers,
    sampled_from=sampled_from,
    booleans=booleans,
    floats=floats,
    lists=lists,
    just=just,
)


def given(**param_strategies: _Strategy):
    """Run the test once per drawn example set (boundary draws first)."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            names = list(param_strategies)
            # boundary pass: every strategy pinned to its simplest extremes
            boundary_sets = []
            for i in range(2):
                drawn = {}
                for n in names:
                    ex = param_strategies[n].boundary_examples()
                    drawn[n] = ex[i % len(ex)]
                boundary_sets.append(drawn)
            random_sets = [
                {n: param_strategies[n].example(rng) for n in names}
                for _ in range(max(0, max_examples - len(boundary_sets)))
            ]
            for drawn in boundary_sets + random_sets:
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:  # noqa: BLE001 - re-raise with context
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): {drawn!r}"
                    ) from e

        wrapper._is_hypothesis_fallback = True
        # hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis does the same); remaining params stay fixtures
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items()
                if name not in param_strategies
            ]
        )
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_: Any):
    """Record ``max_examples`` on the (possibly not-yet-)given-wrapped test.

    Mirrors hypothesis's decorator order tolerance: ``@settings`` may sit
    above or below ``@given``.
    """

    def decorate(fn):
        fn._max_examples = max_examples
        return fn

    return decorate
