"""Checkpoint/restore parity suite.

The crown invariant: a run suspended at a round boundary and restored --
into the same slot, a different slot, a different fleet, or a standalone
cluster in either engine mode -- produces **bit-identical**
``ClusterStats`` to the uninterrupted run, including under active
``FaultPlan``s whose cursor straddles the checkpoint.  Plus the serve
layer built on top: priority admission with aging, preemption,
checkpoint-resume retries, live migration and whole-service suspend/resume.
"""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scu import (
    NotCheckpointable,
    capture_cluster,
    restore_cluster,
)
from repro.core.scu.engine import SlotFleet
from repro.core.scu.faults import FaultEvent, FaultPlan, Watchdog
from repro.core.scu.programs import prep_barrier_bench
from repro.serve.fleet_pool import FleetPool
from repro.serve.fleet_service import (
    CheckpointPolicy,
    FleetService,
    RetryPolicy,
)

POLICIES = ("scu", "tas", "sw", "tree", "tree4", "tree_ew", "fifo")
CORES = (8, 16, 64)

_BARRIER_LINE = 1 << 8


def _bench(policy, n, iters=6, sfr=10, max_cycles=100_000):
    fb = prep_barrier_bench(policy, n, sfr=sfr, iters=iters, compiled=True)
    fb.config.max_cycles = max_cycles
    return fb.config


def _run_fleet(fleet):
    fin = []
    while not fin:
        fin = fleet.advance()
    m = fin[0]
    assert m.error is None, m.error
    return m.cluster.stats


def _reference(policy, n, faults=None, **kw):
    cfg = _bench(policy, n, **kw)
    if faults is not None:
        cfg.cluster.faults = faults
    fl = SlotFleet(2, n)
    fl.admit(cfg)
    return _run_fleet(fl)


def _suspend_at(policy, n, k, faults=None, **kw):
    """Admit, run ``k`` rounds, suspend.  Returns (fleet, ckpt) or
    (fleet, None) when the member finished before round ``k``."""
    cfg = _bench(policy, n, **kw)
    if faults is not None:
        cfg.cluster.faults = faults
    fl = SlotFleet(2, n)
    slot = fl.admit(cfg)
    for _ in range(k):
        if fl.advance():
            return fl, None
    return fl, fl.suspend(slot)


def _mid_plan(n):
    """Non-deadlocking plan whose events straddle any early checkpoint."""
    return FaultPlan([
        FaultEvent("spurious_wake", cycle=9, core=1, line=2),
        FaultEvent("stall", cycle=25, core=0, span=7),
        FaultEvent("bank_blackout", cycle=45, banks=(1,), span=9),
        FaultEvent("droop", cycle=70, cores=(2, 3), span=11, domain="d0"),
        FaultEvent("spurious_wake", cycle=120, core=n - 1, line=5),
    ])


@pytest.mark.parametrize("n", CORES)
@pytest.mark.parametrize("policy", POLICIES)
def test_roundtrip_bit_exact_all_paths(policy, n):
    """Suspend at round k, restore five ways; every path reproduces the
    uninterrupted ClusterStats exactly."""
    iters = 4 if n == 64 else 6
    ref = _reference(policy, n, iters=iters)
    fl, ckpt = _suspend_at(policy, n, k=5, iters=iters)
    assert ckpt is not None, "job finished before the suspension round"
    assert ckpt.cycle > 0

    # same fleet, same (lowest-free) slot
    fl.restore(ckpt, slot=0)
    assert _run_fleet(fl) == ref
    # same fleet, the other slot
    fl.restore(ckpt, slot=1)
    assert _run_fleet(fl) == ref
    # a different fleet entirely
    other = SlotFleet(3, n)
    other.restore(ckpt)
    assert _run_fleet(other) == ref
    # standalone clusters, both engine tiers
    for mode in ("fastforward", "lockstep"):
        cl = restore_cluster(ckpt, mode=mode)
        assert cl.run(ckpt.max_cycles) == ref


@pytest.mark.parametrize("n", (8, 16))
@pytest.mark.parametrize("policy", ("scu", "tas", "tree_ew", "fifo"))
def test_roundtrip_with_fault_cursor_mid_plan(policy, n):
    """The FaultPlan cursor resumes mid-plan: events before the checkpoint
    stay applied, events after it land exactly once."""
    ref = _reference(policy, n, faults=_mid_plan(n))
    for k in (2, 6, 14):
        fl, ckpt = _suspend_at(policy, n, k=k, faults=_mid_plan(n))
        if ckpt is None:
            continue
        assert ckpt.faults is not None
        fl.restore(ckpt)
        assert _run_fleet(fl) == ref
        cl = restore_cluster(ckpt, mode="lockstep")
        assert cl.run(ckpt.max_cycles) == ref


def test_restored_plan_does_not_replay_applied_events():
    """An event already applied before the checkpoint must not re-fire."""
    plan = FaultPlan([FaultEvent("stall", cycle=5, core=0, span=50)])
    fl, ckpt = _suspend_at("scu", 8, k=12, faults=plan)
    assert ckpt is not None
    events, cursor, applied = ckpt.faults
    if ckpt.cycle > 5:
        assert cursor == 1 and len(applied) == 1
    fl.restore(ckpt)
    assert _run_fleet(fl) == _reference("scu", 8, faults=FaultPlan(
        [FaultEvent("stall", cycle=5, core=0, span=50)]))


def test_watchdog_state_carries_across_restore():
    """A release-mode watchdog's progress clock and release budget resume;
    the restored run still recovers from the lost wake exactly."""
    def cfg():
        c = _bench("scu", 8, iters=6)
        c.cluster.faults = FaultPlan([
            FaultEvent("lost_wake", cycle=10, core=2, lines=_BARRIER_LINE)])
        c.cluster.scu.watchdog = Watchdog(200, mode="release")
        return c

    fl = SlotFleet(1, 8)
    fl.admit(cfg())
    ref = _run_fleet(fl)

    fl2 = SlotFleet(1, 8)
    slot = fl2.admit(cfg())
    for _ in range(8):
        assert not fl2.advance()
    ckpt = fl2.suspend(slot)
    assert ckpt.scu.watchdog is not None
    fl2.restore(ckpt)
    assert _run_fleet(fl2) == ref


def test_generator_programs_are_not_checkpointable():
    cfg = prep_barrier_bench("scu", 8, sfr=10, iters=6).config  # not compiled
    fl = SlotFleet(1, 8)
    slot = fl.admit(cfg)
    fl.advance()
    with pytest.raises(NotCheckpointable):
        fl.snapshot(slot)
    # suspend must not evict on failure: the member keeps running
    with pytest.raises(NotCheckpointable):
        fl.suspend(slot)
    assert fl.members[slot] is not None and not fl.members[slot].done
    _run_fleet(fl)  # still completes


def test_snapshot_restore_slot_errors():
    fl = SlotFleet(2, 8)
    with pytest.raises(ValueError):
        fl.snapshot(0)  # free slot
    slot = fl.admit(_bench("scu", 8))
    for _ in range(3):
        fl.advance()
    ckpt = fl.snapshot(slot)
    with pytest.raises(RuntimeError):
        fl.restore(ckpt, slot=slot)  # occupied slot is not free
    fl.restore(ckpt, slot=1)
    with pytest.raises(RuntimeError):
        fl.restore(ckpt)  # no slot free at all


def test_capture_finished_cluster_rejected():
    fl = SlotFleet(1, 8)
    slot = fl.admit(_bench("scu", 8))
    for _ in range(3):
        fl.advance()
    ckpt = fl.snapshot(slot)
    cl = restore_cluster(ckpt, mode="fastforward")
    cl.run(ckpt.max_cycles)
    with pytest.raises(NotCheckpointable):
        capture_cluster(cl)


def test_checkpoint_is_reusable_and_nondestructive():
    """snapshot() leaves the member running; one checkpoint backs many
    restores, each bit-exact."""
    ref = _reference("tree", 8)
    cfg = _bench("tree", 8)
    fl = SlotFleet(1, 8)
    slot = fl.admit(cfg)
    for _ in range(4):
        assert not fl.advance()
    ckpt = fl.snapshot(slot)
    assert _run_fleet(fl) == ref  # original keeps going after snapshot
    for _ in range(3):  # one checkpoint, many restores
        other = SlotFleet(1, 8)
        other.restore(ckpt)
        assert _run_fleet(other) == ref


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    policy=st.sampled_from(POLICIES),
    k=st.integers(min_value=1, max_value=20),
)
def test_recycled_slot_residue_free(seed, policy, k):
    """Property: restoring into a slot previously occupied by an arbitrary
    (even timed-out) tenant is residue-free -- stats match the clean run."""
    import random

    rng = random.Random(seed)
    n = rng.choice((8, 16))
    ref = _reference(policy, n)

    fl = SlotFleet(1, n)
    # dirty the slot: the previous tenant burns to a tight max_cycles cap,
    # leaving lanes mid-SLEEP/STALL with latched events and pending ops
    prev = _bench(rng.choice(POLICIES), n, iters=8,
                  max_cycles=rng.randrange(60, 400))
    slot = fl.admit(prev)
    while True:
        fin = fl.advance()
        if fin:
            assert fin[0].error is not None
            break
    fl.free(slot)

    fl2, ckpt = _suspend_at(policy, n, k=k)
    if ckpt is None:
        return
    fl.restore(ckpt)
    assert _run_fleet(fl) == ref


# --------------------------------------------------------------------------
# serve layer: priority admission, preemption, resume, migration, restart
# --------------------------------------------------------------------------


def _factory(policy="scu", iters=64, n=8, max_cycles=100_000):
    def make(attempt):
        return _bench(policy, n, iters=iters, max_cycles=max_cycles)
    return make


def test_priority_admission_order_and_tiebreak():
    """Higher priority admits first; ties resolve by earlier submission
    then lower job id -- deterministically."""
    svc = FleetService(1, 8, admission_order="priority")
    a = svc.submit(factory=_factory(iters=4), priority=0)
    b = svc.submit(factory=_factory(iters=4), priority=5)
    c = svc.submit(factory=_factory(iters=4), priority=5)
    svc.run_until_drained()
    assert b.admitted_round < c.admitted_round < a.admitted_round


def test_priority_aging_prevents_starvation():
    """With aging, a low-priority job eventually outranks a stream of
    fresh high-priority arrivals; without it, it drains last."""
    def run(aging):
        svc = FleetService(1, 8, admission_order="priority",
                           aging_rounds=aging, queue_limit=256)
        low = svc.submit(factory=_factory(iters=4), priority=0)
        hi_jobs = []
        for i in range(6):
            hi_jobs.append(svc.submit(factory=_factory(iters=4), priority=3))
            for _ in range(4):
                svc.step()
        svc.run_until_drained()
        return low, hi_jobs

    low, hi_jobs = run(aging=None)
    assert all(h.admitted_round < low.admitted_round for h in hi_jobs)
    low, hi_jobs = run(aging=2)
    assert any(h.admitted_round > low.admitted_round for h in hi_jobs)


def test_preemption_high_priority_takes_lane_and_victim_is_bit_exact():
    ref = _reference("scu", 8, iters=64)
    svc = FleetService(1, 8, admission_order="priority", preempt=True)
    low = svc.submit(factory=_factory(iters=64), priority=0)
    for _ in range(6):
        svc.step()
    hi = svc.submit(factory=_factory(iters=8), priority=5)
    svc.run_until_drained()
    assert svc.preemptions == 1 and low.preemptions == 1
    # the high-priority job took the lane the round it arrived
    assert hi.admitted_round == hi.submitted_round
    assert hi.finished_round < low.finished_round
    # the preempted job resumed and its stats are bit-exact
    assert low.state == "done" and low.stats == ref
    assert low.wasted_cycles == 0  # preemption loses zero cycles


def test_preemption_requires_priority_mode():
    with pytest.raises(ValueError):
        FleetService(1, 8, preempt=True)
    with pytest.raises(ValueError):
        FleetService(1, 8, admission_order="sjf")
    with pytest.raises(ValueError):
        CheckpointPolicy(0)


def test_service_checkpoint_resume_saves_cycles():
    """A failed attempt resumes from its last checkpoint: wasted cycles
    stay below one full attempt, and the final stats are bit-exact."""
    ref = _reference("scu", 8, iters=128)

    def factory(attempt):
        cfg = _bench("scu", 8, iters=128, max_cycles=4000)
        if attempt == 1:  # only the first attempt is stalled into timeout
            cfg.cluster.faults = FaultPlan([
                FaultEvent("droop", cycle=2000, cores=tuple(range(8)),
                           span=1_000_000, domain="d0")])
        return cfg

    svc = FleetService(
        1, 8, retry=RetryPolicy(max_attempts=2, backoff_rounds=0),
        checkpoint=CheckpointPolicy(interval_rounds=4),
    )
    job = svc.submit(factory=factory)
    svc.run_until_drained()
    assert job.state == "done"
    assert job.stats == ref
    assert 0 < job.wasted_cycles < 4000  # resume redid only the tail


def test_pool_live_migration_beats_restart_reroute():
    def inject(domain, config):
        if domain == 0:
            config.cluster.faults = FaultPlan([
                FaultEvent("droop", cycle=2000, cores=tuple(range(8)),
                           span=1_000_000, domain="sick")])
        return config

    def run_pool(ckpt):
        pool = FleetPool(
            n_domains=2, n_slots=1, slot_cores=8,
            retry=RetryPolicy(max_attempts=3, backoff_rounds=0, reroute=True),
            inject=inject, checkpoint=ckpt,
        )
        jobs = [pool.submit(factory=_factory(iters=128, max_cycles=4000))
                for _ in range(2)]
        pool.run_until_drained(max_rounds=200_000)
        return pool, jobs

    migrate, jobs_m = run_pool(CheckpointPolicy(4))
    restart, jobs_r = run_pool(None)
    assert all(j.state == "done" for j in jobs_m + jobs_r)
    assert migrate.migrations >= 1
    assert migrate.wasted_cycles < restart.wasted_cycles
    ref = _reference("scu", 8, iters=128)
    for j in jobs_m:
        assert j.stats == ref


def test_service_suspend_all_resumes_bit_exact():
    """Whole-service restart: suspend every member mid-flight, keep
    stepping, and every job's stats match the uninterrupted service."""
    def run(suspend_at):
        svc = FleetService(2, 8, checkpoint=CheckpointPolicy(4))
        jobs = [svc.submit(factory=_factory(iters=64)) for _ in range(3)]
        for _ in range(suspend_at):
            svc.step()
        if suspend_at:
            suspended = svc.suspend_all()
            assert svc.fleet.occupied == 0
            assert all(j.checkpoint is not None for j in suspended)
        svc.run_until_drained()
        return [j.stats for j in jobs]

    assert run(suspend_at=6) == run(suspend_at=0)


def test_pool_suspend_all_resumes_bit_exact():
    def run(suspend_at):
        pool = FleetPool(n_domains=2, n_slots=1, slot_cores=8,
                         checkpoint=CheckpointPolicy(4))
        jobs = [pool.submit(factory=_factory(iters=64)) for _ in range(3)]
        for _ in range(suspend_at):
            pool.step()
        if suspend_at:
            suspended = pool.suspend_all()
            assert all(f.occupied == 0 for f in pool.fleets)
            assert suspended
        pool.run_until_drained()
        return [j.stats for j in jobs]

    assert run(suspend_at=6) == run(suspend_at=0)
