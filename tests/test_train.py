"""Integration tests: training loop, sync strategies, checkpointing, data,
elastic recovery.  Run with XLA_FLAGS=--xla_force_host_platform_device_count=4
(set in tests/conftest.py for this module's worker)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.data import SyntheticLM
from repro.train.elastic import HealthState, plan_recovery, rescale_batch, shrink_mesh
from repro.train.loop import TrainerConfig, train
from repro.train.optimizer import OptConfig
from repro.train.step import TrainConfig

FAST_OPT = OptConfig(lr=1e-2, warmup_steps=5)


def _mesh():
    if jax.device_count() < 4:
        pytest.skip("needs 4 host devices")
    return make_host_mesh(data=2, model=2)


def test_tiny_training_loss_decreases(tmp_path):
    cfg = get_smoke_config("stablelm-3b")
    mesh = _mesh()
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
    tcfg = TrainConfig(remat_policy="none", opt=FAST_OPT)
    trainer = TrainerConfig(steps=30, ckpt_every=1000, log_every=1000)
    _, _, history = train(
        cfg, tcfg, trainer, mesh, lambda i: data.batch(i, batch_size=8)
    )
    first = np.mean([h["loss"] for h in history[:5]])
    last = np.mean([h["loss"] for h in history[-5:]])
    assert last < first - 0.1, f"loss did not decrease: {first:.3f} -> {last:.3f}"


@pytest.mark.parametrize("pair", [("scu", "tas"), ("scu", "sw")])
def test_sync_strategies_numerically_identical(pair):
    """The three disciplines change the schedule, not the math."""
    cfg = get_smoke_config("phi4-mini-3.8b")
    mesh = _mesh()
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, seed=1)

    losses = {}
    for strategy in pair:
        tcfg = TrainConfig(sync_strategy=strategy, remat_policy="none")
        trainer = TrainerConfig(steps=5, ckpt_every=1000, log_every=1000, seed=3)
        _, _, hist = train(
            cfg, tcfg, trainer, mesh, lambda i: data.batch(i, batch_size=4)
        )
        losses[strategy] = [h["loss"] for h in hist]
    a, b = pair
    np.testing.assert_allclose(losses[a], losses[b], rtol=2e-4, atol=2e-4)


def test_grad_accum_matches_full_batch():
    """accum=2 over the same global batch gives (nearly) the same loss path."""
    cfg = get_smoke_config("stablelm-3b")
    mesh = _mesh()
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, seed=2)
    losses = {}
    for accum in (1, 2):
        tcfg = TrainConfig(remat_policy="none", grad_accum=accum)
        trainer = TrainerConfig(steps=4, ckpt_every=1000, log_every=1000, seed=5)
        _, _, hist = train(
            cfg, tcfg, trainer, mesh, lambda i: data.batch(i, batch_size=8)
        )
        losses[accum] = [h["loss"] for h in hist]
    np.testing.assert_allclose(losses[1], losses[2], rtol=1e-3, atol=1e-3)


def test_checkpoint_resume_is_exact(tmp_path):
    """Train 6 steps; vs train 3 + resume 3: identical final loss."""
    cfg = get_smoke_config("codeqwen1.5-7b")
    mesh = _mesh()
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, seed=4)
    tcfg = TrainConfig(remat_policy="none")

    _, _, hist_full = train(
        cfg, tcfg, TrainerConfig(steps=6, ckpt_every=1000, log_every=1000, seed=7),
        mesh, lambda i: data.batch(i, batch_size=4),
    )

    ckpt_dir = str(tmp_path / "ck")
    train(
        cfg, tcfg,
        TrainerConfig(steps=3, ckpt_every=3, ckpt_dir=ckpt_dir, log_every=1000, seed=7),
        mesh, lambda i: data.batch(i, batch_size=4),
    )
    assert latest_step(ckpt_dir) == 3
    _, _, hist_resumed = train(
        cfg, tcfg,
        TrainerConfig(steps=6, ckpt_every=100, ckpt_dir=ckpt_dir, log_every=1000, seed=7),
        mesh, lambda i: data.batch(i, batch_size=4),
    )
    np.testing.assert_allclose(
        [h["loss"] for h in hist_resumed],
        [h["loss"] for h in hist_full[3:]],
        rtol=1e-5, atol=1e-6,
    )


def test_checkpoint_roundtrip_sharded(tmp_path):
    mesh = _mesh()
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = NamedSharding(mesh, P("data", "model"))
    x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8), sh)
    tree = {"a": x, "b": jnp.float32(3.5)}
    save_checkpoint(str(tmp_path), 7, tree)
    restored = restore_checkpoint(
        str(tmp_path), 7, tree, {"a": sh, "b": NamedSharding(mesh, P())}
    )
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(x))
    assert float(restored["b"]) == 3.5


def test_data_pipeline_deterministic_and_sharded():
    d = SyntheticLM(vocab_size=64, seq_len=8, seed=9)
    b1 = d.batch(step=5, batch_size=8)
    b2 = d.batch(step=5, batch_size=8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch(step=6, batch_size=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# Elastic recovery properties
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    failed=st.integers(min_value=0, max_value=200),
    model_parallel=st.sampled_from([4, 8, 16]),
)
def test_shrink_mesh_properties(failed, model_parallel):
    h = HealthState(total_devices=512, failed_devices=list(range(failed)))
    if h.healthy < model_parallel:
        return
    shape, axes = shrink_mesh(h, model_parallel=model_parallel)
    n = int(np.prod(shape))
    assert n <= h.healthy  # never uses dead devices
    assert shape[-1] == model_parallel  # model parallelism preserved
    assert len(shape) == len(axes)


@settings(max_examples=50, deadline=None)
@given(
    new_replicas=st.sampled_from([1, 2, 4, 8, 16, 32]),
)
def test_rescale_batch_preserves_global_batch(new_replicas):
    gb = 256
    per, accum = rescale_batch(gb, old_replicas=32, new_replicas=new_replicas, grad_accum=1)
    assert per * new_replicas == gb
    assert accum >= 1


def test_plan_recovery_smoke():
    h = HealthState(total_devices=512, failed_devices=list(range(48)))
    plan = plan_recovery(h, global_batch=256, old_mesh_shape=(2, 16, 16))
    assert plan["mesh_shape"][-1] == 16
    assert plan["per_replica_batch"] >= 1
