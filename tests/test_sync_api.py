"""The unified ``repro.sync`` policy API: registry, cross-layer parity,
and the tree-barrier extension policy."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_axis_mesh, shard_map
from repro.core.scu import SCU, Cluster, Compute, run_barrier_bench
from repro.kernels.scu_barrier.ops import ref_barrier_count
from repro.sync import (
    LAYER_HOOKS,
    SyncPolicy,
    available_policies,
    canonical_name,
    get_policy,
    make_tree_policy,
    register_policy,
    unregister_policy,
)

BUILTINS = ("scu", "tas", "sw", "tree", "tree4", "tree_ew", "fifo")


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------


def test_builtins_registered_in_order():
    names = available_policies()
    assert names[:3] == ("scu", "tas", "sw")  # the paper's triad first
    for ext in ("tree", "tree4", "tree_ew", "fifo"):  # registered extensions
        assert ext in names


def _dummy_policy(name="dummy"):
    scu = get_policy("scu")
    return dataclasses.replace(scu, name=name, aliases=(name.upper(),))


def test_register_resolve_list_roundtrip():
    policy = _dummy_policy()
    try:
        register_policy(policy)
        assert "dummy" in available_policies()
        assert get_policy("dummy") is policy
        assert get_policy("DUMMY") is policy  # alias + case-insensitivity
        assert canonical_name("Dummy") == "dummy"
    finally:
        unregister_policy("dummy")
    assert "dummy" not in available_policies()


def test_double_registration_rejected():
    policy = _dummy_policy()
    try:
        register_policy(policy)
        with pytest.raises(ValueError, match="already registered"):
            register_policy(policy)
        register_policy(policy, overwrite=True)  # explicit overwrite allowed
    finally:
        unregister_policy("dummy")


def test_alias_cannot_hijack_existing_policy():
    """An alias capturing another policy's name/alias must be rejected --
    otherwise get_policy('scu') would silently return the newcomer."""
    hijacker = dataclasses.replace(_dummy_policy("ring"), aliases=("scu",))
    with pytest.raises(ValueError, match="collides"):
        register_policy(hijacker)
    legacy_hijacker = dataclasses.replace(_dummy_policy("ring"), aliases=("SW",))
    with pytest.raises(ValueError, match="collides"):
        register_policy(legacy_hijacker)
    assert get_policy("scu").name == "scu"
    assert "ring" not in available_policies()


def test_overwrite_drops_stale_aliases():
    policy = dataclasses.replace(_dummy_policy(), aliases=("DUMMY", "DMY"))
    try:
        register_policy(policy)
        replacement = dataclasses.replace(policy, aliases=("DUMMY",))
        register_policy(replacement, overwrite=True)
        assert get_policy("dummy") is replacement
        with pytest.raises(KeyError):
            get_policy("dmy")  # stale alias of the replaced policy is gone
    finally:
        unregister_policy("dummy")


def test_unknown_policy_error_names_available():
    with pytest.raises(KeyError) as e:
        get_policy("bogus")
    msg = str(e.value)
    for name in BUILTINS:
        assert name in msg, f"error should name available policy {name!r}: {msg}"


def test_incomplete_policy_rejected():
    incomplete = dataclasses.replace(_dummy_policy("broken"), chip_barrier=None)
    with pytest.raises(TypeError, match="chip_barrier"):
        register_policy(incomplete)


def test_legacy_spellings_resolve():
    # the pre-registry simulator/benchmark spellings keep working via aliases
    for legacy in ("SCU", "TAS", "SW"):
        assert get_policy(legacy).name == legacy.lower()


def test_legacy_shim_imports_warn_and_resolve():
    # the PR-1 spellings survive as one-line deprecation wrappers only:
    # each must fire DeprecationWarning and forward to the registry
    import repro.core.scu.primitives as primitives
    import repro.core.sync.strategies as strategies

    with pytest.warns(DeprecationWarning, match="available_policies"):
        assert primitives.VARIANTS == ("SCU", "TAS", "SW")
    with pytest.warns(DeprecationWarning, match="repro.sync registry"):
        assert strategies.STRATEGIES == ("scu", "tas", "sw")
    assert callable(strategies.shape_gradients)
    assert callable(strategies.opt_state_specs)


def test_legacy_strategy_wrappers_warn_and_forward():
    from repro.core.sync.strategies import opt_state_specs, shape_gradients

    policy = get_policy("scu")
    mesh = make_axis_mesh((jax.device_count(),), ("x",))
    shape = jax.ShapeDtypeStruct((8,), jnp.float32)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        got = opt_state_specs("scu", {"w": shape}, mesh)
    assert got == policy.opt_state_specs({"w": shape}, mesh)
    grads = {"w": jnp.ones((8,), jnp.float32)}
    with pytest.warns(DeprecationWarning, match="deprecated"):
        shaped = shape_gradients("scu", grads, {"w": shape}, mesh)
    ref = policy.shape_gradients(grads, {"w": shape}, mesh)
    assert jax.tree_util.tree_structure(shaped) == jax.tree_util.tree_structure(ref)


def test_legacy_ops_barrier_warns_and_forwards():
    from repro.kernels.scu_barrier import ops

    with pytest.warns(DeprecationWarning, match="chip_barrier"):
        try:
            ops.barrier(jnp.ones((), jnp.float32), "x")
        except Exception:
            pass  # outside a mesh the forwarded call may reject the axis;
            # the contract under test is that the warning fired first


# ---------------------------------------------------------------------------
# Cross-layer parity: every policy provides every hook, and the barriers
# release with the full participant count at both granularities
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", BUILTINS)
def test_policy_implements_protocol(name):
    policy = get_policy(name)
    assert isinstance(policy, SyncPolicy)
    for hook in LAYER_HOOKS:
        assert callable(getattr(policy, hook)), f"{name} missing {hook}"
    assert policy.description


@pytest.mark.parametrize("name", BUILTINS)
@pytest.mark.parametrize("n", [2, 4, 8])
def test_sim_barrier_releases_full_group(name, n):
    """No core passes the simulator barrier before the last one arrives."""
    policy = get_policy(name)
    cl = Cluster(n_cores=n, scu=SCU(n_cores=n))
    state = policy.make_sim_state(n)
    passed = []
    delays = [1 + 9 * i for i in range(n)]

    def prog(delay):
        def p(cluster, cid):
            yield Compute(delay)
            yield from policy.sim_barrier(cluster, cid, state, None)
            passed.append((cid, cluster.cycle))

        return p

    cl.load([prog(d) for d in delays])
    cl.run(max_cycles=1_000_000)
    assert len(passed) == n, f"{name}: only {len(passed)}/{n} cores released"
    last_arrival = max(delays)
    for cid, cyc in passed:
        assert cyc >= last_arrival, f"{name}: core {cid} escaped early"


@pytest.mark.parametrize("name", BUILTINS)
def test_sim_mutex_mutual_exclusion(name):
    policy = get_policy(name)
    n = 4
    cl = Cluster(n_cores=n, scu=SCU(n_cores=n))
    state = policy.make_sim_state(n)
    done = []

    def prog(cluster, cid):
        for _ in range(3):
            yield from policy.sim_mutex(cluster, cid, 5, state, None)
        done.append(cid)

    cl.load([prog] * n)
    cl.run(max_cycles=2_000_000)
    assert sorted(done) == list(range(n)), f"{name}: mutex liveness violated"


@pytest.mark.parametrize("name", BUILTINS)
def test_chip_barrier_matches_psum_oracle(name):
    """Every discipline's released count == the psum oracle (exchanged
    values must actually produce the full participant count)."""
    policy = get_policy(name)
    n = min(4, jax.device_count())
    if n < 2:
        pytest.skip("needs >= 2 devices")
    mesh = make_axis_mesh((n,), ("x",))
    arrive = jnp.ones((n,), jnp.float32)

    @jax.jit
    def run(a):
        return shard_map(
            lambda v: (policy.chip_barrier(v, "x"), ref_barrier_count(v, "x")),
            mesh=mesh,
            in_specs=P("x"),
            out_specs=P("x"),
        )(a)

    got, oracle = run(arrive)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle))
    np.testing.assert_allclose(np.asarray(got), np.full((n,), float(n)))


# ---------------------------------------------------------------------------
# Tree-policy radix parametrization (radix-k tournament)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("radix", [2, 3, 4])
@pytest.mark.parametrize("n", [8, 16])
def test_tree_radix_barrier_releases_full_group(radix, n):
    """Radix-k tournament parity: no core escapes before the last arrival,
    for non-power-of-radix group sizes too."""
    policy = make_tree_policy(radix=radix)
    cl = Cluster(n_cores=n, scu=SCU(n_cores=n))
    state = policy.make_sim_state(n)
    assert state.radix == radix
    passed = []
    delays = [1 + 9 * i for i in range(n)]

    def prog(delay):
        def p(cluster, cid):
            yield Compute(delay)
            yield from policy.sim_barrier(cluster, cid, state, None)
            passed.append((cid, cluster.cycle))

        return p

    cl.load([prog(d) for d in delays])
    cl.run(max_cycles=1_000_000)
    assert len(passed) == n, f"radix {radix}: only {len(passed)}/{n} released"
    last_arrival = max(delays)
    for cid, cyc in passed:
        assert cyc >= last_arrival, f"radix {radix}: core {cid} escaped early"


def test_tree4_is_a_registered_builtin():
    """The radix-4 tournament is a builtin with a dedicated benchmark row:
    registered, alias-resolvable, and actually radix 4."""
    t4 = get_policy("tree4")
    assert t4.name == "tree4"
    assert get_policy("TREE4") is t4  # alias round-trip
    assert "tree4" in available_policies()
    assert t4.make_sim_state(16).radix == 4


def test_tree_radix4_halves_depth_on_16_cores():
    """Radix 4 -> 2 tournament levels instead of 4 on a 16-core cluster:
    the builtin tree4 barrier must be measurably cheaper than tree."""
    r2 = run_barrier_bench("tree", 16, sfr=0, iters=8)
    r4 = run_barrier_bench("tree4", 16, sfr=0, iters=8)
    assert r4.cycles_per_iter < r2.cycles_per_iter, (
        f"radix-4 tournament ({r4.cycles_per_iter}) should beat radix-2 "
        f"({r2.cycles_per_iter}) at 16 cores"
    )


def test_tree_default_radix_is_binary():
    assert get_policy("tree").make_sim_state(8).radix == 2


# ---------------------------------------------------------------------------
# Training layer: extension policies are numerically identical to scu
# ---------------------------------------------------------------------------


def _toy_grads(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    return {
        "embed": {"table": jax.random.normal(k1, (16, 8))},
        "blocks": {"wq": jax.random.normal(k2, (4, 8, 8))},
        "norm": jax.random.normal(k3, (8,)),
    }


@pytest.mark.parametrize("name", ["tree", "tree4", "fifo"])
def test_extension_shape_gradients_matches_scu(name):
    if jax.device_count() < 4:
        pytest.skip("needs 4 host devices")
    mesh = make_axis_mesh((2, 2), ("data", "model"))
    grads = _toy_grads()
    shaped = {}
    for n in ("scu", name):
        policy = get_policy(n)
        fn = jax.jit(lambda g: policy.shape_gradients(g, grads, mesh))
        shaped[n] = fn(grads)
    for (ka, a), (kb, b) in zip(
        jax.tree_util.tree_leaves_with_path(shaped["scu"]),
        jax.tree_util.tree_leaves_with_path(shaped[name]),
    ):
        assert ka == kb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the discipline must not change the values, only the schedule
    for a, b in zip(jax.tree.leaves(shaped[name]), jax.tree.leaves(grads)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["tree", "tree4", "fifo"])
def test_extension_opt_state_specs_match_scu(name):
    if jax.device_count() < 4:
        pytest.skip("needs 4 host devices")
    mesh = make_axis_mesh((2, 2), ("data", "model"))
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _toy_grads()
    )
    scu_specs = get_policy("scu").opt_state_specs(shapes, mesh)
    ext_specs = get_policy(name).opt_state_specs(shapes, mesh)
    assert jax.tree.all(
        jax.tree.map(
            lambda a, b: a == b, scu_specs, ext_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
    )


# ---------------------------------------------------------------------------
# The fifo policy: pipelined-chain vertical slice
# ---------------------------------------------------------------------------


def test_fifo_policy_registered_with_pipeline_hook():
    fifo = get_policy("fifo")
    assert fifo.name == "fifo"
    assert get_policy("FIFO") is fifo  # alias round-trip
    assert callable(fifo.make_pipeline_programs)
    # the barrier-only policies fall back to the barrier-sync emulation
    assert get_policy("scu").make_pipeline_programs is None


def test_fifo_chain_beats_software_barrier_pipeline():
    """The point of the FIFO discipline: a pipelined chain under per-link
    event queues must beat the same chain under the software barrier-
    synchronous schedule (the paper's Sec. 4.3 motivation)."""
    from repro.core.scu.programs import run_chain_bench

    fifo = run_chain_bench("fifo", 8, sfr=100, iters=16, depth=8)
    sw = run_chain_bench("sw", 8, sfr=100, iters=16)
    assert fifo.cycles_per_iter < 0.75 * sw.cycles_per_iter, (
        f"fifo chain ({fifo.cycles_per_iter}) should clearly beat the "
        f"sw barrier-sync pipeline ({sw.cycles_per_iter})"
    )


def test_fifo_chain_depth_bounds_in_flight():
    """Credit depth 1 serializes neighboring stages; deeper credit windows
    must monotonically recover throughput up to full overlap."""
    from repro.core.scu.programs import run_chain_bench

    costs = [
        run_chain_bench("fifo", 4, sfr=60, iters=12, depth=d).cycles_per_iter
        for d in (1, 2, 8)
    ]
    assert costs[0] > costs[1] > costs[2], costs


def test_fifo_pipelined_app_wins_under_imbalance():
    """On an imbalanced app skeleton the global barrier pays the cluster-
    wide maximum every tick; the FIFO chain only couples neighbors, so it
    must finish faster than the barrier-synchronous pipeline."""
    from repro.core.scu.apps import APPS, run_app_pipelined

    app = APPS["livermore6"]  # highest per-section imbalance in Table 2
    fifo = run_app_pipelined(app, "fifo")
    scu = run_app_pipelined(app, "scu")
    assert fifo.cycles < scu.cycles, (
        f"fifo pipeline ({fifo.cycles}) should beat the barrier-sync "
        f"schedule ({scu.cycles}) on an imbalanced app"
    )


def test_fifo_chain_rejects_depth_beyond_fifo_capacity():
    from repro.core.scu.programs import run_chain_bench

    with pytest.raises(ValueError, match="depth"):
        run_chain_bench("fifo", 4, sfr=10, iters=64, depth=1000)


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------


def test_train_config_validates_and_canonicalizes():
    from repro.train.step import TrainConfig

    assert TrainConfig(sync_strategy="TREE").sync_strategy == "tree"
    assert TrainConfig().sync_policy.name == "scu"
    with pytest.raises(KeyError, match="available policies"):
        TrainConfig(sync_strategy="bogus")


def test_config_base_choices_track_registry():
    from repro.configs.base import sync_policy_choices, validate_sync_policy

    assert sync_policy_choices() == available_policies()
    assert validate_sync_policy("SW") == "sw"
